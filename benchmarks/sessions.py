"""Session-window benchmark: MOVING-deadline hints vs arrival-ts hints
vs on-demand on NEXMark q11 (per-bidder activity sessions, DESIGN.md
§15).

Sessions are the adversarial case for deadline prefetching: a pane's
fire deadline is not known at assignment — every bid extends it and a
bridging bid MERGES two panes — so the lookahead must RE-HINT each move
and the TAC must renew resident panes in place.  Three modes over the
same arrival schedule:

  * ``ondemand``  — LRU cache, synchronous state access (no hints);
  * ``arrival``   — TAC + Keyed Prefetching, per-tuple ARRIVAL-ts hints
                    (right pane, mistimed for fire-time reads);
  * ``deadline``  — TAC + hints carrying the session's CURRENT end,
                    re-hinted on every extension/merge, deadline-aware
                    eviction and fire-time burst.

Emits ``BENCH_sessions.json``.  Expectation (ISSUE 9): the session query
under prefetch (deadline) holds p99 <= on-demand at equal offered load —
gated by tools/bench_gate.py.  ``--smoke`` is the reduced CI config.

    PYTHONPATH=src python benchmarks/sessions.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODES = {"ondemand": ("lru", "sync", "deadline"),
         "arrival": ("tac", "prefetch", "arrival"),
         "deadline": ("tac", "prefetch", "deadline")}

# cache calibrated BELOW the active-pane population (the regime where
# eviction ordering matters: on-demand thrashes panes awaiting fire)
FULL = {
    "q11": dict(rate=6_000.0, oo_bound=0.2, session_gap=0.4,
                allowed_lateness=0.2, cache_entries=128),
}
# reduced-scale CI smoke: same gap geometry (fire cadence must survive),
# lower rate and a proportionally smaller cache
SMOKE = {
    "q11": dict(rate=4_000.0, oo_bound=0.2, session_gap=0.4,
                allowed_lateness=0.2, cache_entries=96),
}


def run_one(query: str, mode: str, qcfg: dict, duration: float,
            warmup: float, seed: int = 7):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    policy, access, hint_ts = MODES[mode]
    cfg = NexmarkConfig(rate=qcfg["rate"], oo_bound=qcfg["oo_bound"],
                        seed=seed, watermark_interval=0.05)
    eng = build_query(query, policy, access, cfg,
                      cache_entries=qcfg["cache_entries"],
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, hint_ts=hint_ts,
                      session_gap=qcfg["session_gap"],
                      allowed_lateness=qcfg["allowed_lateness"])
    m = eng.run(duration=duration, warmup=warmup)
    return {"p50": m["p50"], "p99": m["p99"], "p999": m["p999"],
            "throughput": m["throughput"],
            "hit_rate": m.get("stateful_hit_rate", 0.0),
            "fires": m.get("stateful_fires", 0),
            "sessions_created": m.get("stateful_sessions_created", 0),
            "sessions_merged": m.get("stateful_sessions_merged", 0),
            "sessions_reopened": m.get("stateful_sessions_reopened", 0),
            "panes_purged": m.get("stateful_panes_purged", 0),
            "late_dropped": m.get("stateful_late_dropped", 0),
            "rehints": m.get("sess_lookahead_rehints", 0),
            "burst_hints": m.get("sess_lookahead_burst_hints", 0),
            "hints_received": m.get("stateful_hints_received", 0),
            "prefetch_hits": m.get("stateful_prefetch_hits", 0),
            "backend_reads": m.get("stateful_backend_reads", 0),
            "hint_quality": m.get("stateful_hint_quality", {}),
            "evictions": m.get("stateful_evictions", {})}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="q11")
    ap.add_argument("--modes", default="ondemand,arrival,deadline")
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config (3s run) for the "
                         "bench-smoke perf gate")
    ap.add_argument("--out", default="BENCH_sessions.json")
    args = ap.parse_args()

    cfgs = SMOKE if args.smoke else FULL
    duration, warmup = (3.0, 1.5) if args.smoke else \
        (args.duration, args.warmup)

    result = {"config": {"smoke": args.smoke, "duration": duration,
                         "warmup": warmup, "queries": dict(cfgs),
                         "parallelism": 2, "io_workers": 4,
                         "buffer_timeout": 0.002}}
    for query in args.queries.split(","):
        result[query] = {}
        for mode in args.modes.split(","):
            t0 = time.time()
            r = run_one(query, mode, cfgs[query], duration, warmup)
            r["bench_wall_s"] = time.time() - t0
            result[query][mode] = r
            print(f"[bench/sessions] {query} {mode:9s} "
                  f"p50={r['p50']*1e3:6.2f}ms p99={r['p99']*1e3:7.2f}ms "
                  f"hit={r['hit_rate']:.2f} fires={r['fires']} "
                  f"merged={r['sessions_merged']} "
                  f"rehints={r['rehints']} ({r['bench_wall_s']:.0f}s)",
                  file=sys.stderr)
        rs = result[query]
        if "deadline" in rs:
            headline = {}
            for base in ("ondemand", "arrival"):
                if base in rs:
                    headline[f"p99_speedup_vs_{base}"] = \
                        rs[base]["p99"] / max(1e-12, rs["deadline"]["p99"])
            result[query]["headline"] = headline
            print(f"[bench/sessions] {query} deadline p99 speedup: "
                  + ", ".join(f"{k.split('_vs_')[1]} x{v:.2f}"
                              for k, v in headline.items()),
                  file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({q: result[q].get("headline")
                      for q in args.queries.split(",")}, indent=2))


if __name__ == "__main__":
    main()
