"""Engine-throughput benchmark: the fused device hot path vs the
interpreted inner loop (DESIGN.md §14).

Three measurement planes, per query (q5 windowed count, YSB read-only
enrichment), all over the same generated workload with an untimed
warm-up prefix (steady state: hot state resident — the paper's
post-prefetch regime, where interpreter overhead rather than I/O
dominates tuples/sec):

  * ROOFLINE — capacity of the fused data path: raw
    ``FusedPlane.batch_step`` (stage -> one jitted probe/admit/compute/
    scatter program -> unstage) over the resident working set, no
    engine around it.  This is the number the tentpole changes: the
    data path detached from the per-tuple interpreter.
  * PUMP — wall-clock tuples/sec through the stateful operator inside
    the (single-threaded, simulated) engine, interpreted vs fused.
    Both modes share the sim's per-tuple control plane — delivery,
    drain, window assignment, adjudication — which SERIALIZES with the
    fused device calls here, while a deployment overlaps them.  The
    pump is therefore a parity/regression check on the fused mode's
    overheads, not the capacity claim.  Modes are INTERLEAVED
    (interpreted first in each pair, so warm-cache drift favors
    neither) and each keeps the best of ``--repeats``.
  * FULL — the complete pipeline under ``Engine.run``; sim-time p50/p99
    must show fused within 1.1x of interpreted (batching trades per-
    tuple dispatch for per-batch launches and must not cost latency).

The headline ``speedup_fused_vs_interpreted`` is ROOFLINE (fused data-
path capacity) over the interpreted PUMP (the interpreted data path —
which, by construction, cannot be detached from the per-tuple
interpreter loop: that loop IS interpretation).  An informational
``state_loop`` row (bare ``TimestampAwareCache`` ops in a tight Python
loop, no engine) locates the interpreter cost: state access itself is
fast — the per-tuple event-loop machinery around it is what the fused
path batches away.

Emits ``BENCH_engine.json``; the bench-smoke gate (tools/bench_gate.py)
requires headline speedup >= 1, fused pump within a parity band of
interpreted, and fused full-run p99 <= 1.1x interpreted for every
query present.

    PYTHONPATH=src python benchmarks/engine.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FULL = dict(n_tuples=60_000, batch=256, rate=5_000.0, duration=6.0,
            warmup=2.0, cache_entries=2048, pump_warmup=8_192)
SMOKE = dict(n_tuples=12_000, batch=256, rate=4_000.0, duration=2.0,
             warmup=0.5, cache_entries=1024, pump_warmup=3_072)


def q5_spec():
    from repro.streaming.fused import FusedSpec
    return FusedSpec(kind="sum", width=1,
                     weight_of=lambda tup: 1.0,
                     encode=lambda s: None if s is None else [float(s)],
                     decode=lambda v: int(round(float(v[0]))))


def ysb_spec():
    from repro.streaming.events import Tuple_
    from repro.streaming.fused import FusedSpec
    return FusedSpec(
        kind="read", width=1,
        encode=lambda s: [float(s["campaign"])],
        decode=lambda v: {"campaign": int(round(float(v[0])))},
        emit_of=lambda tup, state: [
            Tuple_(tup.ts, tup.key, (tup.payload, state), 130,
                   tup.ingest_t)])


# ---------------------------------------------------------------- workloads
def q5_workload(n, qcfg, seed=7):
    """Bid tuples + interleaved watermarks from the NEXMark generator,
    exactly as q5's stateful operator sees them."""
    from repro.streaming.events import Tuple_, Watermark
    from repro.streaming.nexmark import NexmarkConfig, NexmarkGen
    cfg = NexmarkConfig(rate=qcfg["rate"], active_window=1.0,
                        oo_bound=0.3, seed=seed)
    gen = NexmarkGen(cfg)
    out, now, hi = [], 0.0, 0.0
    next_wm = cfg.watermark_interval
    while sum(1 for x in out if not isinstance(x, Watermark)) < n:
        now += 1.0 / cfg.rate
        rec = gen(now)
        if rec is None or rec[1]["type"] != "bid":
            continue
        key, payload, size, ets = rec
        hi = max(hi, ets)
        out.append(Tuple_(ets, payload["auction"], payload, size, now))
        if now >= next_wm:
            out.append(Watermark(hi - cfg.oo_bound))
            next_wm += cfg.watermark_interval
    return out


def ysb_workload(n, qcfg, seed=11):
    from repro.streaming.events import Tuple_
    from repro.streaming.ysb import YSBConfig, YSBGen
    # the original YSB spec draws from 100 campaigns x 10 ads = 1000 ad
    # ids (our ysb.py default of 100k is the disaggregation stressor);
    # the engine bench wants the paper's post-prefetch regime — hot
    # state resident, interpreter overhead dominant — so use the
    # faithful ad universe, which fits the pump cache
    cfg = YSBConfig(rate=qcfg["rate"], n_ads=1_000, seed=seed)
    gen = YSBGen(cfg)
    out, now = [], 0.0
    while len(out) < n:
        now += 1.0 / cfg.rate
        key, payload, size = gen(now)
        if payload["etype"] != "view":
            continue
        out.append(Tuple_(now, key, payload, size, now))
    return out


# -------------------------------------------------------------- pump phase
def _mk_q5_op(eng, qcfg, fused):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.windows import WindowAssigner, WindowedStatefulOp

    def agg(tup, acc):
        return (acc or 0) + 1

    def emit(key, wid, end, acc):
        return ("count", key, acc) if acc else None

    kw = dict(policy="tac", mode="async", io_workers=4, state_size=96,
              allowed_lateness=1.0, late_policy="update",
              deadline_aware=True)
    if fused:
        kw.update(fused=q5_spec(), fused_batch=qcfg["batch"])
    return WindowedStatefulOp(eng, "stateful", 1, WindowAssigner(2.0, 1.0),
                              agg, emit, LOCAL_NVME,
                              qcfg["cache_entries"] * 96, **kw)


def _mk_ysb_op(eng, qcfg, fused):
    from repro.streaming.backend import DISAGGREGATED
    from repro.streaming.engine import StatefulOp
    from repro.streaming.events import Tuple_

    def apply_fn(tup, state):
        return state, [Tuple_(tup.ts, tup.key, (tup.payload, state), 130,
                              tup.ingest_t)]

    kw = dict(policy="tac", mode="async", io_workers=8, state_size=64,
              read_only=True, default_state=lambda k: {"campaign": k % 1000},
              dense_backend=True)
    if fused:
        kw.update(fused=ysb_spec(), fused_batch=qcfg["batch"])
    return StatefulOp(eng, "stateful", 1, apply_fn, DISAGGREGATED,
                      qcfg["cache_entries"] * 64, **kw)


def pump(query, fused, workload, qcfg):
    """Wall-clock tuples/sec through the stateful operator alone."""
    from repro.streaming.engine import Engine, SinkOp
    eng = Engine()
    op = _mk_q5_op(eng, qcfg, fused) if query == "q5" \
        else _mk_ysb_op(eng, qcfg, fused)
    sink = SinkOp(eng, "sink", 1)
    eng.add(op)
    eng.add(sink)
    eng.connect(op, sink, partition=lambda k, n: 0)
    chunk = 512
    t = 0.0
    # untimed warm-up prefix: first-touch state fetches amortize out of
    # the measurement for BOTH modes, leaving the steady-state regime
    # the paper targets (prefetching keeps hot state resident; what is
    # left on the critical path is the per-tuple interpreter)
    wn = min(qcfg.get("pump_warmup", 0), max(0, len(workload) - chunk))
    warm, timed = workload[:wn], workload[wn:]
    for i in range(0, len(warm), chunk):
        op.deliver_batch(0, list(warm[i:i + chunk]))
        t += 1.0
        eng.sim.run_until(t)
    eng.sim.run_until(t + 5.0)        # quiesce: parked/in-flight land
    n = sum(1 for x in timed
            if not type(x).__name__ == "Watermark")
    t0 = time.perf_counter()
    for i in range(0, len(timed), chunk):
        op.deliver_batch(0, list(timed[i:i + chunk]))
        t += 1.0                      # sim-seconds: drains queue + I/O
        eng.sim.run_until(t)
    eng.sim.run_until(t + 5.0)
    wall = time.perf_counter() - t0
    r = {"wall_s": wall, "n_tuples": n,
         "tuples_per_s": n / wall if wall > 0 else 0.0,
         "hit_rate": op.caches[0].hit_rate,
         "processed": op.processed}
    if fused:
        plane = op.caches[0]
        r["fused"] = {"batches": plane.batches, "lanes": plane.lanes,
                      "fill_ratio": plane.fill_ratio,
                      "device_hits": plane.device_hits,
                      "device_misses": plane.device_misses}
    return r


def state_loop(query, qcfg, n):
    """Informational: the interpreted STATE ACCESS alone — bare
    ``TimestampAwareCache`` lookup/agg/write in a tight Python loop
    over a resident working set, no engine.  Fast on CPython (dict +
    int ops): shows the interpreted pump's deficit lives in the
    per-tuple event-loop machinery, which is what the fused data path
    batches away."""
    import numpy as np

    from repro.core.tac import TimestampAwareCache
    from repro.streaming.events import Tuple_
    from repro.streaming.windows import WindowKey
    rng = np.random.default_rng(3)
    picks = rng.integers(0, 512, size=n)
    if query == "q5":
        cache = TimestampAwareCache(qcfg["cache_entries"] * 96,
                                    deadline_aware=True)
        keys = [WindowKey(k, 0) for k in range(512)]
        for wk in keys:
            cache.insert(wk, 1, 0.0, size=96)
        seq = [keys[i] for i in picks]
        t0 = time.perf_counter()
        for wk in seq:
            acc = cache.lookup(wk, 1.0)
            cache.write(wk, (acc or 0) + 1, 1.0, size=96)
        wall = time.perf_counter() - t0
    else:
        cache = TimestampAwareCache(qcfg["cache_entries"] * 64)
        for k in range(512):
            cache.insert(k, {"campaign": k % 1000}, 0.0, size=64)
        seq = [int(i) for i in picks]
        out: list = []
        t0 = time.perf_counter()
        for k in seq:
            st = cache.lookup(k, 1.0)
            out.append(Tuple_(1.0, k, (None, st), 130, 1.0))
            if len(out) > 1024:
                out.clear()
        wall = time.perf_counter() - t0
    return {"wall_s": wall, "n_tuples": n,
            "tuples_per_s": n / wall if wall > 0 else 0.0}


def roofline(query, qcfg, n):
    """Fused data-path capacity: batch_step over a resident working
    set, no engine, no adjudication — what the operator sustains once
    the per-tuple interpreter is off the data path."""
    import numpy as np

    from repro.streaming.fused import FusedPlane, Lane
    spec = q5_spec() if query == "q5" else ysb_spec()
    B = qcfg["batch"]
    plane = FusedPlane(qcfg["cache_entries"] * 64, 64, spec, batch=B)
    keys = list(range(min(qcfg["cache_entries"] - 1, 512)))
    for k in keys:
        plane.insert(k, 1 if query == "q5" else {"campaign": k % 1000},
                     0.0)
    rng = np.random.default_rng(3)
    picks = rng.integers(0, len(keys), size=(max(1, n // B), B))
    w = spec.weight(None) if spec.weight_of is None \
        or query == "q5" else None
    lanes_by_batch = [
        [Lane(int(k), 1.0, spec.weight(None) if query == "q5"
              else np.zeros(spec.width, np.float32), False, False, None)
         for k in row] for row in picks]
    plane.batch_step(lanes_by_batch[0])       # compile outside the clock
    t0 = time.perf_counter()
    for lanes in lanes_by_batch:
        plane.batch_step(lanes)
    wall = time.perf_counter() - t0
    total = len(lanes_by_batch) * B
    return {"wall_s": wall, "n_tuples": total,
            "tuples_per_s": total / wall if wall > 0 else 0.0}


# -------------------------------------------------------------- full phase
def full_run(query, fused, qcfg):
    from repro.streaming.nexmark import NexmarkConfig, build_query
    from repro.streaming.ysb import YSBConfig, build_ysb
    if query == "q5":
        cfg = NexmarkConfig(rate=qcfg["rate"], active_window=1.0,
                            oo_bound=0.3, seed=7)
        eng = build_query("q5", "tac", "async", cfg,
                          cache_entries=qcfg["cache_entries"],
                          parallelism=2, source_parallelism=1,
                          io_workers=4, buffer_timeout=0.002,
                          fused=fused, fused_batch=qcfg["batch"])
    else:
        cfg = YSBConfig(rate=qcfg["rate"], seed=11)
        eng = build_ysb("tac", "async", cfg,
                        cache_entries=qcfg["cache_entries"],
                        parallelism=2, source_parallelism=1,
                        io_workers=8, fused=fused,
                        fused_batch=qcfg["batch"])
    t0 = time.perf_counter()
    m = eng.run(duration=qcfg["duration"], warmup=qcfg["warmup"])
    wall = time.perf_counter() - t0
    r = {"wall_s": wall, "p50": m["p50"], "p99": m["p99"],
         "n_outputs": m["n_outputs"],
         "hit_rate": m.get("stateful_hit_rate", 0.0)}
    if fused:
        r["fused"] = m.get("stateful_fused", {})
    return r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3,
                    help="pump runs per mode; best (lowest wall) kept")
    ap.add_argument("--queries", default="q5,ysb")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config for the bench-smoke "
                         "engine-throughput gate")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args()

    qcfg = dict(SMOKE if args.smoke else FULL)
    queries = [q.strip() for q in args.queries.split(",") if q.strip()]
    result = {"config": {"smoke": args.smoke, "repeats": args.repeats,
                         **qcfg}}

    for query in queries:
        workload = q5_workload(qcfg["n_tuples"], qcfg) if query == "q5" \
            else ysb_workload(qcfg["n_tuples"], qcfg)
        best: dict = {}
        # interleaved, interpreted first in each pair (module docstring)
        for i in range(max(1, args.repeats)):
            for mode, fused in (("interpreted", False), ("fused", True)):
                r = pump(query, fused, workload, qcfg)
                if mode not in best or r["wall_s"] < best[mode]["wall_s"]:
                    best[mode] = r
                print(f"[bench/engine] {query} pump {mode:11s} #{i + 1} "
                      f"wall={r['wall_s']:6.2f}s "
                      f"tput={r['tuples_per_s']:9.0f} tup/s",
                      file=sys.stderr)
        rf = roofline(query, qcfg, qcfg["n_tuples"])
        sl = state_loop(query, qcfg, qcfg["n_tuples"])
        print(f"[bench/engine] {query} roofline "
              f"tput={rf['tuples_per_s']:9.0f} tup/s "
              f"(state loop {sl['tuples_per_s']:9.0f})", file=sys.stderr)
        fulls = {}
        for mode, fused in (("interpreted", False), ("fused", True)):
            fulls[mode] = full_run(query, fused, qcfg)
            print(f"[bench/engine] {query} full {mode:11s} "
                  f"p99={fulls[mode]['p99']*1e3:.2f}ms",
                  file=sys.stderr)
        interp_tput = max(1e-12, best["interpreted"]["tuples_per_s"])
        speedup = rf["tuples_per_s"] / interp_tput
        pump_ratio = best["fused"]["tuples_per_s"] / interp_tput
        result[query] = {
            "interpreted": best["interpreted"], "fused": best["fused"],
            "roofline": rf,
            "state_loop": sl,
            "full": fulls,
            "headline": {
                # fused data-path capacity over the interpreted data
                # path (the engine's per-tuple loop); module docstring
                "speedup_fused_vs_interpreted": speedup,
                "pump_ratio_fused_vs_interpreted": pump_ratio,
                "pump_fused_vs_roofline":
                    best["fused"]["tuples_per_s"] /
                    max(1e-12, rf["tuples_per_s"]),
                "p99_ratio_fused_vs_interpreted":
                    fulls["fused"]["p99"] /
                    max(1e-12, fulls["interpreted"]["p99"]),
            }}
        h = result[query]["headline"]
        print(f"[bench/engine] {query}: hot path x{speedup:.2f} "
              f"interpreted, pump x{pump_ratio:.2f}, "
              f"p99 x{h['p99_ratio_fused_vs_interpreted']:.3f}",
              file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({q: result[q]["headline"] for q in queries},
                     indent=2))


if __name__ == "__main__":
    main()
