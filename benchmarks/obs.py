"""Observability overhead benchmark: tracing-enabled vs disabled
wall-clock throughput on the q5 smoke pipeline (DESIGN.md §12).

The observability plane's contract is ZERO-COST WHEN OFF and cheap when
on: sources check one flag per tuple, operator marks hide behind a
``trace is not None`` test, and disabled registry handles are shared
no-op singletons.  This benchmark proves it with the only number that
can — WALL-CLOCK tuples/sec (sim-time latency percentiles are invariant
to host overhead by construction, so they cannot see instrumentation
cost):

  * ``disabled`` — tracing off (``sample_every=0``), the default;
  * ``traced``   — per-tuple critical-path tracing at the default
                   sampling rate plus a periodic JSONL snapshot export;
  * ``timeline`` — everything ``traced`` does PLUS the temporal plane
                   (DESIGN.md §16): interval snapshots on the logical
                   clock, the full health-detector set, engine event
                   recording, and a Perfetto/Chrome trace export.

Host noise on a shared machine dwarfs the actual instrumentation cost,
so the modes are INTERLEAVED (disabled, traced, timeline, disabled,
...) — temporal drift hits all equally — and each mode keeps the best
of its ``--repeats`` runs.  Disabled still goes first in every round,
so any warm-cache advantage of running later accrues to the
instrumented modes: conservative is fine, flattering is not.

The run also replays the chaos alert oracle (DESIGN.md §16): on three
seeded fault schedules, the golden run must raise ZERO alerts and every
effective injected fault must raise its mapped alert within the logical
delay bound — the ``alerts`` block the gate reads.

Emits ``BENCH_obs.json`` plus ``obs_trace.json`` (a Perfetto
trace of the timeline run — loadable in chrome://tracing / ui.perfetto.dev).
The bench-smoke gate (tools/bench_gate.py) requires traced AND timeline
throughput >= 0.95x disabled, a dominant stage, nonzero staged hints,
alert-oracle recall 1.0, and zero golden false alerts.

    PYTHONPATH=src python benchmarks/obs.py --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# the windowing benchmark's q5 configs (same calibration rationale —
# benchmarks/windowing.py): deadline-ts hints so the hint-quality block
# exercises every outcome class
FULL = dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
            window_size=2.0, window_slide=1.0, cache_entries=512)
SMOKE = dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
             window_size=1.0, window_slide=0.5, cache_entries=256)


def run_one(mode: str, qcfg: dict, duration: float, warmup: float,
            sample_every: int, seed: int = 7, trace_out: str = None):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    cfg = NexmarkConfig(rate=qcfg["rate"],
                        active_window=qcfg["active_window"],
                        oo_bound=qcfg["oo_bound"], seed=seed)
    eng = build_query("q5", "tac", "prefetch", cfg,
                      cache_entries=qcfg["cache_entries"],
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, hint_ts="deadline",
                      window_size=qcfg["window_size"],
                      window_slide=qcfg["window_slide"])
    export_path = None
    if mode in ("traced", "timeline"):
        eng.enable_tracing(sample_every=sample_every)
        export_path = os.path.join(tempfile.mkdtemp(prefix="obs_bench_"),
                                   "snapshots.jsonl")
        eng.enable_export(export_path, interval=0.5)
    if mode == "timeline":
        eng.enable_timeline(interval=0.1)
    t0 = time.perf_counter()
    m = eng.run(duration=duration, warmup=warmup)
    wall_s = time.perf_counter() - t0
    r = {"wall_s": wall_s, "n_outputs": m["n_outputs"],
         "tuples_per_s": m["n_outputs"] / wall_s if wall_s > 0 else 0.0,
         "p50": m["p50"], "p99": m["p99"],
         "hit_rate": m.get("stateful_hit_rate", 0.0)}
    if mode in ("traced", "timeline"):
        r["trace"] = m.get("trace", {})
        r["hint_quality"] = m.get("stateful_hint_quality", {})
        r["evictions"] = m.get("stateful_evictions", {})
        with open(export_path) as f:
            r["export_snapshots"] = sum(1 for _ in f)
    if mode == "timeline":
        r["timeline"] = m.get("timeline", {})
        r["health"] = m.get("health", {})
        r["n_alerts"] = len(m.get("alerts", []))
        if trace_out:
            from repro.obs import chrome_trace
            trace = chrome_trace(eng, path=trace_out)
            r["perfetto_events"] = len(trace["traceEvents"])
    return r


# the three validated oracle schedules (tests/test_timeline.py runs the
# same set): every fault kind the oracle maps, plus one deliberately
# ineffective migrate that effective-event filtering must drop
def oracle_schedules():
    from repro.streaming.chaos import FaultEvent, FaultSchedule
    return [
        FaultSchedule(101, (
            FaultEvent("load_shift", 0.5, (2.5, 0.5)),
            FaultEvent("migrate", 1.0, (0, 1)),
            FaultEvent("failure", 1.3, ("warmed",)))),
        FaultSchedule(202, (
            FaultEvent("failure", 0.7, ("cold",)),
            FaultEvent("load_shift", 1.1, (0.4, 0.4)),
            FaultEvent("migrate", 1.4, (1, 0)))),
        FaultSchedule(303, (
            FaultEvent("migrate", 0.5, (3, 0)),
            FaultEvent("migrate", 0.7, (2, 0)),
            FaultEvent("load_shift", 0.9, (3.0, 0.4)),
            FaultEvent("failure", 1.35, ("warmed",)))),
    ]


def run_alert_oracle():
    """Chaos-validated detector soundness + sensitivity (the gate's
    ``alerts`` rule): aggregate recall and golden-false-alert counts
    over the seeded schedules."""
    from repro.streaming.chaos import alert_oracle, run_schedule
    agg = {"schedules": [], "injected": 0, "matched": 0,
           "golden_alerts": 0, "golden_false_stall": 0,
           "per_kind": {}}
    for sched in oracle_schedules():
        golden = run_schedule(sched.with_events(()), t_cut=2.0,
                              observe=True)
        pert = run_schedule(sched, t_cut=2.0, observe=True)
        rep = alert_oracle(sched, pert, golden)
        agg["schedules"].append({"seed": sched.seed, **{
            k: rep[k] for k in ("injected", "matched", "recall",
                                "golden_alerts", "golden_false_stall",
                                "per_kind")}})
        agg["injected"] += rep["injected"]
        agg["matched"] += rep["matched"]
        agg["golden_alerts"] += rep["golden_alerts"]
        agg["golden_false_stall"] += rep["golden_false_stall"]
        for kind, pk in rep["per_kind"].items():
            slot = agg["per_kind"].setdefault(
                kind, {"injected": 0, "matched": 0})
            slot["injected"] += pk["injected"]
            slot["matched"] += pk["matched"]
    agg["recall"] = agg["matched"] / agg["injected"] \
        if agg["injected"] else 0.0
    return agg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="runs per mode; best (lowest wall) is kept")
    ap.add_argument("--sample-every", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config (half-size windows, "
                         "3s run) for the bench-smoke obs-overhead gate")
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--trace-out", default="obs_trace.json",
                    help="Perfetto/Chrome trace of the timeline run")
    args = ap.parse_args()

    qcfg = SMOKE if args.smoke else FULL
    duration, warmup = (3.0, 1.5) if args.smoke else \
        (args.duration, args.warmup)

    result = {"config": {"smoke": args.smoke, "duration": duration,
                         "warmup": warmup, "query": dict(qcfg),
                         "repeats": args.repeats,
                         "sample_every": args.sample_every,
                         "parallelism": 2, "io_workers": 4}}
    # interleaved, disabled first in each round (see module docstring)
    best: dict = {}
    for i in range(max(1, args.repeats)):
        for mode in ("disabled", "traced", "timeline"):
            # heap garbage from the previous engine (event lists, spans,
            # ring buffers) must not bill its GC pauses to this mode
            gc.collect()
            r = run_one(mode, qcfg, duration, warmup, args.sample_every,
                        trace_out=args.trace_out
                        if mode == "timeline" else None)
            if mode not in best or r["wall_s"] < best[mode]["wall_s"]:
                best[mode] = r
            print(f"[bench/obs] {mode:9s} #{i + 1} "
                  f"wall={r['wall_s']:6.2f}s "
                  f"tput={r['tuples_per_s']:9.0f} tup/s "
                  f"p99={r['p99']*1e3:.2f}ms", file=sys.stderr)
    result.update(best)

    result["alerts"] = run_alert_oracle()

    dis = max(1e-12, result["disabled"]["tuples_per_s"])
    tput_ratio = result["traced"]["tuples_per_s"] / dis
    tl_ratio = result["timeline"]["tuples_per_s"] / dis
    result["headline"] = {
        "throughput_ratio_traced_vs_disabled": tput_ratio,
        "throughput_ratio_timeline_vs_disabled": tl_ratio,
        "alert_recall": result["alerts"]["recall"],
        "golden_alerts": result["alerts"]["golden_alerts"]}
    tr = result["traced"].get("trace", {})
    hq = result["traced"].get("hint_quality", {})
    print(f"[bench/obs] traced/disabled throughput x{tput_ratio:.3f} "
          f"timeline/disabled x{tl_ratio:.3f} "
          f"dominant={tr.get('dominant_stage')} "
          f"precision={hq.get('precision', 0.0):.2f} "
          f"recall={hq.get('recall', 0.0):.2f}", file=sys.stderr)
    print(f"[bench/obs] alert oracle: recall="
          f"{result['alerts']['recall']:.2f} "
          f"({result['alerts']['matched']}/{result['alerts']['injected']}) "
          f"golden alerts={result['alerts']['golden_alerts']} "
          f"trace events={result['timeline'].get('perfetto_events', 0)} "
          f"-> {args.trace_out}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps(result["headline"], indent=2))


if __name__ == "__main__":
    main()
