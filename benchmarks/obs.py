"""Observability overhead benchmark: tracing-enabled vs disabled
wall-clock throughput on the q5 smoke pipeline (DESIGN.md §12).

The observability plane's contract is ZERO-COST WHEN OFF and cheap when
on: sources check one flag per tuple, operator marks hide behind a
``trace is not None`` test, and disabled registry handles are shared
no-op singletons.  This benchmark proves it with the only number that
can — WALL-CLOCK tuples/sec (sim-time latency percentiles are invariant
to host overhead by construction, so they cannot see instrumentation
cost):

  * ``disabled`` — tracing off (``sample_every=0``), the default;
  * ``traced``   — per-tuple critical-path tracing at the default
                   sampling rate plus a periodic JSONL snapshot export.

Host noise on a shared machine dwarfs the actual instrumentation cost,
so the two modes are INTERLEAVED (disabled, traced, disabled, traced,
...) — temporal drift hits both equally — and each mode keeps the best
of its ``--repeats`` runs.  Disabled still goes first in every pair, so
any warm-cache advantage of running later accrues to the traced mode:
conservative is fine, flattering is not.

Emits ``BENCH_obs.json``.  The bench-smoke gate (tools/bench_gate.py)
requires traced throughput >= 0.95x disabled (ISSUE 6 acceptance), and
the traced run must surface a stage breakdown with a dominant stage and
a hint-quality block with nonzero staged hints.

    PYTHONPATH=src python benchmarks/obs.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# the windowing benchmark's q5 configs (same calibration rationale —
# benchmarks/windowing.py): deadline-ts hints so the hint-quality block
# exercises every outcome class
FULL = dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
            window_size=2.0, window_slide=1.0, cache_entries=512)
SMOKE = dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
             window_size=1.0, window_slide=0.5, cache_entries=256)


def run_one(mode: str, qcfg: dict, duration: float, warmup: float,
            sample_every: int, seed: int = 7):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    cfg = NexmarkConfig(rate=qcfg["rate"],
                        active_window=qcfg["active_window"],
                        oo_bound=qcfg["oo_bound"], seed=seed)
    eng = build_query("q5", "tac", "prefetch", cfg,
                      cache_entries=qcfg["cache_entries"],
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, hint_ts="deadline",
                      window_size=qcfg["window_size"],
                      window_slide=qcfg["window_slide"])
    export_path = None
    if mode == "traced":
        eng.enable_tracing(sample_every=sample_every)
        export_path = os.path.join(tempfile.mkdtemp(prefix="obs_bench_"),
                                   "snapshots.jsonl")
        eng.enable_export(export_path, interval=0.5)
    t0 = time.perf_counter()
    m = eng.run(duration=duration, warmup=warmup)
    wall_s = time.perf_counter() - t0
    r = {"wall_s": wall_s, "n_outputs": m["n_outputs"],
         "tuples_per_s": m["n_outputs"] / wall_s if wall_s > 0 else 0.0,
         "p50": m["p50"], "p99": m["p99"],
         "hit_rate": m.get("stateful_hit_rate", 0.0)}
    if mode == "traced":
        r["trace"] = m.get("trace", {})
        r["hint_quality"] = m.get("stateful_hint_quality", {})
        r["evictions"] = m.get("stateful_evictions", {})
        with open(export_path) as f:
            r["export_snapshots"] = sum(1 for _ in f)
    return r


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="runs per mode; best (lowest wall) is kept")
    ap.add_argument("--sample-every", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config (half-size windows, "
                         "3s run) for the bench-smoke obs-overhead gate")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()

    qcfg = SMOKE if args.smoke else FULL
    duration, warmup = (3.0, 1.5) if args.smoke else \
        (args.duration, args.warmup)

    result = {"config": {"smoke": args.smoke, "duration": duration,
                         "warmup": warmup, "query": dict(qcfg),
                         "repeats": args.repeats,
                         "sample_every": args.sample_every,
                         "parallelism": 2, "io_workers": 4}}
    # interleaved, disabled first in each pair (see module docstring)
    best: dict = {}
    for i in range(max(1, args.repeats)):
        for mode in ("disabled", "traced"):
            r = run_one(mode, qcfg, duration, warmup, args.sample_every)
            if mode not in best or r["wall_s"] < best[mode]["wall_s"]:
                best[mode] = r
            print(f"[bench/obs] {mode:9s} #{i + 1} "
                  f"wall={r['wall_s']:6.2f}s "
                  f"tput={r['tuples_per_s']:9.0f} tup/s "
                  f"p99={r['p99']*1e3:.2f}ms", file=sys.stderr)
    result.update(best)

    tput_ratio = result["traced"]["tuples_per_s"] / \
        max(1e-12, result["disabled"]["tuples_per_s"])
    result["headline"] = {"throughput_ratio_traced_vs_disabled": tput_ratio}
    tr = result["traced"].get("trace", {})
    hq = result["traced"].get("hint_quality", {})
    print(f"[bench/obs] traced/disabled throughput x{tput_ratio:.3f} "
          f"dominant={tr.get('dominant_stage')} "
          f"precision={hq.get('precision', 0.0):.2f} "
          f"recall={hq.get('recall', 0.0):.2f}", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps(result["headline"], indent=2))


if __name__ == "__main__":
    main()
