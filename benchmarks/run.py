# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig6,fig7,fig8,fig9,fig10,fig11,"
                         "tab1,tab2,roofline,claims")
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--fail-at", type=float, default=None,
                    help="run the failure/recovery scenario instead of the "
                         "paper figures: inject a failure this many seconds "
                         "after warmup on q5 and q20 (DESIGN.md §7)")
    ap.add_argument("--recover", default="warmed,cold",
                    help="comma list of recovery modes to run with "
                         "--fail-at (warmed|cold)")
    ap.add_argument("--fused", action="store_true",
                    help="run stateful hot paths on the fused device "
                         "plane where a FusedSpec exists (ysb; q5/q7 "
                         "overrides) — other workloads stay interpreted "
                         "(DESIGN.md §14)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)              # `benchmarks` package itself
    from benchmarks import paper, roofline
    paper.FUSED = args.fused

    if args.fail_at is not None:
        from benchmarks import recovery as rbench
        modes = args.recover.split(",")
        bad = [m for m in modes if m not in ("warmed", "cold")]
        if bad:
            ap.error(f"--recover modes must be warmed|cold, got {bad}")
        os.makedirs(args.out, exist_ok=True)
        rows = ["name,us_per_call,derived"]
        for query in ("q5", "q20"):
            qcfg = dict(rbench.FULL[query], fail_at=args.fail_at)
            for mode in modes:
                r = rbench.run_one(query, mode, qcfg)
                spike = r.get("post_restore_p99") or 0.0
                rows.append(
                    f"recovery_{query}_{mode},{spike*1e6:.1f},"
                    f"steady_p99_us={(r['steady_p99'] or 0)*1e6:.1f};"
                    f"recovery_s={r.get('recovery_time', 0):.3f};"
                    f"warmup_hints={r.get('warmup_hints', 0)}")
                print(rows[-1], file=sys.stderr)
        csv = "\n".join(rows)
        print(csv)
        with open(os.path.join(args.out, "recovery.csv"), "w") as f:
            f.write(csv + "\n")
        return

    os.makedirs(args.out, exist_ok=True)
    rows = ["name,us_per_call,derived"]

    def want(x):
        return only is None or x in only

    fig6_out = {}
    t0 = time.time()
    if want("fig6") or want("tab1") or want("tab2") or want("claims"):
        fig6_out = paper.fig6(rows)
        print(f"[bench] fig6 done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if want("fig7"):
        paper.fig7(rows)
        print(f"[bench] fig7 done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if want("fig8"):
        paper.fig8(rows)
        print(f"[bench] fig8 done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if want("fig9"):
        paper.fig9(rows)
        print(f"[bench] fig9 done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if want("fig10"):
        paper.fig10(rows)
        print(f"[bench] fig10 done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if want("fig11"):
        paper.fig11(rows)
        print(f"[bench] fig11 done ({time.time()-t0:.0f}s)", file=sys.stderr)
    if fig6_out and want("tab1"):
        paper.tab1(rows, fig6_out)
    if fig6_out and want("tab2"):
        paper.tab2(rows, fig6_out)
    if fig6_out and want("claims"):
        paper.validate_claims(rows, fig6_out)
    if want("roofline"):
        roofline.roofline_rows(rows)

    csv = "\n".join(rows)
    print(csv)
    with open(os.path.join(args.out, "bench.csv"), "w") as f:
        f.write(csv + "\n")


if __name__ == "__main__":
    main()
