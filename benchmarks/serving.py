"""Serving benchmark: prefetch vs on-demand TTFT at equal offered load.

Runs the paged session-state serving path (real jitted smoke-model decode,
calibrated store latency on the hybrid clock) in ``sync`` (on-demand
staging), ``async`` and ``prefetch`` modes over the SAME arrival schedule,
and emits ``BENCH_serving.json``.

    PYTHONPATH=src python benchmarks/serving.py --requests 48
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--cache-sessions", type=int, default=8)
    ap.add_argument("--rate", type=float, default=500.0)
    ap.add_argument("--decode-tokens", type=int, default=3)
    ap.add_argument("--modes", default="sync,async,prefetch")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config (fewer requests/"
                         "sessions, sync+prefetch only) for the "
                         "bench-smoke perf gate (tools/bench_gate.py)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    if args.smoke:
        args.requests = min(args.requests, 16)
        args.sessions = min(args.sessions, 8)
        args.cache_sessions = min(args.cache_sessions, 4)
        args.decode_tokens = min(args.decode_tokens, 2)
        if args.modes == "sync,async,prefetch":
            args.modes = "sync,prefetch"

    from repro.launch.serve import ServeConfig, run_serving

    cfg = ServeConfig(arch=args.arch, n_requests=args.requests,
                      n_sessions=args.sessions,
                      cache_sessions=args.cache_sessions,
                      decode_tokens=args.decode_tokens,
                      arrival_rate=args.rate)
    result = {"config": {"smoke": args.smoke,
                         "arch": cfg.arch, "n_requests": cfg.n_requests,
                         "n_sessions": cfg.n_sessions,
                         "cache_sessions": cfg.cache_sessions,
                         "arrival_rate": cfg.arrival_rate,
                         "decode_tokens": cfg.decode_tokens,
                         "store_latency": cfg.store_latency}}
    for mode in args.modes.split(","):
        t0 = time.time()
        r = run_serving(cfg, mode)
        r["bench_wall_s"] = time.time() - t0
        result[mode] = r
        print(f"[bench/serving] {mode:8s} "
              f"ttft p50={r['ttft_p50']*1e3:7.2f}ms "
              f"p99={r['ttft_p99']*1e3:7.2f}ms "
              f"tpot p50={r['tpot_p50']*1e3:6.2f}ms "
              f"hit={r['arena_hit_rate']:.2f} "
              f"overlap={r['staging_overlap']:.2f} "
              f"({r['bench_wall_s']:.0f}s)", file=sys.stderr)

    if "sync" in result and "prefetch" in result:
        sp = result["sync"]["ttft_p99"] / max(1e-12,
                                              result["prefetch"]["ttft_p99"])
        result["prefetch_p99_ttft_speedup"] = sp
        print(f"[bench/serving] prefetch p99 TTFT speedup {sp:.2f}x "
              "at equal offered load", file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({k: v for k, v in result.items()
                      if k != "config"}, indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
