"""Hint-quality benchmark: all-hints vs selective vs selective+
speculative admission at matched offered load (DESIGN.md §13).

Runs NEXMark q5 (sliding-window panes), q8 (tumbling-window join), and
q20 (interval join) over the same arrival schedule, sweeping the
auction-id distribution — ``uniform`` (no skew), ``zipf`` (static hot
head), ``shift`` (zipf whose hot set ROTATES mid-run, the adversarial
case for learned suppression) — and the lookahead's HintFilter mode:

  * ``allhints``    — every extracted hint goes out (the ablation
                      baseline: maximum recall, maximum waste);
  * ``selective``   — residency + cold-key suppression with hot-key
                      priority (core/hint_filter.py decision table);
  * ``speculative`` — selective plus predicted hints: next-pane window
                      pre-hints at watermark advance and join-partner
                      frontier hints before the key appears upstream.

All three run TAC + Keyed Prefetching with delta-compressed hint
channels, so the ONLY variable is which hints are worth sending.  The
headline per scenario is the wasted-hint count (stagings evicted unused
PLUS duplicate hints for already-resident keys) against p99: selective
must cut waste without giving up tail latency, and every suppression is
graded retroactively (suppress_resident / suppress_miss /
suppress_unused) by the PrefetchRecorder.

Emits ``BENCH_hints.json``.  Expectation (ISSUE 7, the CI gate in
tools/bench_gate.py): on the Zipf scenario selective cuts wasted hints
>= 2x vs all-hints at equal load with p99 no worse, and q20 hint
precision improves from its 0.20 two-sided baseline (BENCH_joins.json).
``--smoke`` runs the Zipf column only at reduced scale.

    PYTHONPATH=src python benchmarks/hints.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# HintFilter config per mode.  resident_ttl ~ a few hint-channel flush
# horizons: once a key was hinted, re-hinting inside that window only
# renews a resident entry — but only keys with CMS estimate >=
# resident_min_est are trusted to still BE resident (a cold key's
# staged entry loses every capacity fight; suppressing its re-hints
# converts prefetch hits into demand fetches, DESIGN.md §13).
MODES = {
    "allhints": {"mode": "all"},
    "selective": {"mode": "selective", "resident_ttl": 0.05,
                  "resident_min_est": 4},
    "speculative": {"mode": "selective", "resident_ttl": 0.05,
                    "resident_min_est": 4, "speculative": True,
                    "spec_width": 4},
}
DISTS = ("uniform", "zipf", "shift")

# calibrated full-scale configs (cache below the live key/pane
# population — the regime where wasted stagings evict load-bearing
# state; rates/windows follow BENCH_windowing / BENCH_joins).  The
# per-config "filter" block maps mode -> HintFilter overrides: the
# residency TTL models how long a staged entry survives in cache,
# which scales with cache size, so full-scale q5 (512 entries) carries
# a longer TTL than its smoke config (256); q20's speculative run adds
# the token-bucket budget (hot-key prioritisation under hint-channel
# saturation — its channel carries ~36k hints/s, the most of the
# three queries).
FULL = {
    "q5": dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
               window_size=2.0, window_slide=1.0, cache_entries=512,
               join_horizon=None, allowed_lateness=None, stateful="stateful",
               lookahead="win_lookahead",
               filter={"selective": {"resident_ttl": 0.12},
                       "speculative": {"resident_ttl": 0.12}}),
    "q8": dict(rate=9_000.0, active_window=4.0, oo_bound=0.3,
               window_size=2.0, window_slide=None, cache_entries=384,
               join_horizon=None, allowed_lateness=0.0, stateful="join",
               lookahead="join_lookahead", filter={}),
    "q20": dict(rate=18_000.0, active_window=30.0, oo_bound=0.25,
                window_size=None, window_slide=None, cache_entries=384,
                join_horizon=None, allowed_lateness=0.1, stateful="join",
                lookahead="join_lookahead",
                filter={"speculative": {"budget_per_s": 2_000.0,
                                        "priority_threshold": 8}}),
}
# reduced-scale CI smoke: same rates, smaller windows/horizons with
# proportionally smaller caches (and the default filter tuning)
SMOKE = {
    "q5": dict(FULL["q5"], window_size=1.0, window_slide=0.5,
               cache_entries=256, filter={}),
    "q8": dict(FULL["q8"], active_window=2.0, window_size=1.0,
               cache_entries=192),
    "q20": dict(FULL["q20"], active_window=15.0, cache_entries=224,
                filter={}),
}


def run_one(query: str, dist: str, mode: str, qcfg: dict, duration: float,
            warmup: float, seed: int = 7):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    cfg = NexmarkConfig(rate=qcfg["rate"],
                        active_window=qcfg["active_window"],
                        oo_bound=qcfg["oo_bound"], seed=seed,
                        key_dist=dist)
    filt = dict(MODES[mode])
    filt.update(qcfg.get("filter", {}).get(mode, {}))
    eng = build_query(query, "tac", "prefetch", cfg,
                      cache_entries=qcfg["cache_entries"],
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.0003,
                      window_size=qcfg["window_size"],
                      window_slide=qcfg["window_slide"],
                      allowed_lateness=qcfg["allowed_lateness"],
                      join_horizon=qcfg["join_horizon"],
                      hint_filter=filt, compress_hints=True)
    m = eng.run(duration=duration, warmup=warmup)
    st, la = qcfg["stateful"], qcfg["lookahead"]
    hq = m.get(f"{st}_hint_quality", {})
    filt = m.get(f"{la}_hint_filter", {})
    received = m.get(f"{st}_hints_received", 0)
    # the headline: stagings that moved bytes nothing read, plus hints
    # that only renewed already-resident keys — the channel/staging work
    # selective admission exists to eliminate
    wasted_hints = hq.get("wasted", 0) + hq.get("duplicate", 0)
    emitted = filt.get("emitted", 0) \
        + m.get(f"{la}_burst_hints", 0) \
        + m.get(f"{la}_speculative_hints", 0)
    return {"p50": m["p50"], "p99": m["p99"], "p999": m["p999"],
            "throughput": m["throughput"],
            "hit_rate": m.get(f"{st}_hit_rate", 0.0),
            "hints_emitted": emitted,
            "hints_received": received,
            "speculative_hints": m.get(f"{la}_speculative_hints", 0),
            "burst_hints": m.get(f"{la}_burst_hints", 0),
            "wasted_hints": wasted_hints,
            "wasted_hint_ratio": wasted_hints / max(1, received),
            "precision": hq.get("precision", 0.0),
            "recall": hq.get("recall", 0.0),
            "hint_filter": filt,
            "hint_quality": hq,
            "hint_bytes": m.get("hint_bytes", 0),
            "hint_bytes_raw": m.get("hint_bytes_raw", 0),
            "hint_compression": m.get("hint_compression", 1.0),
            "backend_reads": m.get(f"{st}_backend_reads", 0)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="q5,q8,q20")
    ap.add_argument("--dists", default=",".join(DISTS))
    ap.add_argument("--modes", default=",".join(MODES))
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config (Zipf column only, 3s "
                         "runs) for the bench-smoke gate")
    ap.add_argument("--out", default="BENCH_hints.json")
    args = ap.parse_args()

    cfgs = SMOKE if args.smoke else FULL
    duration, warmup = (3.0, 1.5) if args.smoke else \
        (args.duration, args.warmup)
    dists = ["zipf"] if args.smoke and args.dists == ",".join(DISTS) \
        else args.dists.split(",")

    result = {"config": {"smoke": args.smoke, "duration": duration,
                         "warmup": warmup, "queries": dict(cfgs),
                         "modes": dict(MODES), "dists": dists,
                         "parallelism": 2, "io_workers": 4,
                         "buffer_timeout": 0.0003}}
    for query in args.queries.split(","):
        result[query] = {}
        for dist in dists:
            result[query][dist] = {}
            for mode in args.modes.split(","):
                t0 = time.time()
                r = run_one(query, dist, mode, cfgs[query], duration,
                            warmup)
                r["bench_wall_s"] = time.time() - t0
                result[query][dist][mode] = r
                print(f"[bench/hints] {query} {dist:7s} {mode:11s} "
                      f"p99={r['p99']*1e3:7.2f}ms "
                      f"wasted={r['wasted_hints']:6d} "
                      f"ratio={r['wasted_hint_ratio']:.3f} "
                      f"prec={r['precision']:.2f} "
                      f"recall={r['recall']:.2f} "
                      f"({r['bench_wall_s']:.0f}s)", file=sys.stderr)
            rs = result[query][dist]
            if "allhints" in rs and "selective" in rs:
                rs_all, rs_sel = rs["allhints"], rs["selective"]
                result[query][dist]["headline"] = {
                    "wasted_cut": rs_all["wasted_hints"]
                    / max(1, rs_sel["wasted_hints"]),
                    "p99_ratio": rs_sel["p99"]
                    / max(1e-12, rs_all["p99"]),
                    "precision_gain": rs_sel["precision"]
                    - rs_all["precision"],
                }
                if "speculative" in rs:
                    result[query][dist]["headline"][
                        "speculative_precision"] = \
                        rs["speculative"]["precision"]

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({q: {d: result[q][d].get("headline")
                          for d in dists}
                      for q in args.queries.split(",")}, indent=2))


if __name__ == "__main__":
    main()
