"""Failure/recovery benchmark: cold vs hint-warmed recovery at matched
offered load (DESIGN.md §7).

Runs NEXMark q5 (sliding-window hot items, §10) and event-time q20
(auction⋈bid interval join, §11) with barrier-aligned checkpoints over a
replayable source, injects a whole-job failure mid-run, and compares
three scenarios over the same arrival schedule:

  * ``unfailed`` — checkpoints on, no failure (the baseline the
    recovered run's steady state must return to);
  * ``cold``     — failure + restore of the last completed epoch, replay
    with a COLD cache: every replayed state access pays backend latency,
    the paper's on-demand profile concentrated into the catch-up window;
  * ``warmed``   — same failure, but the logged hint stream for the
    replay horizon (hint WAL + snapshotted HintsBuffer) is re-issued
    through the PrefetchingManager before the data path resumes, staging
    the hot set off the tuple path.

Reported per scenario: the POST-RESTORE p99 spike (latencies sinking
between resume and replay catch-up), steady-state p99 after catch-up,
recovery time (failure → caught up), checkpoint alignment stall, and
restore volume.  Emits ``BENCH_recovery.json``.  Expectation (ISSUE 5):
warmed recovery shows a lower post-restore p99 spike than cold on both
queries, and the recovered run's steady-state p99 stays within 1.2x the
unfailed run (the CI gate, tools/bench_gate.py).  ``--smoke`` runs a
reduced-scale config for the bench-smoke job.

    PYTHONPATH=src python benchmarks/recovery.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# calibrated configs (DESIGN.md §8).  Per query, the gear that makes the
# cold-restore spike OBSERVABLE at p99 (without it the network-flush
# floor or async overlap hides state latency, and cold == warmed):
#
#   * q5  — fire-burst spike: when the watermark resumes, every pane of
#     the backlogged windows is read at once; a cold cache turns that
#     into an I/O-lane convoy.  Normal 4-lane pool, 2 ms flush gear
#     (the windowing-bench config).
#   * q20 — arrival-burst spike: the interval join's misses overlap so
#     well under a deep thread pool that a cold cache never queues; the
#     bench narrows the state thread pool to ONE lane per subtask
#     (steady-state demand stays well under its capacity) and runs the
#     0.3 ms low-latency flush gear, the same floor-lowering move as
#     benchmarks/joins.py.
#
# fail_at is relative to the end of warmup and lands just AFTER an epoch
# completes: the replay horizon stays short, so the spike isolates the
# cold-cache transient rather than raw catch-up queueing.
FULL = {
    "q5": dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
               window_size=1.0, window_slide=0.5, join_horizon=None,
               allowed_lateness=None, cache_entries=256, io_workers=4,
               buffer_timeout=0.002, ckpt_interval=0.8, fail_at=3.1,
               duration=9.0, warmup=1.0),
    "q20": dict(rate=12_000.0, active_window=8.0, oo_bound=0.25,
                window_size=None, window_slide=None, join_horizon=None,
                allowed_lateness=0.1, cache_entries=384, io_workers=1,
                buffer_timeout=0.0003, ckpt_interval=0.8, fail_at=3.1,
                duration=9.0, warmup=1.0),
}
SMOKE = {
    "q5": dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
               window_size=1.0, window_slide=0.5, join_horizon=None,
               allowed_lateness=None, cache_entries=256, io_workers=4,
               buffer_timeout=0.002, ckpt_interval=0.8, fail_at=2.3,
               duration=6.5, warmup=1.0),
    "q20": dict(rate=12_000.0, active_window=8.0, oo_bound=0.25,
                window_size=None, window_slide=None, join_horizon=None,
                allowed_lateness=0.1, cache_entries=384, io_workers=1,
                buffer_timeout=0.0003, ckpt_interval=0.8, fail_at=2.3,
                duration=6.5, warmup=1.0),
}

REPLAY_SPEEDUP = 2.0
SPIKE_WIN = 0.6      # post-restore transient window the spike p99 covers
STEADY_TAIL = 1.5    # steady-state p99 over the run's last seconds —
#                      the SAME wall window in every scenario, so the
#                      recovered steady state is compared against the
#                      unfailed run over matched samples


def _pctl(lat, t, lo, hi):
    sel = lat[(t >= lo) & (t < hi)]
    if len(sel) == 0:
        return None, 0
    return float(np.percentile(sel, 99)), int(len(sel))


def run_one(query: str, scenario: str, qcfg: dict, seed: int = 7):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query
    from repro.streaming.recovery import (CheckpointCoordinator,
                                          inject_failure_at)

    cfg = NexmarkConfig(rate=qcfg["rate"],
                        active_window=qcfg["active_window"],
                        oo_bound=qcfg["oo_bound"], seed=seed)
    eng = build_query(query, "tac", "prefetch", cfg,
                      cache_entries=qcfg["cache_entries"],
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1,
                      io_workers=qcfg["io_workers"],
                      buffer_timeout=qcfg["buffer_timeout"],
                      window_size=qcfg["window_size"],
                      window_slide=qcfg["window_slide"],
                      allowed_lateness=qcfg["allowed_lateness"],
                      join_horizon=qcfg["join_horizon"],
                      replayable=True)
    coord = CheckpointCoordinator(eng, interval=qcfg["ckpt_interval"])
    coord.start()
    t_fail = qcfg["warmup"] + qcfg["fail_at"]
    if scenario != "unfailed":
        inject_failure_at(eng, at=t_fail, mode=scenario,
                          replay_speedup=REPLAY_SPEEDUP)
    m = eng.run(duration=qcfg["duration"], warmup=qcfg["warmup"])

    op = "stateful" if query in ("q5", "q7") else "join"
    lat = np.asarray(eng.latencies)
    t = np.asarray(eng.latency_t)
    t_end = qcfg["warmup"] + qcfg["duration"]
    ck = m.get("checkpoint", {})
    steady_p99, n_steady = _pctl(lat, t, t_end - STEADY_TAIL, float("inf"))
    out = {"p50": m["p50"], "p99": m["p99"], "p999": m["p999"],
           "throughput": m["throughput"],
           "hit_rate": m.get(f"{op}_hit_rate", 0.0),
           "backend_reads": m.get(f"{op}_backend_reads", 0),
           "epochs_completed": ck.get("epochs_completed", 0),
           "align_stall_avg": ck.get("align_stall_avg", 0.0),
           "align_stall_max": ck.get("align_stall_max", 0.0),
           "snapshot_bytes": ck.get("snapshot_bytes_total", 0),
           "steady_p99": steady_p99, "steady_samples": n_steady}
    if scenario == "unfailed":
        return out

    rec = m.get("recovery", {})
    src = eng.operators["source"]
    done = [d for d in src.replay_done_t if d is not None]
    t_resume = rec.get("last_t_resume", t_fail)
    t_caught_up = max(done) if done else t_end
    spike_p99, n_spike = _pctl(lat, t, t_resume, t_resume + SPIKE_WIN)
    out.update({
        "post_restore_p99": spike_p99,
        "post_restore_samples": n_spike,
        "recovery_time": t_caught_up - t_fail,
        "downtime": rec.get("last_downtime"),
        "restore_bytes": rec.get("last_restore_bytes"),
        "warmup_lead": rec.get("last_warmup_lead"),
        "warmup_hints": rec.get("warmup_hints", 0),
        "replayed": rec.get("replayed", 0),
        "restored_epoch": rec.get("last_epoch"),
    })
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="q5,q20")
    ap.add_argument("--scenarios", default="unfailed,cold,warmed")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config for the bench-smoke "
                         "recovery gate")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args()

    cfgs = SMOKE if args.smoke else FULL
    result = {"config": {"smoke": args.smoke, "queries": dict(cfgs),
                         "parallelism": 2,
                         "replay_speedup": REPLAY_SPEEDUP,
                         "spike_window": SPIKE_WIN,
                         "steady_tail": STEADY_TAIL}}
    for query in args.queries.split(","):
        result[query] = {}
        for scenario in args.scenarios.split(","):
            t0 = time.time()
            r = run_one(query, scenario, cfgs[query])
            r["bench_wall_s"] = time.time() - t0
            result[query][scenario] = r
            spike = r.get("post_restore_p99")
            print(f"[bench/recovery] {query} {scenario:9s} "
                  f"p99={r['p99']*1e3:7.2f}ms "
                  + (f"spike_p99={spike*1e3:7.2f}ms "
                     f"steady_p99={(r['steady_p99'] or 0)*1e3:6.2f}ms "
                     f"rec={r['recovery_time']:.2f}s "
                     f"warm_hints={r['warmup_hints']} "
                     if spike is not None else
                     f"(epochs={r['epochs_completed']}) ")
                  + f"({r['bench_wall_s']:.0f}s)", file=sys.stderr)
        rs = result[query]
        if "cold" in rs and "warmed" in rs \
                and rs["cold"].get("post_restore_p99") \
                and rs["warmed"].get("post_restore_p99"):
            headline = {"spike_reduction_vs_cold":
                        rs["cold"]["post_restore_p99"]
                        / max(1e-12, rs["warmed"]["post_restore_p99"])}
            if rs.get("unfailed"):
                headline["warmed_steady_vs_unfailed"] = \
                    (rs["warmed"]["steady_p99"] or 0.0) \
                    / max(1e-12, rs["unfailed"]["steady_p99"]
                          or rs["unfailed"]["p99"])
            result[query]["headline"] = headline
            print(f"[bench/recovery] {query} warmed spike reduction "
                  f"x{headline['spike_reduction_vs_cold']:.2f} vs cold",
                  file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({q: result[q].get("headline")
                      for q in args.queries.split(",")}, indent=2))


if __name__ == "__main__":
    main()
