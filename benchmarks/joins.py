"""Stream-stream join benchmark: two-sided vs one-sided hints vs
on-demand at matched offered load (DESIGN.md §11).

Runs NEXMark q8 (tumbling-window person⋈auction, co-grouped panes fired
on watermark) and q20 (auction⋈bid interval join with retention-deadline
expiry) over the same arrival schedule in three modes:

  * ``ondemand``  — LRU cache, synchronous state access (no hints);
  * ``onesided``  — TAC + Keyed Prefetching with hints from the PROBE
                    side only (auctions for q8, bids for q20): the
                    conventional lookahead, blind to the build side;
  * ``twosided``  — both inputs emit cross-side hints: a build-side
                    tuple pre-stages the state future probes will read
                    (pane-deadline hints for q8, retention-deadline
                    hints for q20), so the key is resident before its
                    FIRST probe arrives and stays protected for as long
                    as a match remains possible.

Cache capacity is calibrated below the live key/pane population, the
regime where on-demand thrashes and hint protection decides which side
of the join survives eviction.

Emits ``BENCH_joins.json``.  Expectation (ISSUE 4): two-sided hints beat
on-demand on p99 end-to-end latency for q8 and q20 at equal load (the
CI gate), and improve on one-sided hints where build-side state matters.
``--smoke`` runs a reduced-scale config for the bench-smoke perf gate
(tools/bench_gate.py).

    PYTHONPATH=src python benchmarks/joins.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODES = {"ondemand": ("lru", "sync", "two"),
         "onesided": ("tac", "prefetch", "one"),
         "twosided": ("tac", "prefetch", "two")}

# calibrated full-scale configs (cache below the live key population,
# data channels in the low-latency flush gear so the floor does not mask
# state-access effects — DESIGN.md §8)
FULL = {
    "q8": dict(rate=9_000.0, active_window=4.0, oo_bound=0.3,
               window_size=2.0, join_horizon=None, cache_entries=384,
               allowed_lateness=0.0),
    "q20": dict(rate=18_000.0, active_window=30.0, oo_bound=0.25,
                window_size=None, join_horizon=None, cache_entries=384,
                allowed_lateness=0.1),
}
# reduced-scale CI smoke: same rates (the cache/population balance must
# survive), smaller windows/horizons with proportionally smaller caches
SMOKE = {
    "q8": dict(rate=9_000.0, active_window=2.0, oo_bound=0.3,
               window_size=1.0, join_horizon=None, cache_entries=192,
               allowed_lateness=0.0),
    "q20": dict(rate=18_000.0, active_window=15.0, oo_bound=0.25,
                window_size=None, join_horizon=None, cache_entries=224,
                allowed_lateness=0.1),
}


def run_one(query: str, mode: str, qcfg: dict, duration: float,
            warmup: float, seed: int = 7):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    policy, access, sides = MODES[mode]
    cfg = NexmarkConfig(rate=qcfg["rate"],
                        active_window=qcfg["active_window"],
                        oo_bound=qcfg["oo_bound"], seed=seed)
    eng = build_query(query, policy, access, cfg,
                      cache_entries=qcfg["cache_entries"],
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.0003,
                      window_size=qcfg["window_size"],
                      allowed_lateness=qcfg["allowed_lateness"],
                      join_hints=sides, join_horizon=qcfg["join_horizon"])
    m = eng.run(duration=duration, warmup=warmup)
    return {"p50": m["p50"], "p99": m["p99"], "p999": m["p999"],
            "throughput": m["throughput"],
            "hit_rate": m.get("join_hit_rate", 0.0),
            "joined": m.get("join_joined", 0),
            "late_dropped": m.get("join_late_dropped", 0),
            "keys_expired": m.get("join_keys_expired", 0),
            "fires": m.get("join_fires", 0),
            "hints_left": m.get("join_lookahead_hints_left", 0),
            "hints_right": m.get("join_lookahead_hints_right", 0),
            "hints_received": m.get("join_hints_received", 0),
            "hints_late": m.get("join_hints_late", 0),
            "prefetch_hits": m.get("join_prefetch_hits", 0),
            "backend_reads": m.get("join_backend_reads", 0),
            # prefetch-quality telemetry (DESIGN.md §12): per-hint
            # outcomes, precision/recall, signed lead-time percentiles
            "hint_quality": m.get("join_hint_quality", {}),
            "evictions": m.get("join_evictions", {})}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="q8,q20")
    ap.add_argument("--modes", default="ondemand,onesided,twosided")
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config (smaller windows/"
                         "horizons, 3s run) for the bench-smoke gate")
    ap.add_argument("--out", default="BENCH_joins.json")
    args = ap.parse_args()

    cfgs = SMOKE if args.smoke else FULL
    duration, warmup = (3.0, 1.5) if args.smoke else \
        (args.duration, args.warmup)

    result = {"config": {"smoke": args.smoke, "duration": duration,
                         "warmup": warmup, "queries": dict(cfgs),
                         "parallelism": 2, "io_workers": 4,
                         "buffer_timeout": 0.0003}}
    for query in args.queries.split(","):
        result[query] = {}
        for mode in args.modes.split(","):
            t0 = time.time()
            r = run_one(query, mode, cfgs[query], duration, warmup)
            r["bench_wall_s"] = time.time() - t0
            result[query][mode] = r
            print(f"[bench/joins] {query} {mode:9s} "
                  f"p50={r['p50']*1e3:6.2f}ms p99={r['p99']*1e3:7.2f}ms "
                  f"hit={r['hit_rate']:.2f} joined={r['joined']} "
                  f"hints=L{r['hints_left']}/R{r['hints_right']} "
                  f"({r['bench_wall_s']:.0f}s)", file=sys.stderr)
        rs = result[query]
        if "twosided" in rs:
            headline = {}
            for base in ("ondemand", "onesided"):
                if base in rs:
                    headline[f"p99_speedup_vs_{base}"] = \
                        rs[base]["p99"] / max(1e-12, rs["twosided"]["p99"])
            result[query]["headline"] = headline
            print(f"[bench/joins] {query} twosided p99 speedup: "
                  + ", ".join(f"{k.split('_vs_')[1]} x{v:.2f}"
                              for k, v in headline.items()),
                  file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({q: result[q].get("headline")
                      for q in args.queries.split(",")}, indent=2))


if __name__ == "__main__":
    main()
