"""Roofline table from the dry-run artifacts (deliverable g / §Roofline)."""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List


def load_records(path: str = "results/dryrun") -> List[Dict[str, Any]]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def roofline_rows(rows: List[str], path: str = "results/dryrun",
                  mesh: str = "single") -> List[Dict[str, Any]]:
    recs = [r for r in load_records(path)
            if r.get("mesh") == mesh and not r.get("note")]
    out = []
    for r in recs:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("skipped"):
            rows.append(f"{name},0,skipped=subquadratic-only")
            continue
        if not r.get("ok"):
            rows.append(f"{name},0,FAILED")
            continue
        ro = r["roofline"]
        dom = ro["dominant"].replace("_s", "")
        step_s = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        rows.append(
            f"{name},{step_s * 1e6:.0f},"
            f"compute_s={ro['compute_s']:.4f};memory_s={ro['memory_s']:.4f}"
            f";collective_s={ro['collective_s']:.4f};dominant={dom}"
            f";useful_flops_ratio={ro['useful_flops_ratio']:.3f}"
            f";roofline_fraction={ro['roofline_fraction']:.4f}"
            f";fits16g_args={r['memory']['fits_16g_args']}")
        out.append(r)
    return out


def markdown_table(path: str = "results/dryrun", mesh: str = "single") -> str:
    recs = [r for r in load_records(path)
            if r.get("mesh") == mesh and not r.get("note")]
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | MODEL/HLO flops | roofline frac | args GiB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"])):
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped (sub-quadratic only) | — | — | — | — |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED |")
            continue
        ro, mem = r["roofline"], r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3f} | "
            f"{ro['memory_s']:.3f} | {ro['collective_s']:.3f} | "
            f"{ro['dominant'].replace('_s','')} | "
            f"{ro['useful_flops_ratio']:.3f} | "
            f"{ro['roofline_fraction']:.4f} | "
            f"{mem['argument_size_in_bytes']/2**30:.2f} | "
            f"{'Y' if mem['fits_16g_args'] else 'N'} |")
    return "\n".join(lines)
