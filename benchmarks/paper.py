"""Paper-figure benchmarks (Fig 6-11, Tables I-II) on the event engine.

Methodology follows §VI: rates are set so the sync-caching baselines run
near their sustainable limit (their stateful operators ~60-75% busy incl.
I/O wait, Table I), measurements start after warmup, and the state exceeds
the cache.  All runs are deterministic (seeded discrete-event clock).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Tuple

from repro.streaming.nexmark import NexmarkConfig, build_query
from repro.streaming.synthetic import SyntheticConfig, build_synthetic
from repro.streaming.ysb import YSBConfig, build_ysb

APPROACHES: List[Tuple[str, str, str]] = [
    ("Cache-LRU", "lru", "sync"),
    ("Cache-Clock", "clock", "sync"),
    ("AsyncIO", "lru", "async"),
    ("KeyedPrefetching", "tac", "prefetch"),
]

# calibrated operating points (sync baseline near its sustainable limit)
WORKLOADS: Dict[str, Dict[str, Any]] = {
    "q13": dict(rate=22_000, cache_entries=512, parallelism=2,
                source_parallelism=1, io_workers=2),
    "q18": dict(rate=40_000, cache_entries=768, parallelism=2,
                source_parallelism=1, io_workers=3, active_window=30.0),
    "q19": dict(rate=22_000, cache_entries=384, parallelism=2,
                source_parallelism=1, io_workers=4),
    "q20": dict(rate=24_000, cache_entries=384, parallelism=2,
                source_parallelism=1, io_workers=2),
    "ysb": dict(rate=26_000, cache_entries=8192, parallelism=1,
                source_parallelism=1, io_workers=24),
}

DUR, WARM = 6.0, 3.0

# run.py --fused sets this: workloads with a FusedSpec (ysb here; q5/q7
# when passed as overrides) run their stateful hot path on the device
# (DESIGN.md §14); the rest keep the interpreted inner loop
FUSED = False
_FUSED_QUERIES = ("q5", "q7")


def _build(workload: str, policy: str, mode: str, **over):
    cfgd = dict(WORKLOADS[workload])
    cfgd.update(over)
    if workload == "ysb":
        ycfg = YSBConfig(rate=cfgd.pop("rate"))
        if FUSED:
            cfgd.setdefault("fused", True)
        return build_ysb(policy, mode, ycfg, **cfgd)
    ncfg = NexmarkConfig(rate=cfgd.pop("rate"),
                         active_window=cfgd.pop("active_window", 60.0),
                         hot_auction_prob=cfgd.pop("hot_auction_prob", 0.5))
    if FUSED and workload in _FUSED_QUERIES:
        cfgd.setdefault("fused", True)
    return build_query(workload, policy, mode, ncfg, **cfgd)


def run_one(workload: str, policy: str, mode: str, dur=DUR, warm=WARM,
            **over) -> Dict[str, Any]:
    eng = _build(workload, policy, mode, **over)
    m = eng.run(duration=dur, warmup=warm)
    m["lookahead_timeline"] = eng.lookahead_timeline
    return m


# ------------------------------------------------------------------- figures
def fig6(rows: List[str]) -> Dict[str, Dict[str, Any]]:
    """End-to-end percentile latency, every workload x approach."""
    out = {}
    for wl in WORKLOADS:
        for label, policy, mode in APPROACHES:
            m = run_one(wl, policy, mode)
            key = f"fig6_{wl}_{label}"
            out[key] = m
            rows.append(f"{key},{m['p999'] * 1e6:.0f},"
                        f"p50_ms={m['p50']*1e3:.2f};p99_ms={m['p99']*1e3:.2f}"
                        f";p999_ms={m['p999']*1e3:.2f}"
                        f";hit={m.get('stateful_hit_rate', 0):.3f}"
                        f";thr={m['throughput']:.0f}")
    return out


def fig7(rows: List[str]) -> None:
    """Q13 p99/p999 as the hot-auction percentage varies 25..100%."""
    for hot in (0.25, 0.5, 0.75, 1.0):
        for label, policy, mode in APPROACHES:
            m = run_one("q13", policy, mode, dur=4.0,
                        hot_auction_prob=hot)
            rows.append(f"fig7_q13_hot{int(hot*100)}_{label},"
                        f"{m['p999'] * 1e6:.0f},"
                        f"p99_ms={m['p99']*1e3:.2f}"
                        f";p999_ms={m['p999']*1e3:.2f}")


def fig8(rows: List[str]) -> None:
    """p999 with varying cache sizes (q13 and q20)."""
    for wl in ("q13", "q20"):
        for entries in (256, 512, 2048):
            for label, policy, mode in APPROACHES:
                m = run_one(wl, policy, mode, dur=4.0,
                            cache_entries=entries)
                rows.append(f"fig8_{wl}_c{entries}_{label},"
                            f"{m['p999'] * 1e6:.0f},"
                            f"p999_ms={m['p999']*1e3:.2f}"
                            f";hit={m.get('stateful_hit_rate', 0):.3f}")


def fig9(rows: List[str]) -> None:
    """Impact of the CMS threshold T on latency (q13, prefetching)."""
    for T in (5, 20, 80, None):          # None => no filter (hint everything)
        conf = {"threshold": T} if T is not None else {"threshold": 10 ** 9}
        label = f"T{T}" if T is not None else "nofilter"
        m = run_one("q13", "tac", "prefetch", dur=4.0, cms_conf=conf)
        rows.append(f"fig9_q13_{label},{m['p999'] * 1e6:.0f},"
                    f"p50_ms={m['p50']*1e3:.2f};p999_ms={m['p999']*1e3:.2f}"
                    f";hint_bytes={m['hint_bytes']}")


def fig10(rows: List[str]) -> Dict[str, Any]:
    """Dynamic lookahead adaptation timeline (synthetic query)."""
    cfg = SyntheticConfig(t_mismatch=8.0, t_latency_drop=16.0)
    eng = build_synthetic(cfg)
    m = eng.run(duration=24.0, warmup=2.0)
    tl = ";".join(f"{t:.1f}s->{op}" for t, op in eng.lookahead_timeline)
    sw = ";".join(f"{t:.1f}s:{why}->{to}"
                  for t, _, why, to in eng.controller.switch_log)
    rows.append(f"fig10_adaptation,{m['p999'] * 1e6:.0f},"
                f"timeline={tl};hit={m['stateful_hit_rate']:.3f}")
    return {"timeline": eng.lookahead_timeline,
            "switch_log": eng.controller.switch_log, "metrics": m}


def fig11(rows: List[str]) -> None:
    """Max sustainable throughput: highest offered rate with bounded queues
    and >97% delivery."""
    for wl in WORKLOADS:
        base = WORKLOADS[wl]["rate"]
        for label, policy, mode in APPROACHES:
            best = 0.0
            for mult in (0.8, 1.0, 1.25, 1.5):
                rate = base * mult
                m = run_one(wl, policy, mode, dur=3.0, warm=2.0, rate=rate)
                queued = m.get("stateful_queued", 0)
                # sustainable: queues bounded & outputs keep up
                expected = m["throughput"]
                if queued < 2000 and m["throughput"] > 0:
                    best = max(best, m["throughput"])
                else:
                    break
            rows.append(f"fig11_{wl}_{label},{best:.0f},"
                        f"max_sustainable_eps={best:.0f}")


def tab1(rows: List[str], fig6_out: Dict[str, Dict[str, Any]]) -> None:
    """CPU utilisation of the stateful operator (busy incl. I/O wait)."""
    for key, m in fig6_out.items():
        wl_label = key.replace("fig6_", "")
        rows.append(f"tab1_{wl_label},{m.get('util_stateful', 0) * 1e6:.0f},"
                    f"stateful_busy_frac={m.get('util_stateful', 0):.3f}")


def tab2(rows: List[str], fig6_out: Dict[str, Dict[str, Any]]) -> None:
    """Network overhead of hints vs data bytes."""
    for key, m in fig6_out.items():
        if "KeyedPrefetching" not in key:
            continue
        wl = key.replace("fig6_", "").replace("_KeyedPrefetching", "")
        rows.append(f"tab2_{wl},{m['net_overhead'] * 1e6:.0f},"
                    f"hint_overhead_pct={m['net_overhead'] * 100:.2f}")


def validate_claims(rows: List[str],
                    fig6_out: Dict[str, Dict[str, Any]]) -> None:
    """Paper claims: p999 reduced 1.34-11x vs best baseline; p50 <= async
    + 3ms; throughput >= baselines."""
    for wl in WORKLOADS:
        kp = fig6_out[f"fig6_{wl}_KeyedPrefetching"]
        base_p999 = min(fig6_out[f"fig6_{wl}_{b}"]["p999"]
                        for b, _, _ in APPROACHES[:3])
        worst_p999 = max(fig6_out[f"fig6_{wl}_{b}"]["p999"]
                         for b, _, _ in APPROACHES[:3])
        speedup_min = base_p999 / kp["p999"]
        speedup_max = worst_p999 / kp["p999"]
        async_p50 = fig6_out[f"fig6_{wl}_AsyncIO"]["p50"]
        p50_ok = kp["p50"] <= async_p50 + 3e-3
        thr_ok = kp["throughput"] >= 0.99 * max(
            fig6_out[f"fig6_{wl}_{b}"]["throughput"]
            for b, _, _ in APPROACHES[:3])
        rows.append(
            f"claims_{wl},{speedup_min * 1e6:.0f},"
            f"p999_speedup_vs_best={speedup_min:.2f}"
            f";vs_worst={speedup_max:.2f};p50_within_3ms_of_async={p50_ok}"
            f";throughput_not_worse={thr_ok}")
