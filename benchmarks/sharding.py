"""Sharded state plane benchmark: NEXMark q3/q4 across 1/2/4/8 shards
with a mid-run key-range rebalance (DESIGN.md §9).

Weak scaling: the offered rate grows linearly with the shard-owner count
(per-shard rate calibrated so the on-demand sync baseline runs near its
per-owner sustainable limit), the stateful operator runs `N` subtasks
owning `4N` hash shards, and halfway through the measured window two of
subtask 0's shards migrate to the last subtask — drain, bulk transfer,
re-admit with preserved timestamps, replay.  Data channels run Flink's
low-latency gear (2 ms buffer timeout) so the network floor does not mask
state-access latency.

Emits ``BENCH_sharding.json``: per query x shard count x mode, overall and
migration-window latency percentiles plus the per-shard routing counters.
Expectation (ISSUE 2): prefetch keeps a p99 advantage over on-demand at
4+ shards, including across the migration window.

    PYTHONPATH=src python benchmarks/sharding.py --shards 1,2,4,8
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODES = {"sync": ("lru", "sync"), "async": ("lru", "async"),
         "prefetch": ("tac", "prefetch")}

# per-shard offered rates (events/s); q3's stateful traffic is only the
# person+auction 8%, so its per-shard rate is higher for equal pressure
RATES = {"q3": 24_000.0, "q4": 13_000.0}
CACHE_ENTRIES = {"q3": 512, "q4": 384}
# q3 reads person profiles from a remote-KV tier (DISAGGREGATED) and runs
# the tightest buffer timeout: its stateful traffic is sparse (8%), so the
# state-access latency has to be visible above the network-flush floor
BACKENDS = {"q3": "disagg", "q4": "nvme"}
BUFFER_TIMEOUTS = {"q3": 0.0003, "q4": 0.002}
MIGRATION_WINDOW = 0.4          # seconds after the rebalance event


def run_one(query: str, n_owners: int, mode: str, duration: float,
            warmup: float, rate_per_shard: float, seed: int = 7):
    from repro.streaming.backend import DISAGGREGATED, LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    policy, access = MODES[mode]
    n_shards = 4 * n_owners
    cfg = NexmarkConfig(rate=rate_per_shard * n_owners,
                        active_window=30.0, seed=seed)
    eng = build_query(query, policy, access, cfg,
                      cache_entries=CACHE_ENTRIES[query],
                      backend=DISAGGREGATED if BACKENDS[query] == "disagg"
                      else LOCAL_NVME,
                      parallelism=n_owners,
                      source_parallelism=max(1, n_owners // 2),
                      io_workers=3, n_shards=n_shards,
                      buffer_timeout=BUFFER_TIMEOUTS[query])
    t_mig = warmup + duration / 2
    migrated = []
    if n_owners > 1:
        # rebalance: two of subtask 0's shards move to the last subtask
        for shard in (0, n_owners):         # both owned by sub 0 (s % N)
            eng.migrate_shard("stateful", shard, n_owners - 1, at=t_mig)
            migrated.append(shard)
    m = eng.run(duration=duration, warmup=warmup)

    lat = np.asarray(eng.latencies)
    lat_t = np.asarray(eng.latency_t)
    out = {"p50": m["p50"], "p99": m["p99"], "p999": m["p999"],
           "throughput": m["throughput"],
           "hit_rate": m.get("stateful_hit_rate", 0.0),
           "util_stateful": m.get("util_stateful", 0.0),
           "prefetch_hits": m.get("stateful_prefetch_hits", 0),
           "backend_reads": m.get("stateful_backend_reads", 0),
           "shard_plane": m.get("stateful_shard_plane"),
           "migrated_shards": migrated}
    if migrated and len(lat):
        win = (lat_t >= t_mig) & (lat_t <= t_mig + MIGRATION_WINDOW)
        post = lat_t > t_mig + MIGRATION_WINDOW
        out["migration_window_p99"] = float(
            np.percentile(lat[win], 99)) if win.any() else None
        out["post_migration_p99"] = float(
            np.percentile(lat[post], 99)) if post.any() else None
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="q3,q4")
    ap.add_argument("--shards", default="1,2,4,8")
    ap.add_argument("--modes", default="sync,prefetch")
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--warmup", type=float, default=1.0)
    ap.add_argument("--out", default="BENCH_sharding.json")
    args = ap.parse_args()

    shard_counts = [int(s) for s in args.shards.split(",")]
    result = {"config": {"shards": shard_counts,
                         "rates_per_shard": RATES,
                         "cache_entries": CACHE_ENTRIES,
                         "duration": args.duration, "warmup": args.warmup,
                         "backends": BACKENDS,
                         "buffer_timeouts": BUFFER_TIMEOUTS,
                         "n_bins_per_owner": 4,
                         "migration_window": MIGRATION_WINDOW}}
    for query in args.queries.split(","):
        result[query] = {}
        for n in shard_counts:
            result[query][str(n)] = {}
            for mode in args.modes.split(","):
                t0 = time.time()
                r = run_one(query, n, mode, args.duration, args.warmup,
                            RATES[query])
                r["bench_wall_s"] = time.time() - t0
                result[query][str(n)][mode] = r
                mig_ms = (r.get("migration_window_p99") or 0) * 1e3
                print(f"[bench/sharding] {query} shards={n:<2d} {mode:8s} "
                      f"p50={r['p50']*1e3:6.2f}ms p99={r['p99']*1e3:7.2f}ms"
                      f" hit={r['hit_rate']:.2f}"
                      f" mig_p99={mig_ms:7.2f}ms"
                      f" ({r['bench_wall_s']:.0f}s)",
                      file=sys.stderr)
        # headline: prefetch p99 advantage per shard count
        adv = {}
        for n in shard_counts:
            rs = result[query][str(n)]
            if "sync" in rs and "prefetch" in rs:
                adv[str(n)] = rs["sync"]["p99"] / max(1e-12,
                                                      rs["prefetch"]["p99"])
        result[query]["p99_speedup_by_shards"] = adv
        print(f"[bench/sharding] {query} prefetch p99 speedup by shards: "
              + ", ".join(f"{k}x{v:.2f}" for k, v in adv.items()),
              file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({q: result[q].get("p99_speedup_by_shards")
                      for q in args.queries.split(",")}, indent=2))


if __name__ == "__main__":
    main()
