"""Event-time windowing benchmark: deadline-ts vs arrival-ts hints vs
on-demand at matched offered load (DESIGN.md §10).

Runs NEXMark q5 (hot items, SLIDING window, late-side updates) and q7
(highest bid, TUMBLING window, late drops) over the same arrival schedule
in three modes:

  * ``ondemand``  — LRU cache, synchronous state access (no hints);
  * ``arrival``   — TAC + Keyed Prefetching with per-tuple ARRIVAL-ts
                    hints (accurate key, mistimed for fire-time reads);
  * ``deadline``  — TAC + hints carrying the WINDOW-FIRE DEADLINE, with
                    fire-time burst prefetch and deadline-aware eviction.

Cache capacity is calibrated between one window's pane count and the
live-pane total, the regime where ordering matters: arrival-ts ordering
evicts panes of the window awaiting fire, so its fire burst stalls on
backend refetches; deadline ordering keeps the next-to-fire window
resident and the burst re-stages the rest off the tuple path.

Emits ``BENCH_windowing.json``.  Expectation (ISSUE 3): deadline-ts beats
BOTH baselines on p99 end-to-end latency for q5 and q7 at equal load.
``--smoke`` runs a reduced-scale config for the CI perf gate
(tools/bench_gate.py).

    PYTHONPATH=src python benchmarks/windowing.py
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MODES = {"ondemand": ("lru", "sync", "deadline"),
         "arrival": ("tac", "prefetch", "arrival"),
         "deadline": ("tac", "prefetch", "deadline")}

# calibrated full-scale configs (see module docstring on the cache regime)
FULL = {
    "q5": dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
               window_size=2.0, window_slide=1.0, cache_entries=512),
    "q7": dict(rate=8_000.0, active_window=2.0, oo_bound=0.4,
               window_size=2.0, window_slide=None, cache_entries=576),
}
# reduced-scale CI smoke: same rates (the cache/pane-count balance must
# survive), half-size windows with proportionally smaller caches
SMOKE = {
    "q5": dict(rate=5_000.0, active_window=1.0, oo_bound=0.3,
               window_size=1.0, window_slide=0.5, cache_entries=256),
    "q7": dict(rate=8_000.0, active_window=2.0, oo_bound=0.4,
               window_size=1.0, window_slide=None, cache_entries=288),
}


def run_one(query: str, mode: str, qcfg: dict, duration: float,
            warmup: float, seed: int = 7):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query

    policy, access, hint_ts = MODES[mode]
    cfg = NexmarkConfig(rate=qcfg["rate"], active_window=qcfg["active_window"],
                        oo_bound=qcfg["oo_bound"], seed=seed)
    eng = build_query(query, policy, access, cfg,
                      cache_entries=qcfg["cache_entries"],
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, hint_ts=hint_ts,
                      window_size=qcfg["window_size"],
                      window_slide=qcfg["window_slide"])
    m = eng.run(duration=duration, warmup=warmup)
    return {"p50": m["p50"], "p99": m["p99"], "p999": m["p999"],
            "throughput": m["throughput"],
            "hit_rate": m.get("stateful_hit_rate", 0.0),
            "fires": m.get("stateful_fires", 0),
            "late_dropped": m.get("stateful_late_dropped", 0),
            "late_updates": m.get("stateful_late_updates", 0),
            "panes_purged": m.get("stateful_panes_purged", 0),
            "burst_hints": m.get("win_lookahead_burst_hints", 0),
            "hints_received": m.get("stateful_hints_received", 0),
            "hints_late": m.get("stateful_hints_late", 0),
            "prefetch_hits": m.get("stateful_prefetch_hits", 0),
            "backend_reads": m.get("stateful_backend_reads", 0),
            # prefetch-quality telemetry (DESIGN.md §12): per-hint
            # outcomes, precision/recall, signed lead-time percentiles
            "hint_quality": m.get("stateful_hint_quality", {}),
            "evictions": m.get("stateful_evictions", {})}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="q5,q7")
    ap.add_argument("--modes", default="ondemand,arrival,deadline")
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--warmup", type=float, default=2.0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale CI config (half-size windows, "
                         "3s run) for the bench-smoke perf gate")
    ap.add_argument("--out", default="BENCH_windowing.json")
    args = ap.parse_args()

    cfgs = SMOKE if args.smoke else FULL
    duration, warmup = (3.0, 1.5) if args.smoke else \
        (args.duration, args.warmup)

    result = {"config": {"smoke": args.smoke, "duration": duration,
                         "warmup": warmup, "queries": dict(cfgs),
                         "parallelism": 2, "io_workers": 4,
                         "buffer_timeout": 0.002}}
    for query in args.queries.split(","):
        result[query] = {}
        for mode in args.modes.split(","):
            t0 = time.time()
            r = run_one(query, mode, cfgs[query], duration, warmup)
            r["bench_wall_s"] = time.time() - t0
            result[query][mode] = r
            print(f"[bench/windowing] {query} {mode:9s} "
                  f"p50={r['p50']*1e3:6.2f}ms p99={r['p99']*1e3:7.2f}ms "
                  f"hit={r['hit_rate']:.2f} fires={r['fires']} "
                  f"late={r['late_dropped']}+{r['late_updates']} "
                  f"({r['bench_wall_s']:.0f}s)", file=sys.stderr)
        rs = result[query]
        if "deadline" in rs:
            headline = {}
            for base in ("ondemand", "arrival"):
                if base in rs:
                    headline[f"p99_speedup_vs_{base}"] = \
                        rs[base]["p99"] / max(1e-12, rs["deadline"]["p99"])
            result[query]["headline"] = headline
            print(f"[bench/windowing] {query} deadline p99 speedup: "
                  + ", ".join(f"{k.split('_vs_')[1]} x{v:.2f}"
                              for k, v in headline.items()),
                  file=sys.stderr)

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    print(json.dumps({q: result[q].get("headline")
                      for q in args.queries.split(",")}, indent=2))


if __name__ == "__main__":
    main()
