"""Device-side integration of the paper's technique: the Timestamp-Aware
Cache (tac_jax) manages PHYSICAL page slots, and its probe results form the
page table that the paged decode-attention Pallas kernel dereferences.

This is the TPU-serving analogue of cache -> key-value store indirection:
prefetched KV pages are admitted with hint timestamps, the probe yields slot
ids, and attention over the scattered physical pages must equal dense
attention over the logical sequence.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tac_jax
from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.kernels.tac_probe.ops import bucket_of, tac_probe


def _page_key(seq: int, page: int) -> int:
    return seq * 1024 + page + 1


def test_tac_managed_paged_attention_matches_dense():
    rng = np.random.RandomState(0)
    B, H, d = 2, 4, 32
    page, pages_per_seq = 16, 3
    n_buckets, ways = 8, 4
    n_slots = n_buckets * ways

    # physical page pool + device TAC managing which logical page sits where
    k_pages = jnp.zeros((n_slots, page, d), jnp.float32)
    v_pages = jnp.zeros((n_slots, page, d), jnp.float32)
    state = tac_jax.init(n_buckets, ways, 1)      # values unused; slots only

    logical_k = rng.randn(B, pages_per_seq * page, d).astype(np.float32)
    logical_v = rng.randn(B, pages_per_seq * page, d).astype(np.float32)

    # admit every logical page with its hint timestamp (prefetch)
    for b in range(B):
        for p in range(pages_per_seq):
            key = _page_key(b, p)
            state = tac_jax.admit(state, jnp.asarray([key], jnp.int32),
                                  jnp.asarray([float(100 + p)]),
                                  jnp.zeros((1, 1)))
            # find the slot the TAC chose and stage the page there
            _, hit, way = tac_probe(jnp.asarray([key], jnp.int32),
                                    state.keys, state.vals)
            assert bool(hit[0])
            bucket = int(np.asarray(bucket_of(
                jnp.asarray([key], jnp.int32), n_buckets))[0])
            slot = bucket * ways + int(np.asarray(way)[0])
            k_pages = k_pages.at[slot].set(
                logical_k[b, p * page:(p + 1) * page])
            v_pages = v_pages.at[slot].set(
                logical_v[b, p * page:(p + 1) * page])

    # build the page table from TAC probes (the serving hot path)
    table = np.zeros((B, pages_per_seq), np.int32)
    for b in range(B):
        keys = jnp.asarray([_page_key(b, p) for p in range(pages_per_seq)],
                           jnp.int32)
        _, hit, ways_found = tac_probe(keys, state.keys, state.vals)
        assert bool(np.asarray(hit).all()), "prefetched pages must be resident"
        buckets = np.asarray(bucket_of(keys, n_buckets))
        table[b] = buckets * ways + np.asarray(ways_found)

    q = jnp.asarray(rng.randn(B, H, d).astype(np.float32))
    seq_lens = jnp.asarray([pages_per_seq * page, 2 * page + 5])

    out = paged_decode_attention(q, k_pages, v_pages, jnp.asarray(table),
                                 seq_lens)

    # dense reference over the logical layout
    import math
    s = np.einsum("bhd,btd->bht", np.asarray(q), logical_k) / math.sqrt(d)
    for b in range(B):
        s[b, :, int(seq_lens[b]):] = -1e30
    p_ = np.exp(s - s.max(-1, keepdims=True))
    p_ = p_ / p_.sum(-1, keepdims=True)
    ref = np.einsum("bht,btd->bhd", p_, logical_v)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=2e-5)


def test_tac_eviction_frees_slots_for_new_pages():
    """When the cache is full, admitting a new page must evict the oldest-
    timestamp page and reuse its slot (the paper's eviction rule on device)."""
    state = tac_jax.init(1, 2, 1)                 # one bucket, two slots
    state = tac_jax.admit(state, jnp.asarray([_page_key(0, 0)], jnp.int32),
                          jnp.asarray([10.0]), jnp.zeros((1, 1)))
    state = tac_jax.admit(state, jnp.asarray([_page_key(0, 1)], jnp.int32),
                          jnp.asarray([50.0]), jnp.zeros((1, 1)))
    # renew page 0 with a future hint: page 1 becomes the eviction victim
    state = tac_jax.renew(state, jnp.asarray([_page_key(0, 0)], jnp.int32),
                          jnp.asarray([99.0]))
    state = tac_jax.admit(state, jnp.asarray([_page_key(0, 2)], jnp.int32),
                          jnp.asarray([60.0]), jnp.zeros((1, 1)))
    keys = jnp.asarray([_page_key(0, 0), _page_key(0, 1), _page_key(0, 2)],
                       jnp.int32)
    _, hit, _ = tac_jax.lookup(state, keys, jnp.zeros(3))
    assert list(np.asarray(hit)) == [True, False, True]
