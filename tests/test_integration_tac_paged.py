"""Device-side integration of the paper's technique: the arena's TAC page
table assigns PHYSICAL page slots, and the paged decode-attention Pallas
kernel dereferences them.

This is the TPU-serving analogue of cache -> key-value store indirection:
prefetched KV pages are admitted with hint timestamps through the BATCHED
arena APIs (one fused admit + one scatter for all pages — no per-page
Python staging loop), the probe yields the page table, and attention over
the scattered physical pages must equal dense attention over the logical
sequence.
"""
import math

import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ops import paged_decode_attention
from repro.serving import PagedStateArena


def _page_key(seq: int, page: int) -> int:
    return seq * 1024 + page + 1


def test_arena_managed_paged_attention_matches_dense():
    rng = np.random.RandomState(0)
    B, H, d = 2, 4, 32
    page, pages_per_seq = 16, 3
    arena = PagedStateArena(n_buckets=8, ways=4,
                            pools={"k": ((page, d), jnp.float32),
                                   "v": ((page, d), jnp.float32)})

    logical_k = rng.randn(B, pages_per_seq * page, d).astype(np.float32)
    logical_v = rng.randn(B, pages_per_seq * page, d).astype(np.float32)

    # admit EVERY logical page in one batched call (hint timestamps), then
    # stage all page contents with one scatter per pool — the serving path
    keys = np.asarray([[_page_key(b, p) for p in range(pages_per_seq)]
                       for b in range(B)], np.int32)
    ts = np.asarray([[100.0 + p for p in range(pages_per_seq)]
                     for b in range(B)], np.float32)
    adm = arena.admit(keys.reshape(-1), ts.reshape(-1))
    arena.stage(adm.slots,
                {"k": jnp.asarray(logical_k.reshape(-1, page, d)),
                 "v": jnp.asarray(logical_v.reshape(-1, page, d))})

    # build the page table from one batched probe (the serving hot path)
    hit, table = arena.page_table(jnp.asarray(keys))
    assert hit.all(), "prefetched pages must be resident"

    q = jnp.asarray(rng.randn(B, H, d).astype(np.float32))
    seq_lens = jnp.asarray([pages_per_seq * page, 2 * page + 5])

    out = paged_decode_attention(q, arena.pools["k"], arena.pools["v"],
                                 table, seq_lens)

    # dense reference over the logical layout
    s = np.einsum("bhd,btd->bht", np.asarray(q), logical_k) / math.sqrt(d)
    for b in range(B):
        s[b, :, int(seq_lens[b]):] = -1e30
    p_ = np.exp(s - s.max(-1, keepdims=True))
    p_ = p_ / p_.sum(-1, keepdims=True)
    ref = np.einsum("bht,btd->bhd", p_, logical_v)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=2e-5)


def test_arena_eviction_frees_slots_for_new_pages():
    """When the cache is full, admitting a new page must evict the oldest-
    timestamp page and reuse its slot (the paper's eviction rule on device),
    and a renewed page must be protected."""
    arena = PagedStateArena(n_buckets=1, ways=2,
                            pools={"k": ((4, 2), jnp.float32)})
    adm = arena.admit(np.asarray([_page_key(0, 0), _page_key(0, 1)],
                                 np.int32),
                      np.asarray([10.0, 50.0], np.float32))
    assert (adm.evicted_keys == -1).all()
    # renew page 0 with a future hint: page 1 becomes the eviction victim
    arena.renew(np.asarray([_page_key(0, 0)], np.int32),
                np.asarray([99.0], np.float32))
    adm2 = arena.admit(np.asarray([_page_key(0, 2)], np.int32),
                       np.asarray([60.0], np.float32))
    assert list(adm2.evicted_keys) == [_page_key(0, 1)]
    hit, _ = arena.probe(np.asarray(
        [_page_key(0, 0), _page_key(0, 1), _page_key(0, 2)], np.int32))
    assert list(hit) == [True, False, True]
