"""Import hypothesis if available; otherwise stub the decorators so only
the property tests skip and the plain unit tests in the module still run
(the dev extra is optional: ``pip install .[dev]``)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (pip install .[dev])")(fn)
