"""Unit + property tests for the paper's core data structures."""
import random

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cms import CountMinFilter
from repro.core.hints import HintsBuffer
from repro.core.policies import ClockCache, LRUCache
from repro.core.prefetch import (LookaheadCandidate, PrefetchingController,
                                 PrefetchingManager)
from repro.core.tac import TimestampAwareCache


# ----------------------------------------------------------------------- TAC
def test_tac_orders_by_timestamp():
    tac = TimestampAwareCache(capacity=3)
    tac.insert("a", 1, ts=10.0)
    tac.insert("b", 2, ts=20.0)
    tac.insert("c", 3, ts=30.0)
    tac.insert("d", 4, ts=25.0)          # evicts "a" (smallest ts)
    assert not tac.contains("a")
    assert tac.contains("b") and tac.contains("c") and tac.contains("d")


def test_tac_prefetched_entries_protected_by_future_ts():
    tac = TimestampAwareCache(capacity=2)
    tac.insert("old", 1, ts=5.0)
    tac.insert("pf", 2, ts=100.0, prefetched=True)   # hint in the future
    tac.insert("new", 3, ts=10.0)        # evicts "old", NOT the prefetched
    assert tac.contains("pf")
    assert not tac.contains("old")


def test_tac_renew_extends_life():
    tac = TimestampAwareCache(capacity=2)
    tac.insert("a", 1, ts=1.0)
    tac.insert("b", 2, ts=2.0)
    assert tac.renew("a", hint_ts=50.0)  # expected to be used again soon
    tac.insert("c", 3, ts=3.0)           # should evict b (ts=2), not a
    assert tac.contains("a") and tac.contains("c")
    assert not tac.contains("b")


def test_tac_eviction_buffer_writeback_and_rescue():
    tac = TimestampAwareCache(capacity=2)
    tac.insert("a", {"v": 1}, ts=1.0)
    tac.write("a", {"v": 2}, now_ts=1.5)             # dirty
    tac.insert("b", 2, ts=2.0)
    tac.insert("c", 3, ts=3.0)           # evicts dirty "a" -> eviction buffer
    assert "a" in tac.evict_buffer
    # a read rescues the staged entry instead of hitting the backend
    assert tac.lookup("a", now_ts=4.0) == {"v": 2}
    assert "a" not in tac.evict_buffer
    # pop_writeback drains dirty entries for the state thread pool; the
    # rescued "a" is still dirty (never persisted), so both must drain
    tac.write("b", 22, now_ts=5.0)
    tac.insert("d", 4, ts=6.0)
    tac.insert("e", 5, ts=7.0)
    drained = {}
    while True:
        wb = tac.pop_writeback()
        if wb is None:
            break
        drained[wb.key] = wb.state
    assert drained == {"a": {"v": 2}, "b": 22}


def test_tac_flush_dirty_for_checkpoint():
    tac = TimestampAwareCache(capacity=4)
    tac.write("a", 1, now_ts=1.0)
    tac.write("b", 2, now_ts=2.0)
    flushed = {e.key for e in tac.flush_dirty()}
    assert flushed == {"a", "b"}
    assert not any(e.dirty for e in tac.entries.values())


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30), st.floats(0, 100),
                          st.booleans()), min_size=1, max_size=200))
def test_tac_capacity_invariant(ops):
    """Property: used <= capacity always; eviction order respects min-ts."""
    tac = TimestampAwareCache(capacity=8)
    for key, ts, dirty in ops:
        if dirty:
            tac.write(key, ts, now_ts=ts)
        else:
            tac.insert(key, ts, ts=ts)
        assert tac.used <= 8
        assert len(tac.entries) <= 8
        if tac.entries:
            # heap top (after lazy cleanup) is the true min timestamp
            min_ts = min(e.ts for e in tac.entries.values())
            assert min_ts >= 0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 20), st.floats(0, 1000)),
                min_size=5, max_size=300))
def test_tac_eviction_order_matches_sorted_timestamps(trace):
    """Property: a full eviction drain pops entries in timestamp order —
    the DLL-ordering equivalence of the lazy-heap implementation."""
    tac = TimestampAwareCache(capacity=1000)
    for key, ts in trace:
        tac.insert(key, None, ts=ts)
    order = []
    while tac.entries:
        tac._make_room(tac.capacity)     # force evictions
        tac.capacity = max(0, len(tac.entries) - 1)
        before = dict(tac.entries)
        tac._evict_one()
        gone = set(before) - set(tac.entries)
        if gone:
            order.append(before[gone.pop()].ts)
    assert order == sorted(order)


# ----------------------------------------------------------------------- CMS
def test_cms_detects_hot_keys():
    cms = CountMinFilter(depth=4, width=1000, threshold=20,
                         aging_interval=10_000)
    for _ in range(50):
        cms.update_and_classify(42)
    assert cms.is_hot(42)
    assert not cms.is_hot(7)


def test_cms_aging_decays_counts():
    cms = CountMinFilter(depth=4, width=1000, threshold=20,
                         aging_interval=100)
    for _ in range(60):
        cms.update_and_classify(42)
    est0 = cms.estimate(42)
    for i in range(400):                 # 4 aging passes of other keys
        cms.update_and_classify(1000 + i % 50)
    assert cms.estimate(42) < est0


def test_cms_saturating_counters():
    cms = CountMinFilter(depth=2, width=100, bits=8, threshold=20,
                         aging_interval=10 ** 9)
    for _ in range(5000):
        cms.update_and_classify(1)
    assert cms.estimate(1) <= 255


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=50, max_size=500))
def test_cms_never_underestimates(keys):
    """CMS property: estimate >= true count (before any aging)."""
    cms = CountMinFilter(depth=4, width=512, bits=8, threshold=10 ** 9,
                         aging_interval=10 ** 9)
    true = {}
    for k in keys:
        cms.update_and_classify(k)
        true[k] = true.get(k, 0) + 1
    for k, c in true.items():
        assert cms.estimate(k) >= min(c, 255)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=50, max_size=500))
def test_cms_update_estimate_never_underestimates(keys):
    """``update()`` property: the returned running estimate is >= the
    true count so far (saturating at the counter max)."""
    cms = CountMinFilter(depth=4, width=512, bits=8, threshold=10 ** 9,
                         aging_interval=10 ** 9)
    true = {}
    for k in keys:
        true[k] = true.get(k, 0) + 1
        est, _hot = cms.update(k)
        assert est >= min(true[k], 255)
        assert est == cms.estimate(k)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=20, max_size=300))
def test_cms_update_matches_legacy_classify(keys):
    """``update()`` and ``update_and_classify()`` fed the same stream
    agree on every hot verdict and leave identical counter state
    (including aging), so the HintFilter's estimate path cannot drift
    from the legacy hot/cold path."""
    a = CountMinFilter(depth=3, width=256, bits=8, threshold=5,
                       aging_interval=64)
    b = CountMinFilter(depth=3, width=256, bits=8, threshold=5,
                       aging_interval=64)
    for k in keys:
        est, hot = a.update(k)
        assert hot == b.update_and_classify(k)
        assert hot == (est >= a.threshold)
    assert (a.counters == b.counters).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 32), st.integers(1, 60))
def test_cms_classify_monotone_across_threshold(key, n):
    """With aging off, repeated updates of a single key cross the hot
    threshold exactly once and never fall back (verdict sequence is
    monotone False* True*)."""
    cms = CountMinFilter(depth=4, width=128, threshold=20,
                         aging_interval=10 ** 9)
    verdicts = [cms.update_and_classify(key) for _ in range(n)]
    assert verdicts == sorted(verdicts)
    if n >= cms.threshold:
        assert all(verdicts[cms.threshold - 1:])
        assert not any(verdicts[:cms.threshold - 1])


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=1, max_size=200))
def test_cms_reset_forgets_everything(keys):
    """``reset()`` zeroes every estimate and hot verdict, and the
    cached flat view still aliases the counters afterwards (the next
    update is visible)."""
    cms = CountMinFilter(depth=4, width=256, threshold=3,
                         aging_interval=10 ** 9)
    for k in keys:
        cms.update(k)
    cms.reset()
    for k in keys:
        assert cms.estimate(k) == 0
        assert not cms.is_hot(k)
    est, _ = cms.update(keys[0])
    assert est == 1 == cms.estimate(keys[0])


# --------------------------------------------------------------------- hints
def test_hints_buffer_dedup_and_ts_merge():
    hb = HintsBuffer()
    hb.add("k", 10.0)
    hb.add("k", 15.0)
    assert len(hb) == 1
    key, ts = hb.next_fetch()
    assert key == "k" and ts == 15.0
    hb.add("k", 20.0)                    # while in flight: merge into flight
    assert hb.complete("k") == 20.0
    assert len(hb) == 0


def test_hints_take_specific_key():
    hb = HintsBuffer()
    hb.add("a", 1.0)
    hb.add("b", 2.0)
    assert hb.take("b") == 2.0
    assert hb.pending("b") and "b" in hb.in_flight
    assert hb.complete("b") == 2.0


def test_hints_buffer_dropped_counter_at_max_size():
    hb = HintsBuffer(max_size=2)
    hb.add("a", 1.0)
    hb.add("b", 2.0)
    hb.add("c", 3.0)                     # over capacity: dropped
    assert hb.dropped == 1 and len(hb) == 2 and not hb.pending("c")
    # merges into existing keys are NOT drops, even at capacity
    hb.add("a", 9.0)
    assert hb.dropped == 1 and hb.unprocessed["a"] == 9.0
    # in-flight keys free their unprocessed slot
    hb.take("a")
    hb.add("d", 4.0)
    assert hb.dropped == 1 and hb.pending("d")


def test_hints_buffer_inflight_max_ts_merge_on_readd():
    hb = HintsBuffer()
    hb.add("k", 5.0)
    assert hb.take("k") == 5.0
    hb.add("k", 3.0)                     # older re-add: ts keeps the max
    assert hb.in_flight["k"] == 5.0 and "k" not in hb.unprocessed
    hb.add("k", 9.0)                     # newer re-add: merges upward
    assert hb.in_flight["k"] == 9.0 and "k" not in hb.unprocessed
    assert hb.complete("k") == 9.0
    assert len(hb) == 0


# ---------------------------------------------------- controller adaptation
def _mk_ctl():
    ctl = PrefetchingController()
    ctl.register("op", [LookaheadCandidate("a", 0),
                        LookaheadCandidate("b", 1),
                        LookaheadCandidate("c", 2)])
    return ctl


def test_controller_activation_and_mismatch_discard():
    ctl = _mk_ctl()
    assert ctl.activate("op") == "a"
    # mismatch on a: discard a (and upstream), move to b
    assert ctl.report_mismatch("op", "a", now=1.0) == "b"
    assert [c.op_id for c in ctl.candidates["op"]] == ["b", "c"]
    # mismatch on b: only c remains
    assert ctl.report_mismatch("op", "b", now=2.0) == "c"


def test_manager_timing_selects_latest_with_slack():
    ctl = _mk_ctl()
    ctl.activate("op")
    mgr = PrefetchingManager("op", 0, ctl, gamma=0.001, min_dwell=0.0)
    mgr.enabled = True

    class FakeCache:
        pf_ins_by_origin = {}
        pf_unused_by_origin = {}

    # slack: a=50ms, b=20ms, c=2ms; access latency p99 = 5ms
    for _ in range(10):
        mgr.slack.setdefault("a", []).append(0.050)
        mgr.slack.setdefault("b", []).append(0.020)
        mgr.slack.setdefault("c", []).append(0.002)
        mgr.record_access_latency(0.005)
    # latest candidate with slack >= 5ms + 1ms is b
    assert mgr.evaluate(FakeCache(), now=1.0) == "b"
    # access latency drops to 0.5ms -> c (2ms >= 1.5ms) becomes viable
    mgr.access_lat = [0.0005] * 10
    assert mgr.evaluate(FakeCache(), now=2.0) == "c"


def test_manager_mismatch_via_cache_counters():
    ctl = _mk_ctl()
    ctl.activate("op")
    mgr = PrefetchingManager("op", 0, ctl, gamma=0.001)
    mgr.enabled = True

    class FakeCache:
        pf_ins_by_origin = {"a": 100}
        pf_unused_by_origin = {"a": 40}  # 40% fetched-but-never-used

    assert mgr.evaluate(FakeCache(), now=1.0) == "b"


def test_manager_drops_late_hints():
    ctl = _mk_ctl()
    mgr = PrefetchingManager("op", 0, ctl)

    class FakeCache:
        def contains(self, k):
            return False

    # watermark 100, lateness 5: hint at ts=90 is late -> dropped
    assert not mgr.on_hint("k", 90.0, FakeCache(), watermark=100.0,
                           lateness=5.0)
    assert mgr.on_hint("k2", 99.0, FakeCache(), watermark=100.0,
                       lateness=5.0)


# ----------------------------------------------------------- baseline caches
@pytest.mark.parametrize("cls", [LRUCache, ClockCache])
def test_baseline_cache_basics(cls):
    c = cls(capacity=2)
    c.insert("a", 1)
    c.insert("b", 2)
    assert c.lookup("a") == 1
    c.insert("c", 3)
    assert len(c) == 2
    assert c.lookup("c") == 3


def test_lru_evicts_least_recent():
    c = LRUCache(capacity=2)
    c.insert("a", 1)
    c.insert("b", 2)
    c.lookup("a")
    c.insert("c", 3)                     # evicts b
    assert c.lookup("b") is None
    assert c.lookup("a") == 1


# ------------------------------------------- snapshot/restore roundtrips (§7)
def _drive(cache, ops):
    """Apply a random op trace: insert / write(dirty) / lookup."""
    for kind, key, ts in ops:
        if kind == 0:
            cache.insert(key, {"k": key}, ts, size=1)
        elif kind == 1:
            cache.write(key, {"k": key, "w": ts}, ts, size=1)
        else:
            cache.lookup(key, ts)


def _entry_view(cache, with_ts):
    out = {}
    for e in list(cache.entries.values()) + list(cache.evict_buffer.values()):
        out[e.key] = (e.dirty, e.ts if with_ts else None)
    return out


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 15),
                          st.floats(0, 100)), min_size=1, max_size=120))
def test_tac_export_import_roundtrip(ops):
    """Property (§7 snapshot <-> restore, §9 migration): draining a TAC
    through export_entries and re-importing reproduces keys, states,
    DIRTY bits, and TIMESTAMPS — hence the identical eviction order."""
    a = TimestampAwareCache(capacity=64)
    _drive(a, ops)
    before = _entry_view(a, with_ts=True)
    exported = a.export_entries(lambda k: True)
    assert not a.entries and not a.evict_buffer
    b = TimestampAwareCache(capacity=64)
    b.import_entries(exported)
    assert _entry_view(b, with_ts=True) == before
    # eviction ORDER is reproduced: drain both a fresh copy and b
    c = TimestampAwareCache(capacity=64)
    c.import_entries([type(e)(e.key, e.state, e.ts, e.dirty, e.size)
                      for e in exported])
    order = []
    while b.entries:
        keys_before = set(b.entries)
        b._evict_one()
        order.append((keys_before - set(b.entries)).pop())
    order_c = []
    while c.entries:
        keys_before = set(c.entries)
        c._evict_one()
        order_c.append((keys_before - set(c.entries)).pop())
    assert order == order_c


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 15),
                          st.floats(0, 100)), min_size=1, max_size=120),
       st.floats(0, 100))
def test_tac_deadline_aware_roundtrip_keeps_order(ops, clock):
    """Property: the deadline-aware eviction order (stale-oldest first,
    then farthest deadline — DESIGN.md §10) survives a §7 roundtrip, as
    ordering is a pure function of the preserved timestamps + clock."""
    a = TimestampAwareCache(capacity=64, deadline_aware=True)
    a.set_clock(clock)
    _drive(a, ops)
    exported = a.export_entries(lambda k: True)
    b = TimestampAwareCache(capacity=64, deadline_aware=True)
    b.set_clock(clock)
    b.import_entries(exported)
    c = TimestampAwareCache(capacity=64, deadline_aware=True)
    c.set_clock(clock)
    c.import_entries([type(e)(e.key, e.state, e.ts, e.dirty, e.size)
                      for e in exported])
    order_b, order_c = [], []
    for cache, order in ((b, order_b), (c, order_c)):
        while cache.entries:
            keys_before = set(cache.entries)
            cache._evict_one()
            order.append((keys_before - set(cache.entries)).pop())
    assert order_b == order_c


@pytest.mark.parametrize("cls", [LRUCache, ClockCache])
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 15),
                          st.floats(0, 100)), min_size=1, max_size=120))
def test_baseline_export_import_roundtrip(cls, ops):
    """Property: LRU/Clock roundtrips preserve contents + dirty bits and
    (for LRU) the recency order — export drains oldest-first and import
    re-inserts positionally (DESIGN.md §7, §9)."""
    a = cls(capacity=64)
    _drive(a, ops)
    before = _entry_view(a, with_ts=False)
    lru_order = list(a.entries) if cls is LRUCache else None
    exported = a.export_entries(lambda k: True)
    b = cls(capacity=64)
    b.import_entries(exported)
    assert _entry_view(b, with_ts=False) == before
    if lru_order is not None:
        resident = [k for k in lru_order if k in b.entries]
        assert [k for k in b.entries] == resident
