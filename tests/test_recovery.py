"""Fault-tolerance plane tests (DESIGN.md §7): barrier alignment,
epoch-numbered snapshots, failure injection at adversarial points, and
prefetch-warmed recovery.

Quick by design (sub-second to few-second discrete-event runs): tier-1.
"""
from collections import defaultdict

import pytest

from repro.streaming.backend import IN_MEMORY, LOCAL_NVME, StateBackend
from repro.streaming.engine import (Engine, MapOp, SinkOp, SourceOp,
                                    StatefulOp)
from repro.streaming.events import Tuple_, WindowKey
from repro.streaming.nexmark import NexmarkConfig, build_query
from repro.streaming.recovery import (CheckpointCoordinator, SnapshotStore,
                                      inject_failure_at)
from repro.streaming.windows import WindowAssigner, WindowedStatefulOp


def _noop_gen(now):
    return (int(now * 1000) % 7, {"v": 1}, 100)


def _q5_engine(rate=3_000, seed=7, late_prob=0.0, oo_bound=0.15,
               interval=0.4, **kw):
    cfg = NexmarkConfig(rate=rate, active_window=1.0, oo_bound=oo_bound,
                        seed=seed, late_prob=late_prob)
    eng = build_query("q5", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, window_size=0.5,
                      window_slide=0.5, replayable=True, **kw)
    coord = CheckpointCoordinator(eng, interval=interval)
    coord.start()
    return eng, coord


def _capture_sink(eng):
    got = defaultdict(set)
    sink = eng.operators["sink"]

    def capture(sub, tup):
        got[(tup.ts, tup.key)].add(tup.payload[2])
        return 1e-6

    sink.process = capture
    return got


# ------------------------------------------------------------- alignment
def test_barrier_aligns_across_inputs_and_meters_stall():
    """A two-input operator must not snapshot until BOTH inputs
    delivered the epoch's barrier; post-barrier traffic from the early
    input is buffered behind the aligned cut and the stall is metered."""
    from repro.streaming.engine import _AlignedBarrier
    from repro.streaming.events import CheckpointBarrier
    eng = Engine()
    m = eng.add(MapOp(eng, "m", 1))
    m.barrier_expected = 2
    pre_b = Tuple_(0.0, "preB", None, 100)
    post_a = Tuple_(0.0, "postA", None, 100)
    # input A delivers its barrier first: alignment opens
    out = m._align_filter(0, [CheckpointBarrier(1)], ("chA", 0))
    assert out == [] and m._align[0]["arrived"] == {("chA", 0)}
    # post-barrier traffic from A buffers; pre-barrier from B flows
    assert m._align_filter(0, [post_a], ("chA", 0)) == []
    assert m._align_filter(0, [pre_b], ("chB", 0)) == [pre_b]
    eng.sim.t = 0.003
    out = m._align_filter(0, [CheckpointBarrier(1)], ("chB", 0))
    # last input reported: sentinel first, then the buffered traffic
    assert isinstance(out[0], _AlignedBarrier)
    assert out[0].epoch == 1 and out[0].buffered == 1
    assert out[0].stall == pytest.approx(0.003)
    assert out[1] is post_a
    assert m._align[0] is None


def test_barrier_end_to_end_snapshots_every_subtask():
    eng = Engine()
    a = eng.add(SourceOp(eng, "a", 1, 3000.0, _noop_gen))
    b = eng.add(SourceOp(eng, "b", 1, 2000.0, _noop_gen))
    m = eng.add(MapOp(eng, "m", 2))
    sink = eng.add(SinkOp(eng, "sink", 1))
    eng.connect(a, m)
    eng.connect(b, m)
    eng.connect(m, sink)
    assert m.barrier_expected == 2 and sink.barrier_expected == 2
    eng.sim.after(0.5, eng.trigger_checkpoint, 1)
    eng.run(duration=1.0)
    # every (operator, subtask) reached the aligned cut exactly once
    assert eng.snapshots_taken == m.parallelism + sink.parallelism


def test_coordinator_completes_epochs_and_trims_logs():
    eng, coord = _q5_engine()
    eng.run(duration=2.0)
    assert coord.epochs_completed >= 3
    assert coord.store.last_epoch == coord.epochs_completed
    # completed-epoch offsets trimmed the durable log
    src = eng.operators["source"]
    assert src.log_base[0] > 0
    # metrics surface the checkpoint block alongside the per-shard ones
    m = eng.metrics(2.0, 0.0)
    assert m["checkpoint"]["epochs_completed"] == coord.epochs_completed
    assert m["checkpoint"]["align_stall_avg"] >= 0.0


def test_backend_snapshot_delta_is_incremental_with_tombstones():
    b = StateBackend(IN_MEMORY)
    b.track_deltas = True                 # coordinator attach does this
    b.write("a", {"n": 1})
    b.write("b", {"n": 2})
    delta, deleted = b.snapshot_delta()
    assert set(delta) == {"a", "b"} and not deleted
    # mutating the live dict must not mutate the exported copy
    live = b.data["a"]
    live["n"] = 99
    assert delta["a"]["n"] == 1
    b.delete("b")
    b.write("c", {"n": 3})
    delta2, deleted2 = b.snapshot_delta()
    # incremental: "a" was not re-written since the last cut, so only
    # "c" rides the second delta; "b" leaves a tombstone
    assert set(delta2) == {"c"}
    assert deleted2 == {"b"}


# ------------------------------------------------- exactly-once recovery
@pytest.mark.parametrize("mode", ["warmed", "cold"])
def test_failure_recovery_preserves_windowed_counts(mode):
    """ISSUE 5 acceptance: a run with an injected mid-stream failure
    produces the same q5 tumbling counts as an unfailed run (exactly-once
    STATE effects; emit-path duplicates are the recorded deviation and
    are deduped by (window, key) here)."""
    def run(fail):
        eng, coord = _q5_engine()
        got = _capture_sink(eng)
        if fail:
            inject_failure_at(eng, at=1.5, mode=mode)
        eng.run(duration=4.4 if fail else 3.9)
        return got

    base, failed = run(False), run(True)
    horizon = 2.2     # window ends covered by both runs' logical streams
    compared = 0
    for (end, key), counts in base.items():
        if end > horizon:
            continue
        compared += 1
        assert failed.get((end, key)) == counts, (end, key)
    for (end, key) in failed:
        assert end > horizon or (end, key) in base, (end, key)
    assert compared > 300


def test_warmed_recovery_reissues_hints_and_prefetches():
    eng, coord = _q5_engine()
    inject_failure_at(eng, at=1.5, mode="warmed")
    m = eng.run(duration=3.0)
    rec = m["recovery"]
    assert rec["failures"] == 1
    assert rec["warmup_hints"] > 0
    assert rec["replayed"] > 0
    assert rec["last_mode"] == "warmed"
    assert rec["last_restore_bytes"] > 0
    # the source caught back up to live generation
    src = eng.operators["source"]
    assert all(d is not None for d in src.replay_done_t)


def test_cold_recovery_issues_no_warmup_hints():
    eng, coord = _q5_engine()
    inject_failure_at(eng, at=1.5, mode="cold")
    m = eng.run(duration=3.0)
    assert m["recovery"]["warmup_hints"] == 0
    assert m["recovery"]["replayed"] > 0


# ------------------------------------------------- adversarial failures
def test_failure_between_alignment_and_persist_rolls_back_epoch():
    """An epoch whose snapshots all acked but whose store write has not
    completed must NOT be restorable: the failure rolls it back and
    recovery restores the previous epoch."""
    eng, coord = _q5_engine(interval=0.5)

    fired = {}

    def fail_mid_persist(epoch):
        # called when the last ack lands, BEFORE the store write delay
        if epoch == 2 and "t" not in fired:
            fired["t"] = eng.sim.t
            coord.fail(mode="cold")

    orig = coord.on_operator_snapshot

    def spy(epoch, op, sub, payload, stall, buffered):
        orig(epoch, op, sub, payload, stall, buffered)
        if coord.pending is not None and epoch == 2 \
                and set(coord.pending["acks"]) >= coord.pending["expected"]:
            fail_mid_persist(epoch)

    coord.on_operator_snapshot = spy
    eng.run(duration=2.5)
    assert "t" in fired, "epoch 2 never fully acked"
    assert coord.rolled_back == 1
    # restored from epoch 1, not the rolled-back epoch 2
    assert coord.recoveries[0]["epoch"] == 1
    # and the job keeps checkpointing afterwards
    assert coord.epochs_completed >= 2


def test_migration_and_epoch_serialize():
    """A migration requested while an epoch is in flight is deferred to
    epoch completion; a trigger landing mid-migration is deferred too —
    no epoch cut ever straddles an ownership flip (§9 ∩ §7)."""
    cfg = NexmarkConfig(rate=3000, active_window=1.0, oo_bound=0.2, seed=7)
    eng = build_query("q7", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, window_size=0.5, n_shards=8,
                      replayable=True)
    coord = CheckpointCoordinator(eng, interval=0.3)
    coord.start()
    st = eng.operators["stateful"]

    # force "epoch in flight" and request a migration: it must queue
    coord.pending = {"epoch": 99, "t0": 0.0, "offsets": {}, "acks": {},
                     "expected": {("x", 0)}, "bytes": 0}
    eng.migrate_shard("stateful", 0, 1)
    assert coord._queued_migrations == [("stateful", 0, 1)]
    assert not st.shards.migrating
    coord.pending = None

    # force "migration in flight" and trigger: it must defer
    st.shards.migrating[3] = 1
    before = coord.deferred_triggers
    coord.trigger()
    assert coord.deferred_triggers == before + 1
    assert coord.pending is None
    st.shards.migrating.clear()

    # end-to-end: barrier racing a real mid-run migration still converges
    eng.migrate_shard("stateful", 0, 1, at=0.45)
    m = eng.run(duration=1.6, warmup=0.4)
    assert st.shards.migrations == 1
    assert coord.epochs_completed >= 2
    assert m["stateful_fires"] > 0


def test_late_tuples_straddle_restore_with_lateness_preserved():
    """§10 allowed-lateness semantics across recovery: late tuples in the
    replayed/post-restore stream still take the drop/update paths against
    the RESTORED window registry, and restored fired windows do not
    refire."""
    # lateness horizon (0.1) tighter than the late tail (up to 2x the
    # 0.2 oo bound): some late tuples update, others drop
    eng, coord = _q5_engine(late_prob=0.05, oo_bound=0.2,
                            allowed_lateness=0.1)
    inject_failure_at(eng, at=1.5, mode="warmed")
    m = eng.run(duration=4.0)
    st = eng.operators["stateful"]
    assert m["recovery"]["failures"] == 1
    assert st.late_updates > 0            # q5 late-side updates still flow
    assert st.late_dropped > 0            # beyond-horizon drops still flow
    assert st.fires > 0


def test_restored_fired_registry_blocks_refire_and_keeps_update_path():
    """Unit: a window registry snapshot taken after a fire, restored into
    a fresh incarnation, must (a) not refire the fired key on the next
    watermark, (b) route a late tuple for it through the late-update
    path."""
    eng = Engine()
    win = WindowedStatefulOp(
        eng, "w", 1, WindowAssigner(1.0),
        lambda t, a: (a or 0) + 1, lambda k, wid, end, acc: ("c", k, acc),
        IN_MEMORY, 10_000, policy="tac", mode="sync", state_size=100,
        allowed_lateness=0.5, late_policy="update")
    win.windows[0][0] = {"keys": {7}, "fired": True, "fired_keys": {7}}
    extra = win.snapshot_extra(0)
    win.reset_volatile()
    assert win.windows[0] == {}
    win.restore_extra(0, extra)
    assert win.windows[0][0]["fired_keys"] == {7}
    batches = []
    win.deliver_batch = lambda sub, batch, origin=None: \
        batches.append(batch)
    win.on_watermark(0, 1.2)
    assert batches == []                  # no refire of the restored key
    outs = []
    win.emit = lambda sub, msg: outs.append(msg)
    win._apply(0, Tuple_(0.9, WindowKey(7, 0), {"k": 7}, 100, 0.9), 1)
    assert win.late_updates == 1 and len(outs) == 1


def test_interval_join_registry_rides_snapshot():
    """q20 path: retention deadlines and purge marks restore with the
    epoch, so expiry resumes and dead keys stay dead (§11 ∩ §7)."""
    from repro.streaming.joins import IntervalJoinOp
    eng = Engine()
    j = IntervalJoinOp(eng, "j", 1, lambda p: p["s"],
                       lambda k, l, r: (l, r), (0.0, 5.0), IN_MEMORY,
                       10_000, policy="tac", mode="sync", state_size=100)
    j.retention[0] = {"k1": 7.5, "k2": 3.0}
    j._purged[0] = {"dead"}
    extra = j.snapshot_extra(0)
    j.reset_volatile()
    assert j.retention[0] == {} and j._purged[0] == set()
    j.restore_extra(0, extra)
    assert j.retention[0] == {"k1": 7.5, "k2": 3.0}
    assert j._purged[0] == {"dead"}


def test_q20_interval_join_failure_recovery_end_to_end():
    cfg = NexmarkConfig(rate=5_000, active_window=6.0, oo_bound=0.25,
                        seed=7)
    eng = build_query("q20", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=2,
                      buffer_timeout=0.0005, allowed_lateness=0.1,
                      replayable=True)
    coord = CheckpointCoordinator(eng, interval=0.4)
    coord.start()
    inject_failure_at(eng, at=1.6, mode="warmed")
    m = eng.run(duration=3.2)
    assert m["recovery"]["failures"] == 1
    assert m["recovery"]["warmup_hints"] > 0
    assert m["join_joined"] > 0
    assert m["n_outputs"] > 0


# ------------------------------------------------------- engine plumbing
def test_channel_never_reorders_across_batch_sizes():
    """A small batch flushed just after a large one must not overtake it
    (the per-message delay term would otherwise reorder): barriers and
    watermarks rely on per-(src,dst) FIFO."""
    from repro.streaming.engine import Channel

    class _Dst:
        parallelism = 1

        def __init__(self):
            self.seen = []

        def deliver_batch(self, sub, batch, origin=None):
            self.seen.extend(batch)

    eng = Engine()
    dst = _Dst()
    ch = Channel(eng.sim, dst, "data", lambda k, n: 0, 1)
    big = [Tuple_(0.0, i, None, 200) for i in range(60)]
    for t in big:                          # > 8 KiB: size-flush
        ch.send(0, t)
    ch.send(0, Tuple_(0.0, "tail", None, 10))
    ch._flush(0, 0)                        # tiny batch right behind
    eng.sim.run_until(1.0)
    assert [m.key for m in dst.seen][:60] == [t.key for t in big]
    assert dst.seen[-1].key == "tail"


def test_inflight_writeback_readable_until_landed():
    """Memtable semantics: a dirty entry popped for async write-back must
    stay readable — a fetch racing the write-back otherwise reads the
    backend's stale copy and loses the in-flight updates."""
    eng = Engine()
    outs = []

    def apply_fn(tup, state):
        s = dict(state)
        s["n"] += 1
        return s, []

    st = eng.add(StatefulOp(eng, "s", 1, apply_fn, LOCAL_NVME,
                            cache_capacity=100, policy="lru", mode="async",
                            io_workers=1, state_size=100,
                            default_state=lambda k: {"n": 0}))
    # key A dirty in cache with 5 applied updates; backend still stale
    st.caches[0].write("A", {"n": 5}, 1.0, size=100)
    st.backends[0].write("A", {"n": 0}, 100)
    # capacity 100 = one entry: inserting B evicts A to the write-back
    # path; _io_kick pops it into the in-flight memtable
    st.caches[0].insert("B", {"n": 0}, 1.0, size=100)
    st._io_kick(0)
    assert "A" in st.wb_pending[0]
    # a tuple for A arriving NOW must see n=5, not the backend's n=0
    st._on_data(0, Tuple_(2.0, "A", {}, 100, 2.0))
    eng.sim.run_until(0.1)
    assert st.caches[0].lookup("A", 3.0)["n"] == 6


def test_snapshot_store_persists_to_disk_via_async_writer(tmp_path):
    store = SnapshotStore(directory=str(tmp_path))
    store.persist(1, {"t0": 0.0, "offsets": {}, "bytes": 10,
                      "ops": {("s", 0): {"delta": {"k": 1},
                                         "deleted": set()}}})
    store.persist(2, {"t0": 0.5, "offsets": {}, "bytes": 10,
                      "ops": {("s", 0): {"delta": {"k": 2},
                                         "deleted": set()}}})
    store.wait()
    assert store.materialized[("s", 0)] == {"k": 2}
    names = sorted(p.name for p in tmp_path.iterdir())
    assert names == ["epoch_00000001", "epoch_00000002"]
    import pickle
    with open(tmp_path / "epoch_00000002" / "record.pkl", "rb") as f:
        rec = pickle.load(f)
    assert rec["epoch"] == 2


def test_trigger_defers_through_post_migration_quiesce():
    """A trigger landing in the forwarding tail right after a migration
    LANDS must defer: stale-partitioned tuples forwarded around the flip
    bypass alignment, so the cut waits for the tail to drain."""
    cfg = NexmarkConfig(rate=2000, active_window=1.0, oo_bound=0.2, seed=7)
    eng = build_query("q7", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, window_size=0.5, n_shards=8,
                      replayable=True)
    coord = CheckpointCoordinator(eng, interval=10.0)   # manual triggers
    st = eng.operators["stateful"]
    st.shards.last_finish_t = 5.0
    eng.sim.t = 5.0001                   # just after the landing
    before = coord.deferred_triggers
    coord.trigger()
    assert coord.deferred_triggers == before + 1 and coord.pending is None
    eng.sim.t = 5.0 + 0.002 + 1.0        # tail drained
    coord.trigger()
    assert coord.pending is not None


def test_inflight_writeback_rides_migration():
    """Cross-subtask face of the memtable race: a dirty entry whose
    write-back is in flight at migration time left the eviction buffer,
    so the drain must carry its LATEST state to the destination."""
    from repro.streaming.shards import ShardPlane

    def apply_fn(tup, state):
        return state, []

    eng = Engine()
    plane = ShardPlane(4, 2)
    st = eng.add(StatefulOp(eng, "s", 2, apply_fn, LOCAL_NVME,
                            cache_capacity=100, policy="tac", mode="async",
                            io_workers=1, state_size=100,
                            default_state=lambda k: {"n": 0},
                            shards=plane))
    key = next(k for k in range(100) if plane.shard_of(k) == 0)
    src = plane.owner[0]
    st.backends[src].write(key, {"n": 0}, 100)       # stale durable copy
    st.caches[src].write(key, {"n": 7}, 1.0, size=100)
    # evict the dirty entry and pop it into the in-flight write lane
    st.caches[src].insert("filler", {}, 1.0, size=100)
    st._io_kick(src)
    assert key in st.wb_pending[src]
    assert not st.caches[src].contains(key)
    st.migrate_shard(0, 1 - src)
    eng.sim.run_until(0.1)               # transfer + write-back land
    # the destination cache got n=7, not the stale backend n=0
    assert st.caches[1 - src].lookup(key, 2.0)["n"] == 7
    # and the in-flight write landed at the destination's partition
    assert st.backends[1 - src].data[key]["n"] == 7


def test_delta_tracking_off_without_coordinator():
    """Runs that never checkpoint must not accumulate delta/tombstone
    sets (unbounded growth over purged panes); coordinator attach flips
    tracking on for every backend."""
    b = StateBackend(IN_MEMORY)
    b.write("a", {"n": 1})
    b.delete("a")
    assert not b._epoch_dirty and not b._epoch_deleted
    eng, coord = _q5_engine()
    st = eng.operators["stateful"]
    assert all(bk.track_deltas for bk in st.backends)


def test_overlapping_trigger_epochs_do_not_wedge_alignment():
    """Two back-to-back trigger_checkpoint calls (no coordinator, which
    would serialize them): a later epoch's barrier arriving while an
    earlier alignment is open buffers and re-opens cleanly — every
    subtask snapshots once per epoch and traffic keeps flowing."""
    eng = Engine()
    a = eng.add(SourceOp(eng, "a", 1, 3000.0, _noop_gen))
    b = eng.add(SourceOp(eng, "b", 1, 2000.0, _noop_gen))
    m = eng.add(MapOp(eng, "m", 2))
    sink = eng.add(SinkOp(eng, "sink", 1))
    eng.connect(a, m)
    eng.connect(b, m)
    eng.connect(m, sink)
    eng.sim.after(0.5, eng.trigger_checkpoint, 1)
    eng.sim.after(0.5001, eng.trigger_checkpoint, 2)   # overlaps epoch 1
    res = eng.run(duration=1.5)
    # both epochs reached every (operator, subtask) — nothing wedged
    assert eng.snapshots_taken == 2 * (m.parallelism + sink.parallelism)
    assert all(al is None for al in m._align)
    # and the pipeline kept producing after the overlap
    assert res["n_outputs"] > 0
    late = [t for t in eng.latency_t if t > 0.6]
    assert late, "no sink output after the overlapping epochs"
