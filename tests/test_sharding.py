"""Tests for the sharded keyed-state plane (DESIGN.md §9).

Covers the ISSUE 2 satellite checklist: ``hash_partition`` edge cases,
hint routing on the shard plane (rekeyed tuples, empty batches, a hint
arriving at a shard mid-migration), the serving ``ShardRouter``'s
key-range migration (timestamps, dirty bits, and page contents preserved),
the ``tac_jax`` migration export/import primitives, and the per-shard
counters surfaced by ``Engine.metrics``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tac_jax
from repro.serving import PagedStateArena, ShardRouter, TieredStore
from repro.streaming.backend import IN_MEMORY, LOCAL_NVME, StateBackend
from repro.streaming.engine import (Engine, SinkOp, StatefulOp,
                                    hash_partition)
from repro.streaming.events import Hint, Tuple_
from repro.streaming.shards import ShardPlane


# --------------------------------------------------------- hash_partition
def test_hash_partition_edge_cases():
    assert hash_partition(None, 7) == 0          # keyless control traffic
    assert hash_partition(0, 1) == 0             # single shard swallows all
    for key in (0, 1, 41, -3, (7, 11), "session"):
        p = hash_partition(key, 4)
        assert 0 <= p < 4
        assert p == hash_partition(key, 4)       # deterministic
    # small non-negative ints partition as key % n (hash(i) == i), which is
    # what keeps host routing and the device-side tac_jax.shard_of aligned
    for key in range(32):
        assert hash_partition(key, 5) == key % 5
    dev = np.asarray(tac_jax.shard_of(jnp.arange(32, dtype=jnp.int32), 5))
    assert dev.tolist() == [k % 5 for k in range(32)]


def test_shard_plane_validation():
    with pytest.raises(ValueError):
        ShardPlane(2, 4)                          # fewer shards than owners
    with pytest.raises(ValueError):
        ShardPlane(4, 2, owners=[0, 1, 2, 1])     # owner out of range
    plane = ShardPlane(8, 2)
    assert plane.owner == [0, 1] * 4
    assert plane.owner_of(5) == plane.owner[5 % 8]


# ------------------------------------------------- engine plane + routing
def _mini_sharded_op(mode="prefetch", policy="tac", n_shards=4,
                     parallelism=2):
    """Two-subtask stateful op on a shard plane, driven directly (no
    sources): deliver_batch + sim.run_until."""
    eng = Engine(marker_interval=10.0)            # markers out of the way
    plane = ShardPlane(n_shards, parallelism)

    def apply_fn(tup, state):
        state = (state or 0) + 1
        return state, [Tuple_(tup.ts, tup.key, state, 64, tup.ingest_t)]

    op = eng.add(StatefulOp(eng, "stateful", parallelism, apply_fn,
                            LOCAL_NVME, cache_capacity=64 * 200,
                            policy=policy, mode=mode, io_workers=2,
                            default_state=lambda k: 0, shards=plane))
    sink = eng.add(SinkOp(eng, "sink", 1))
    eng.connect(op, sink, partition=lambda k, n: 0)
    return eng, op, plane


def test_rekeyed_tuple_routes_to_owner_and_forwards_on_misroute():
    """A tuple delivered to the wrong subtask (stale routing during an
    ownership flip) is forwarded one hop to the owner and processed there,
    not dropped or applied against the wrong shard's cache."""
    eng, op, plane = _mini_sharded_op(mode="sync", policy="lru")
    key = 2                                       # shard 2 -> owner 0
    wrong = 1 - plane.owner_of(key)
    op.deliver_batch(wrong, [Tuple_(0.0, key, None, 64, 0.0)])
    eng.sim.run_until(0.1)
    assert plane.misroutes == 1
    assert op.processed == 1
    assert op.caches[plane.owner_of(key)].contains(key)
    assert not op.caches[wrong].contains(key)


def test_empty_batches_and_plane_counters():
    """Empty deliveries are harmless, and the routers count per shard."""
    eng, op, plane = _mini_sharded_op(mode="sync", policy="lru")
    op.deliver_batch(0, [])                       # empty batch: no-op
    eng.sim.run_until(0.01)
    assert op.processed == 0
    for key in (0, 1, 2, 3, 4):
        sub = plane.route_data(key, op.parallelism)
        op.deliver_batch(sub, [Tuple_(0.0, key, None, 64, 0.0)])
    eng.sim.run_until(0.2)
    assert op.processed == 5
    assert plane.tuples_routed == [2, 1, 1, 1]    # shard 0 got keys 0 and 4
    m = eng.metrics(duration=0.2, warmup=0.0)
    sp = m["stateful_shard_plane"]
    assert sp["tuples_routed"] == [2, 1, 1, 1]
    assert sp["owner"] == plane.owner


def test_hint_mid_migration_parks_and_replays():
    """ISSUE satellite: a hint arriving for a shard whose state is still in
    transit parks at the new owner and is replayed after re-admission — it
    still triggers a prefetch instead of being lost or applied at the old
    owner."""
    eng, op, plane = _mini_sharded_op(mode="prefetch", policy="tac")
    key = 0                                       # shard 0 -> owner 0
    # warm the key on subtask 0 so the migration has state to move
    op.deliver_batch(0, [Tuple_(0.0, key, None, 64, 0.0)])
    eng.sim.run_until(0.05)
    assert op.caches[0].contains(key)
    op.migrate_shard(0, 1)                        # state now in transit
    assert plane.owner[0] == 1 and 0 in plane.migrating
    assert not op.caches[0].contains(key)         # drained from the source
    # hint and tuple race in during the transfer: both arrive at the new
    # owner (routing already flipped) and must park
    hint = Hint(key, ts=1.0, origin="udf")
    op.deliver_batch(plane.owner_of(key), [hint])
    op.deliver_batch(plane.route_data(key, 2),
                     [Tuple_(0.1, key, None, 64, 0.1)])
    eng.sim.run_until(eng.sim.t + 1e-5)           # < transfer delay
    assert plane.parked_in_migration == 2
    assert op.managers[1].hints_received == 0     # not processed yet
    eng.sim.run_until(eng.sim.t + 0.1)            # transfer completes
    assert 0 not in plane.migrating
    assert plane.migrations == 1
    assert op.managers[1].hints_received == 1     # replayed at the dst
    assert op.caches[1].contains(key)             # migrated state landed
    assert op.processed >= 2


def test_migration_preserves_entry_timestamps_and_dirty():
    """TAC entries keep their (possibly future/hint) timestamps across a
    migration, so prefetched-but-unused state stays protected."""
    eng, op, plane = _mini_sharded_op(mode="sync", policy="tac")
    cache = op.caches[0]
    cache.insert(0, "hot", ts=123.0, dirty=True, size=200)
    op.backends[0].write(0, "hot", 200)
    op.migrate_shard(0, 1)
    eng.sim.run_until(eng.sim.t + 0.1)
    e = op.caches[1].entries[0]
    assert e.ts == 123.0 and e.dirty
    assert op.backends[1].data[0] == "hot"        # partition moved
    assert 0 not in op.backends[0].data


def test_inflight_writeback_lands_at_new_owner():
    """A dirty write-back already in an IO lane when its shard migrates
    must land in the NEW owner's backend partition (the shard's entries
    moved at drain time; writing to the source would strand the update)."""
    from repro.core.tac import Entry
    from repro.streaming.engine import _IOReq
    eng, op, plane = _mini_sharded_op(mode="sync", policy="tac")
    e = Entry(0, "latest", 1.0, dirty=True, size=200)
    op._io_enqueue(0, _IOReq("write", 0, entry=e))   # lane issued at src
    op.migrate_shard(0, 1)                           # flips before it lands
    eng.sim.run_until(eng.sim.t + 0.1)
    assert op.backends[1].data.get(0) == "latest"
    assert 0 not in op.backends[0].data


def test_ready_tuples_relocate_with_migrating_shard():
    """A tuple resumed into the ready queue but not yet processed moves
    with its shard instead of running against the drained source."""
    eng, op, plane = _mini_sharded_op(mode="async", policy="tac")
    op.ready[0].append(Tuple_(0.0, 0, None, 64, 0.0))
    op.migrate_shard(0, 1)
    assert not op.ready[0]                           # relocated, not run
    eng.sim.run_until(eng.sim.t + 0.2)
    assert op.processed == 1
    assert op.caches[1].contains(0)
    assert not op.caches[0].contains(0)


# ------------------------------------------------------- tac_jax primitives
def test_tac_jax_export_import_roundtrip():
    state = tac_jax.init(4, 2, 2)
    keys = jnp.asarray([3, 8, 13, 6], jnp.int32)
    ts = jnp.asarray([5.0, 6.0, 7.0, 8.0])
    vals = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)
    dirty = jnp.asarray([True, False, True, False])
    state = tac_jax.admit_batch(state, keys, ts, vals, dirty).state
    resident = np.asarray(state.keys)
    odd = set(int(k) for k in resident[resident >= 0] if k % 2 == 1)
    exp = tac_jax.export_mask(state, np.asarray(state.keys) % 2 == 1)
    assert set(exp.keys.tolist()) == odd
    left = np.asarray(exp.state.keys)
    assert not (left[left >= 0] % 2 == 1).any()   # drained from the source
    res = tac_jax.import_entries(tac_jax.init(4, 2, 2), exp.keys, exp.ts,
                                 exp.vals, exp.dirty)
    back = np.asarray(res.state.keys)
    assert set(back[back >= 0].tolist()) == odd
    # timestamps and dirty bits preserved
    for i, k in enumerate(exp.keys):
        b, w = np.nonzero(back == k)
        assert np.asarray(res.state.ts)[b[0], w[0]] == exp.ts[i]
        assert np.asarray(res.state.dirty)[b[0], w[0]] == exp.dirty[i]


def test_tac_jax_owned_wrappers_drop_foreign_keys():
    state = tac_jax.init(4, 2, 1)
    res, dropped = tac_jax.admit_owned(
        state, jnp.asarray([0, 1, 2, 3], jnp.int32),
        jnp.asarray([1.0, 2.0, 3.0, 4.0]), shard_id=0, n_shards=2)
    assert dropped == 2
    resident = np.asarray(res.state.keys)
    assert set(resident[resident >= 0].tolist()) == {0, 2}
    _, hit, owned = tac_jax.probe_owned(res.state,
                                        jnp.asarray([0, 1, 2], jnp.int32),
                                        shard_id=0, n_shards=2)
    assert np.asarray(hit).tolist() == [True, False, True]
    assert np.asarray(owned).tolist() == [True, False, True]
    # empty owned subset is fine
    res2, d2 = tac_jax.admit_owned(state, jnp.asarray([1, 3], jnp.int32),
                                   jnp.asarray([1.0, 2.0]),
                                   shard_id=0, n_shards=2)
    assert d2 == 2 and np.asarray(res2.slots).shape == (0,)


# ----------------------------------------------------------- serving router
def _router(n_shards=2, n_bins=8):
    mk_arena = lambda s: PagedStateArena(4, 2, {"kv": ((2, 4), np.float32)})
    mk_store = lambda s: TieredStore(backing_model=IN_MEMORY,
                                    page_bytes=256, workers=2)
    return ShardRouter(n_shards, mk_arena, mk_store, n_bins=n_bins)


def test_router_empty_batches():
    r = _router()
    hit, slots = r.probe(np.zeros((0,), np.int32))
    assert hit.shape == (0,) and slots.shape == (0,)
    adm = r.admit(np.zeros((0,), np.int32), np.zeros((0,), np.float32))
    assert adm.slots.shape == (0,)
    r.stage(adm.slots, {})
    r.renew(np.zeros((0,), np.int32), np.zeros((0,), np.float32))
    r.mark_dirty(np.zeros((0,), np.int32))
    assert r.request_stage([], now=0.0) == 0
    keys, blocks = r.flush_dirty()
    assert keys.shape == (0,) and blocks == {}


def test_router_routes_and_globalizes_slots():
    r = _router()
    keys = np.asarray([0, 1, 2, 3], np.int32)     # bins 0..3 -> shards 0101
    adm = r.admit(keys, np.asarray([1.0, 2.0, 3.0, 4.0], np.float32))
    r.stage(adm.slots, {"kv": np.stack([np.full((2, 4), float(k))
                                        for k in keys])})
    hit, slots = r.probe(keys)
    assert hit.all()
    assert (slots == adm.slots).all()
    shards = slots // r.slots_per_shard
    assert shards.tolist() == [0, 1, 0, 1]
    # per-shard arenas saw only their own keys
    a0 = np.asarray(r.arenas[0].tac.keys)
    assert set(a0[a0 >= 0].tolist()) == {0, 2}


def test_router_migration_preserves_pages_ts_dirty():
    r = _router()
    keys = np.asarray([0, 2, 4], np.int32)        # all bins owned by shard 0
    ts = np.asarray([10.0, 20.0, 30.0], np.float32)
    adm = r.admit(keys, ts, dirty=np.asarray([True, False, True]))
    r.stage(adm.slots, {"kv": np.stack([np.full((2, 4), float(k))
                                        for k in keys])})
    r.stores[0].seed(2, {"kv": np.zeros((2, 4), np.float32)})
    stats = r.migrate_bins([0, 2, 4], dst=1)
    assert stats["pages"] == 3 and stats["sources"] == 1
    assert (r.shard_of(keys) == 1).all()          # ownership flipped
    hit, slots = r.probe(keys, count=False)
    assert hit.all() and (slots // r.slots_per_shard == 1).all()
    # page contents crossed intact
    local = slots - r.slots_per_shard
    blk = np.asarray(r.arenas[1].gather(local)["kv"])
    for i, k in enumerate(keys):
        assert np.allclose(blk[i], float(k))
    # timestamps + dirty preserved in the destination TAC
    dk = np.asarray(r.arenas[1].tac.keys)
    for k, t, d in zip(keys, ts, [True, False, True]):
        b, w = np.nonzero(dk == k)
        assert np.asarray(r.arenas[1].tac.ts)[b[0], w[0]] == t
        assert bool(np.asarray(r.arenas[1].tac.dirty)[b[0], w[0]]) == d
    # tier contents moved with the shard
    assert 2 in r.stores[1].backing.data and 2 not in r.stores[0].backing.data
    # the old owner no longer holds the pages
    a0 = np.asarray(r.arenas[0].tac.keys)
    assert (a0 < 0).all()


def test_router_hint_routing_not_broadcast():
    """request_stage sends each key only to its owning shard's store."""
    r = _router()
    n = r.request_stage([0, 1, 2, 5], now=0.0, hint_ts=[1.0, 1.0, 1.0, 1.0])
    assert n == 4
    assert set(r.stores[0].in_flight) == {0, 2}
    assert set(r.stores[1].in_flight) == {1, 5}
    assert r.hints_routed.tolist() == [2, 2]
    done = r.poll(now=10.0)
    assert {k for k, _, _ in done} == {0, 1, 2, 5}


def test_backend_export_import_partition_handoff():
    src, dst = StateBackend(IN_MEMORY), StateBackend(IN_MEMORY)
    for k in range(6):
        src.write(k, f"v{k}", 64)
    moved = src.export_keys(lambda k: k % 2 == 0)
    writes_before = dst.writes
    assert dst.import_keys(moved) == 3
    assert set(src.data) == {1, 3, 5} and set(dst.data) == {0, 2, 4}
    assert dst.writes == writes_before            # handoff is not workload IO
