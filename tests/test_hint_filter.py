"""HintFilter admission tests (DESIGN.md §13): mode semantics, the
residency/cold/budget decision layers, bit-parity of ``hot`` mode with
the legacy inline CMS rule, the speculation gate, and the Pallas device
twin."""
import random

import pytest

from repro.core.cms import CountMinFilter
from repro.core.hint_filter import (EMIT, SUPPRESS_BUDGET, SUPPRESS_COLD,
                                    SUPPRESS_HOT, SUPPRESS_RESIDENT,
                                    HintFilter)

CMS = {"depth": 4, "width": 1000, "threshold": 20, "aging_interval": 1000}


def test_bad_mode_raises():
    with pytest.raises(ValueError):
        HintFilter(mode="sometimes")


# ---------------------------------------------------------------- all / hot
def test_all_mode_admits_everything_but_still_counts():
    f = HintFilter(mode="all", cms_conf=CMS)
    for i in range(100):
        assert f.admit(7, now=i * 1e-3)
    assert f.counters[EMIT] == 100
    assert sum(v for k, v in f.counters.items() if k != EMIT) == 0
    # the CMS counted every admission, so estimates stay comparable
    # across modes
    assert f.cms.estimate(7) >= 20


def test_hot_mode_matches_legacy_inline_rule():
    """Default mode is counter-for-counter identical to the old inline
    ``update_and_classify`` call sites."""
    f = HintFilter(mode="hot", cms_conf=CMS)
    legacy = CountMinFilter(**CMS)
    rng = random.Random(3)
    keys = [rng.randrange(40) for _ in range(3000)]
    suppressed = 0
    for i, k in enumerate(keys):
        hot = legacy.update_and_classify(k)
        suppressed += hot
        assert f.admit(k, now=i * 1e-4) == (not hot)
    assert f.counters[SUPPRESS_HOT] == suppressed
    assert f.counters[EMIT] == len(keys) - suppressed
    assert (f.cms.counters == legacy.counters).all()


def test_hot_mode_ignores_freq_key():
    """The legacy rule classified the FULL key; freq_key is a
    selective-mode concept and must not perturb hot mode."""
    a = HintFilter(mode="hot", cms_conf=CMS)
    b = HintFilter(mode="hot", cms_conf=CMS)
    for i in range(50):
        va = a.admit(("pane", 1), now=0.0)
        vb = b.admit(("pane", 1), now=0.0, freq_key="base")
        assert va == vb
    assert a.counters == b.counters


# ----------------------------------------------------------- selective mode
def test_residency_suppression_requires_min_est():
    """A recently-hinted key is only presumed still resident (and its
    re-hint suppressed) once its frequency estimate clears
    ``resident_min_est`` — cold keys lose capacity fights, so their
    re-hints must go through."""
    f = HintFilter(mode="selective", cms_conf=CMS, resident_ttl=1.0,
                   resident_min_est=4)
    # est 1..3: below min_est, every admission passes despite the TTL
    for i in range(3):
        assert f.admit("k", now=0.01 * i)
    # est 4: inside the TTL and now trusted resident -> suppressed
    assert not f.admit("k", now=0.04)
    assert f.last_verdict == SUPPRESS_RESIDENT
    assert f.counters[SUPPRESS_RESIDENT] == 1


def test_residency_suppression_expires_with_ttl():
    f = HintFilter(mode="selective", cms_conf=CMS, resident_ttl=0.05)
    assert f.admit("k", now=0.0)
    assert not f.admit("k", now=0.01)        # inside TTL
    assert f.admit("k", now=0.06)            # TTL expired: readmitted
    assert f.counters[EMIT] == 2
    assert f.counters[SUPPRESS_RESIDENT] == 1


def test_freq_key_separates_frequency_from_identity():
    """Panes of one base key share a frequency stream (freq_key) but
    keep per-pane residency: a NEW pane of a hot base is admitted even
    though the previous pane was just hinted."""
    f = HintFilter(mode="selective", cms_conf=CMS, resident_ttl=1.0,
                   resident_min_est=4)
    for i in range(10):
        f.admit(("b", 1), now=0.001 * i, freq_key="b")
    # base "b" is well past min_est; pane ("b", 2) was never hinted
    assert f.admit(("b", 2), now=0.02, freq_key="b")
    assert not f.admit(("b", 2), now=0.03, freq_key="b")  # now resident


def test_cold_threshold_suppresses_first_occurrences():
    f = HintFilter(mode="selective", cms_conf=CMS, cold_threshold=2,
                   resident_min_est=10 ** 6)
    assert not f.admit("k", now=0.0)         # est 1 <= 2
    assert f.last_verdict == SUPPRESS_COLD
    assert not f.admit("k", now=0.1)         # est 2 <= 2
    assert f.admit("k", now=0.2)             # est 3: warm enough
    assert f.counters[SUPPRESS_COLD] == 2


def test_budget_prioritises_hot_keys_when_dry():
    f = HintFilter(mode="selective", cms_conf=CMS, budget_per_s=50.0,
                   priority_threshold=5, resident_min_est=10 ** 6)
    for _ in range(30):                      # hot key, bypasses the bucket
        f.cms.update("hot")
    assert f.admit("cold1", now=0.0)         # consumes the single token
    assert not f.admit("cold2", now=0.0)     # dry + est below priority
    assert f.last_verdict == SUPPRESS_BUDGET
    assert f.admit("hot", now=0.0)           # dry but est >= priority
    assert f.admit("cold2", now=1.0)         # bucket refilled
    assert f.counters[SUPPRESS_BUDGET] == 1


def test_note_emit_sweeps_expired_residency_entries():
    f = HintFilter(mode="selective", cms_conf=CMS, resident_ttl=0.01,
                   sweep_every=4)
    for i in range(4):
        f.note_emit(f"k{i}", now=0.1 * i)
    # the 4th note triggers a sweep at t=0.3: only k3 is within the TTL
    assert list(f._last_emit) == ["k3"]


# -------------------------------------------------------------- speculation
def test_speculate_ok_gates_on_frequency():
    f = HintFilter(mode="selective", cms_conf=CMS, speculative=True)
    assert not f.speculate_ok("k")           # never seen: not worth it
    for _ in range(f.spec_min_est):
        f.cms.update("k")
    assert f.speculate_ok("k")
    g = HintFilter(mode="selective", cms_conf=CMS)   # speculation off
    for _ in range(50):
        g.cms.update("k")
    assert not g.speculate_ok("k")


def test_speculative_emit_marks_key_resident():
    """note_emit on a speculated key makes the later data-driven hint a
    suppressed (correct) duplicate."""
    f = HintFilter(mode="selective", cms_conf=CMS, resident_ttl=1.0)
    f.note_emit("k", now=0.0)
    assert not f.admit("k", now=0.01)
    assert f.last_verdict == SUPPRESS_RESIDENT


# ------------------------------------------------------------ reset/rollup
def test_reset_clears_soft_state():
    f = HintFilter(mode="selective", cms_conf=CMS, resident_ttl=10.0,
                   budget_per_s=50.0)
    assert f.admit("k", now=0.0)
    assert not f.admit("k", now=0.1)
    f.reset()
    assert f.cms.estimate("k") == 0
    assert f._tokens == f._bucket_cap
    assert f.admit("k", now=0.2)             # residency map cleared


def test_metrics_block_has_mode_and_all_verdicts():
    f = HintFilter(mode="selective", cms_conf=CMS)
    blk = f.metrics_block()
    assert blk["mode"] == "selective"
    for k in (EMIT, SUPPRESS_HOT, SUPPRESS_RESIDENT, SUPPRESS_COLD,
              SUPPRESS_BUDGET):
        assert blk[k] == 0


# ------------------------------------------------------------- device twin
def test_classify_batch_kernel_matches_host_semantics():
    """The cms_sketch Pallas twin (interpret mode): repeated keys cross
    the hot threshold, unseen keys stay cold — same SEMANTICS as the
    host sketch even though the hash values differ."""
    jnp = pytest.importorskip("jax.numpy")  # noqa: F841  (kernel needs jax)
    f = HintFilter(mode="selective",
                   cms_conf=dict(CMS, threshold=8, aging_interval=10 ** 6))
    for _ in range(3):
        f.classify_batch([5] * 4)            # 12 updates of key 5
    mask = f.classify_batch([5, 999])
    assert bool(mask[0]) and not bool(mask[1])
    f.reset()
    mask = f.classify_batch([5, 999])        # device state rebuilt cold
    assert not mask.any()
