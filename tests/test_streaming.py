"""Integration tests for the dataflow engine + Keyed Prefetching."""
import pytest

from repro.streaming.nexmark import NexmarkConfig, build_query
from repro.streaming.synthetic import SyntheticConfig, build_synthetic

# full-duration discrete-event sims: excluded from the quick tier-1 loop
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def q13_results():
    cfg = NexmarkConfig(rate=20_000, active_window=40.0)
    out = {}
    for policy, mode in [("lru", "sync"), ("lru", "async"),
                         ("tac", "prefetch")]:
        eng = build_query("q13", policy, mode, cfg, cache_entries=512,
                          parallelism=2, source_parallelism=1, io_workers=2)
        out[mode if policy == "lru" else "prefetch"] = \
            eng.run(duration=3.0, warmup=1.5)
    return out


def test_prefetching_raises_hit_rate(q13_results):
    assert q13_results["prefetch"]["stateful_hit_rate"] > 0.9
    assert q13_results["prefetch"]["stateful_hit_rate"] > \
        q13_results["sync"]["stateful_hit_rate"] + 0.1


def test_prefetching_improves_tail_latency(q13_results):
    assert q13_results["prefetch"]["p999"] < q13_results["sync"]["p999"]


def test_prefetching_keeps_throughput(q13_results):
    assert q13_results["prefetch"]["throughput"] >= \
        0.98 * q13_results["sync"]["throughput"]


def test_hint_network_overhead_is_small(q13_results):
    assert 0.0 < q13_results["prefetch"]["net_overhead"] < 0.15


def test_cpu_util_lower_with_prefetching(q13_results):
    """Paper Table I: async/KP overlap I/O, so stateful busy-time drops."""
    assert q13_results["prefetch"]["util_stateful"] < \
        q13_results["sync"]["util_stateful"]


def test_adaptive_lookahead_switches_on_mismatch():
    """With udf0 pinned as the only candidate, udf1's key remap at t=3 makes
    udf0's hints wrong; the per-origin prefetch-miss detector must fire and
    discard udf0."""
    cfg = SyntheticConfig(rate=10_000, t_mismatch=3.0, t_latency_drop=1e9)
    eng = build_synthetic(cfg, lookaheads=("udf0",))
    eng.run(duration=8.0, warmup=1.0)
    reasons = [w for _, _, w, _ in eng.controller.switch_log]
    assert "activate" in reasons
    assert "mismatch" in reasons
    # after the mismatch, udf0 must be discarded from the candidates
    remaining = [c.op_id for c in eng.controller.candidates["stateful"]]
    assert "udf0" not in remaining
    assert eng.controller.active["stateful"] is None   # none left


def test_adaptive_lookahead_timing_switch_happens():
    cfg = SyntheticConfig(rate=15_000, t_mismatch=1e9, t_latency_drop=1e9)
    eng = build_synthetic(cfg)
    eng.run(duration=6.0, warmup=1.0)
    reasons = [w for _, _, w, _ in eng.controller.switch_log]
    assert "activate" in reasons
    # slack-driven selection moved off the source-side candidate
    assert eng.controller.active["stateful"] in ("udf1", "udf2")


def test_checkpoint_barrier_flushes_dirty_state():
    """Paper §IV-E: on a checkpoint barrier, all modified TAC state (resident
    or staged in the eviction buffer) is persisted before completion."""
    from repro.streaming.nexmark import NexmarkConfig, build_query
    cfg = NexmarkConfig(rate=10_000, active_window=30.0)
    eng = build_query("q19", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=2)
    eng.sim.after(2.0, eng.trigger_checkpoint, 1)
    eng.run(duration=3.0, warmup=0.0)
    acks = eng.checkpoint_acks.get(1, [])
    st = eng.operators["stateful"]
    assert len(acks) == st.parallelism          # every subtask acked
    assert sum(n for _, _, _, n in acks) > 0    # dirty state was flushed
    # after the barrier point, caches had no dirty residue at flush time
    for c in st.caches:
        assert len(c.evict_buffer) >= 0         # buffer drained at barrier
