"""Per-kernel validation: shape/dtype sweeps vs the ref.py pure-jnp oracles
(kernels execute in interpret mode — Python on CPU — per the brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("S,H,KV,d,bq,bk", [
    (128, 4, 2, 32, 64, 64),
    (256, 2, 2, 64, 64, 128),
    (128, 4, 1, 16, 128, 32),      # MQA, uneven blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(S, H, KV, d, bq, bk, dtype, causal):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B = 2
    q = jax.random.normal(RNG, (B, S, H, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, 1).reshape(B * H, S, d)
    ref = attention_ref(qf, kf, vf, causal=causal) \
        .reshape(B, H, S, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# ------------------------------------------------------ paged decode attention
@pytest.mark.parametrize("B,H,d,page,P", [
    (3, 8, 32, 16, 4),
    (2, 4, 64, 32, 2),
    (4, 16, 16, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, H, d, page, P, dtype):
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_ref
    slots = B * P + 3
    q = jax.random.normal(RNG, (B, H, d), dtype)
    kp = jax.random.normal(jax.random.PRNGKey(1), (slots, page, d), dtype)
    vp = jax.random.normal(jax.random.PRNGKey(2), (slots, page, d), dtype)
    pt = jax.random.permutation(jax.random.PRNGKey(3),
                                slots)[:B * P].reshape(B, P)
    lens = jax.random.randint(jax.random.PRNGKey(4), (B,), 1, P * page + 1)
    out = paged_decode_attention(q, kp, vp, pt, lens)
    ref = paged_decode_ref(q, kp, vp, pt, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# -------------------------------------------------------------------- tac probe
@pytest.mark.parametrize("nb,ways,D,B", [(16, 8, 64, 32), (8, 4, 128, 16),
                                         (32, 16, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tac_probe(nb, ways, D, B, dtype):
    from repro.kernels.tac_probe.ops import bucket_of, tac_probe
    from repro.kernels.tac_probe.ref import tac_probe_ref
    rng = np.random.RandomState(0)
    bkeys = rng.choice(10_000, size=(nb, ways), replace=False) \
        .astype(np.int32)
    bvals = rng.randn(nb, ways, D).astype(np.float32)
    qk = np.where(np.arange(B) % 2 == 0,
                  rng.randint(1, 100_000, B), -(7 + np.arange(B))) \
        .astype(np.int32)
    bks = np.asarray(bucket_of(jnp.asarray(qk), nb))
    next_way = {}
    planted = 0
    for i in range(0, B, 2):          # plant hits in the hashed bucket
        wslot = next_way.get(bks[i], 0)
        if wslot < ways:
            bkeys[bks[i], wslot] = qk[i]
            next_way[bks[i]] = wslot + 1
            planted += 1
    bvals_j = jnp.asarray(bvals).astype(dtype)
    out_v, out_h, out_w = tac_probe(jnp.asarray(qk), jnp.asarray(bkeys),
                                    bvals_j)
    ref_v, ref_h, ref_w = tac_probe_ref(jnp.asarray(qk), jnp.asarray(bks),
                                        jnp.asarray(bkeys), bvals_j)
    assert (np.asarray(out_h) == np.asarray(ref_h)).all()
    assert (np.asarray(out_w) == np.asarray(ref_w)).all()
    np.testing.assert_allclose(np.asarray(out_v, np.float32),
                               np.asarray(ref_v, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))
    assert int(out_h.sum()) >= planted


# ------------------------------------------------------------------ cms sketch
@pytest.mark.parametrize("d,w,B", [(4, 256, 64), (2, 512, 128), (4, 128, 32)])
def test_cms_sketch(d, w, B):
    from repro.kernels.cms_sketch.ops import (cms_update_and_classify,
                                              columns_for)
    from repro.kernels.cms_sketch.ref import cms_update_ref
    rng = np.random.RandomState(1)
    a = jnp.asarray(rng.randint(1, 2 ** 31, d), dtype=jnp.uint32)
    b = jnp.asarray(rng.randint(0, 2 ** 31, d), dtype=jnp.uint32)
    keys = np.concatenate([np.full(20, 42), rng.randint(0, 1000, B - 20)])
    rng.shuffle(keys)
    keys = keys.astype(np.int32)
    counters0 = jnp.zeros((d, w), jnp.int32)
    new_c, hot = cms_update_and_classify(jnp.asarray(keys), counters0, a, b,
                                         threshold=5)
    cols = np.asarray(columns_for(jnp.asarray(keys), a, b, w))
    ref_c, ref_est = cms_update_ref(cols, np.zeros((d, w), np.int32))
    assert (np.asarray(new_c) == ref_c).all()
    assert (np.asarray(hot) == (ref_est >= 5).all(axis=0)).all()
    # the heavy hitter must be classified hot by its last occurrence
    last42 = np.where(keys == 42)[0][-1]
    assert bool(hot[last42])


def test_cms_sketch_saturation_and_aging_protocol():
    from repro.kernels.cms_sketch.ops import cms_update_and_classify
    d, w = 2, 64
    a = jnp.asarray([3, 7], dtype=jnp.uint32)
    b = jnp.asarray([1, 5], dtype=jnp.uint32)
    counters = jnp.full((d, w), 250, jnp.int32)
    keys = jnp.asarray(np.full(32, 9, np.int32))
    new_c, hot = cms_update_and_classify(keys, counters, a, b, threshold=10)
    assert int(new_c.max()) <= 255                 # saturating
    aged = new_c >> 1                              # caller-side aging
    assert int(aged.max()) <= 127


# ------------------------------------------------------------------ ssm scans
@pytest.mark.parametrize("S,P,N,chunk", [(128, 16, 8, 32), (64, 32, 16, 64),
                                         (256, 8, 4, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba2_scan(S, P, N, chunk, dtype):
    from repro.kernels.mamba2_scan.ops import mamba2_scan
    from repro.kernels.mamba2_scan.ref import mamba2_scan_ref
    BH = 3
    x = jax.random.normal(RNG, (BH, S, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (BH, S))).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (BH,)) * 0.5)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (BH, S, N), dtype)
    Cm = jax.random.normal(jax.random.PRNGKey(4), (BH, S, N), dtype)
    out = mamba2_scan(x, dt, A, Bm, Cm, chunk=chunk)
    ref = mamba2_scan_ref(x, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-9
    rel = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32)))) / scale
    assert rel < (5e-2 if dtype == jnp.bfloat16 else 1e-4), rel


@pytest.mark.parametrize("S,N,chunk", [(128, 8, 32), (64, 16, 64),
                                       (96, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(S, N, chunk, dtype):
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan
    from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
    BH = 3
    r = jax.random.normal(RNG, (BH, S, N), dtype)
    k = (jax.random.normal(jax.random.PRNGKey(5), (BH, S, N)) * 0.3) \
        .astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(6), (BH, S, N), dtype)
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(7),
                                         (BH, S, N))).astype(dtype)
    u = (jax.random.normal(jax.random.PRNGKey(8), (BH, N)) * 0.1) \
        .astype(dtype)
    out = rwkv6_scan(r, k, v, w, u, chunk=chunk)
    ref = rwkv6_scan_ref(r, k, v, w, u)
    scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-9
    rel = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32)))) / scale
    assert rel < (5e-2 if dtype == jnp.bfloat16 else 1e-4), rel


@pytest.mark.parametrize("kind", ["sum", "max", "read"])
def test_fused_step_composition(kind):
    """fused_step = tac_probe_gather ∘ operator compute ∘ page_scatter in
    one program; duplicate keys must compose exactly as a sequential
    per-lane loop (DESIGN.md §14)."""
    from repro.core import tac_jax
    W, V, B = 8, 2, 6
    state = tac_jax.init(1, W, 1)
    pages = jnp.zeros((W + 1, 1, V + 1), jnp.float32)
    # admit keys 0..3 at slots 0..3 with seed values
    seed = np.arange(1, 4 * V + 1, dtype=np.float32).reshape(4, V)
    state, pages, _ = tac_jax.fused_admit(
        state, pages, jnp.arange(4, dtype=jnp.int32),
        jnp.arange(4, dtype=jnp.int32),
        jnp.zeros(4, jnp.float32), jnp.asarray(seed),
        jnp.ones(4, bool), jnp.zeros(4, bool))
    # batch: dup key 1 (composes), key 2 fire (reads only), key 7 miss,
    # one padding lane
    keys = jnp.asarray([1, 1, 2, 7, 1, -2], jnp.int32)
    ts = jnp.full(B, 5.0, jnp.float32)
    wts = jnp.asarray(
        np.arange(1, B * V + 1, dtype=np.float32).reshape(B, V))
    fire = jnp.asarray([0, 0, 1, 0, 0, 0], bool)
    valid = jnp.asarray([1, 1, 1, 1, 1, 0], bool)
    out = tac_jax.fused_step(state, pages, keys, ts, wts, fire, valid,
                             kind=kind)
    hit = np.asarray(out.hit)
    assert hit.tolist() == [True, True, True, False, True, False]
    assert np.asarray(out.tallies).tolist() == [4, 1]
    # sequential reference over the same lanes
    vals = {k: seed[k].copy() for k in range(4)}
    ref = []
    for i in range(B):
        k = int(keys[i])
        if not hit[i]:
            ref.append(np.zeros(V, np.float32))
            continue
        if kind != "read" and not bool(fire[i]):
            w = np.asarray(wts[i])
            vals[k] = np.maximum(vals[k], w) if kind == "max" \
                else vals[k] + w
        ref.append(vals[k].copy())
    np.testing.assert_allclose(np.asarray(out.new_vals), np.stack(ref),
                               rtol=1e-6)
    # pool holds the final composed value; scratch row stays absent
    pool = np.asarray(out.pages)
    expect = seed[1] if kind == "read" else vals[1]
    np.testing.assert_allclose(pool[1, 0, 1:], expect, rtol=1e-6)
    assert pool[-1].sum() == 0.0
    # fire lane never dirties; update lanes do (except read kind)
    dirty = np.asarray(out.state.dirty)[0]
    assert not dirty[2]
    assert bool(dirty[1]) == (kind != "read")
    # drop then re-probe: membership cleared, pool row stale-but-dead
    st2 = tac_jax.drop_slots(out.state, jnp.asarray([1, 0], jnp.int32),
                             jnp.asarray([True, False], bool))
    out2 = tac_jax.fused_step(st2, out.pages, keys, ts, wts, fire, valid,
                              kind=kind)
    assert np.asarray(out2.hit).tolist() == [False, False, True, False,
                                             False, False]
