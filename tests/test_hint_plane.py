"""Hint-quality plane tests (DESIGN.md §13 over the §12 telemetry):

* deterministic PrefetchRecorder regression tests under scripted
  suppression/access schedules — every suppressed hint resolves to
  exactly one of resident/miss/unused, and the §12 precision/recall
  formulas are unchanged by suppression;
* live-engine runs with a selective/speculative HintFilter — the
  suppression ledger closes, speculation emits, and the delta codec
  compresses the hint channel without touching latency accounting;
* the adversarial distribution-shift run (ISSUE 7): a mid-stream hot-set
  flip must not let stale CMS state suppress the new hot set beyond one
  aging period — gated on the prefetch hit rate staying at the all-hints
  level.
"""
import pytest

from repro.obs import MetricsRegistry, PrefetchRecorder


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def make_recorder(horizon=1.0):
    clock = Clock()
    reg = MetricsRegistry()
    rec = PrefetchRecorder(reg, "engine.op", clock,
                           suppress_horizon=horizon)
    return rec, clock


def suppression_counts(rec):
    return (rec.suppressed.value, rec.suppress_resident.value,
            rec.suppress_miss.value, rec.suppress_unused.value)


def assert_ledger_closes(rec):
    """Invariant: suppressed == resident + miss + unused + pending."""
    s, r, m, u = suppression_counts(rec)
    pending = sum(n for _t, n in rec.pending_suppressed.values())
    assert s == r + m + u + pending


# ------------------------------------------------- scripted recorder runs
def test_suppress_then_hit_grades_resident():
    rec, clock = make_recorder()
    rec.on_suppressed("k")
    assert_ledger_closes(rec)
    clock.t = 0.1
    rec.on_access("k", hit=True)
    assert suppression_counts(rec) == (1, 1, 0, 0)
    assert not rec.pending_suppressed
    assert_ledger_closes(rec)


def test_suppress_then_miss_grades_miss():
    rec, clock = make_recorder()
    rec.on_suppressed("k")
    clock.t = 0.1
    rec.on_access("k", hit=False)
    assert suppression_counts(rec) == (1, 0, 1, 0)
    assert_ledger_closes(rec)


def test_repeated_suppressions_fold_and_share_one_outcome():
    rec, clock = make_recorder()
    for i in range(3):
        clock.t = 0.01 * i
        rec.on_suppressed("k")
    assert rec.pending_suppressed["k"] == [0.0, 3]
    clock.t = 0.1
    rec.on_access("k", hit=False)
    assert suppression_counts(rec) == (3, 0, 3, 0)
    assert_ledger_closes(rec)


def test_access_beyond_horizon_grades_unused():
    """An access long after the suppression is unrelated to it: the
    hint would have been wasted anyway, whatever the access outcome."""
    rec, clock = make_recorder(horizon=1.0)
    rec.on_suppressed("k")
    clock.t = 1.5
    rec.on_access("k", hit=False)
    assert suppression_counts(rec) == (1, 0, 0, 1)
    assert_ledger_closes(rec)


def test_flush_pending_closes_the_ledger():
    rec, clock = make_recorder()
    rec.on_suppressed("a")
    rec.on_suppressed("b")
    clock.t = 0.1
    rec.on_access("a", hit=True)
    rec.flush_pending()
    assert suppression_counts(rec) == (2, 1, 0, 1)
    assert not rec.pending_suppressed
    assert_ledger_closes(rec)


def test_periodic_expiry_reclaims_stale_pending_entries():
    rec, clock = make_recorder(horizon=0.5)
    rec.on_suppressed("stale")
    clock.t = 2.0
    # 1023 more suppressions of distinct keys trigger the 1024-step
    # sweep, which grades the stale entry without any access
    for i in range(1023):
        rec.on_suppressed(("fresh", i))
    assert "stale" not in rec.pending_suppressed
    assert rec.suppress_unused.value >= 1
    assert_ledger_closes(rec)


def test_unknown_access_is_a_noop():
    rec, _clock = make_recorder()
    rec.on_access("never-suppressed", hit=True)
    assert suppression_counts(rec) == (0, 0, 0, 0)


def test_quality_block_formulas_unchanged_by_suppression():
    """§12: precision = used / (staged + late), recall = hits /
    (hits + demand) — suppression adds fields, never re-weights them."""
    rec, clock = make_recorder()
    for _ in range(4):
        rec.on_staged()
    clock.t = 0.2
    rec.on_used(stage_t=0.1)
    rec.on_used(stage_t=0.15)
    rec.on_wasted()
    rec.on_late(first_need_t=0.19)
    rec.on_suppressed("k")
    rec.on_access("k", hit=False)
    blk = rec.quality_block(prefetch_hits=6, demand_fetches=2,
                            duplicates=3, late_wm=1)
    assert blk["precision"] == pytest.approx(2 / (4 + 1))
    assert blk["recall"] == pytest.approx(6 / (6 + 2))
    # every staged hint still ends in exactly one §12 outcome
    assert blk["used"] + blk["wasted"] + blk["resident_unused"] \
        == blk["staged"]
    # and every suppressed hint in exactly one §13 outcome
    assert blk["suppressed"] == blk["suppress_resident"] \
        + blk["suppress_miss"] + blk["suppress_unused"] \
        + blk["suppress_pending"]
    assert blk["suppress_miss"] == 1
    assert blk["suppress_pending"] == 0


# ---------------------------------------------------- live engine runs
def run_q5(key_dist="zipf", hint_filter=None, compress=True,
           duration=1.5, rate=2_000.0):
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query
    cfg = NexmarkConfig(rate=rate, active_window=1.0, oo_bound=0.3,
                        seed=7, key_dist=key_dist, shift_interval=0.4)
    eng = build_query("q5", "tac", "prefetch", cfg, cache_entries=128,
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.0005, window_size=0.5,
                      window_slide=0.25, hint_filter=hint_filter,
                      compress_hints=compress)
    return eng.run(duration=duration, warmup=0.4)


SELECTIVE = {"mode": "selective", "resident_ttl": 0.05,
             "resident_min_est": 4}


@pytest.fixture(scope="module")
def q5_selective():
    return run_q5(hint_filter=dict(SELECTIVE, speculative=True,
                                   spec_width=2))


def test_live_suppression_ledger_closes(q5_selective):
    m = q5_selective
    hq = m["stateful_hint_quality"]
    filt = m["win_lookahead_hint_filter"]
    assert filt["mode"] == "selective"
    suppressed_src = sum(v for k, v in filt.items()
                        if str(k).startswith("suppressed_"))
    assert hq["suppressed"] == suppressed_src > 0
    # Engine.run flushed the pending map: every suppression graded
    assert hq["suppress_pending"] == 0
    assert hq["suppressed"] == hq["suppress_resident"] \
        + hq["suppress_miss"] + hq["suppress_unused"]
    # staged outcomes still partition (§12 untouched by §13)
    assert hq["used"] + hq["wasted"] + hq["resident_unused"] \
        == hq["staged"]


def test_live_speculation_emits_next_pane_hints(q5_selective):
    m = q5_selective
    assert m["win_lookahead_speculative_hints"] > 0


def test_live_delta_codec_compresses_without_touching_latency():
    base = run_q5(hint_filter=None, compress=False)
    comp = run_q5(hint_filter=None, compress=True)
    # identical simulation: codec changes byte ACCOUNTING only
    assert comp["p99"] == base["p99"]
    assert comp["n_outputs"] == base["n_outputs"]
    assert base["hint_bytes"] == base.get("hint_bytes_raw", base["hint_bytes"])
    assert comp["hint_bytes_raw"] == base["hint_bytes"]
    assert comp["hint_bytes"] < comp["hint_bytes_raw"]
    assert comp["hint_compression"] > 1.5


# ------------------------------------------- adversarial distribution shift
def test_shift_does_not_let_stale_cms_starve_new_hot_set():
    """ISSUE 7 satellite: flip the hot set mid-stream (key_dist="shift",
    several epochs per run).  CMS aging must retire the stale hot set
    fast enough that selective suppression never starves the new one:
    the prefetch hit rate and recall stay at the all-hints level, and
    incorrect suppressions stay a small fraction of the total."""
    allh = run_q5(key_dist="shift", hint_filter={"mode": "all"},
                  duration=2.5)
    sel = run_q5(key_dist="shift", hint_filter=SELECTIVE, duration=2.5)
    assert sel["stateful_hit_rate"] >= allh["stateful_hit_rate"] - 0.02
    hq_sel = sel["stateful_hint_quality"]
    hq_all = allh["stateful_hint_quality"]
    assert hq_sel["recall"] >= hq_all["recall"] - 0.05
    assert hq_sel["suppress_miss"] <= 0.2 * max(1, hq_sel["suppressed"])
