"""Temporal observability plane tests (DESIGN.md §16).

Four claims: (1) ``interval_sketch`` turns two cumulative sketch states
into an EXACT per-interval histogram (counts/mean) that stays mergeable,
(2) the ``Timeline`` ring retains the newest ``capacity`` intervals and
reports — not hides — what it evicted, (3) hysteresis detectors never
flap: noise confined to the gap between the fire and clear thresholds
raises at most one alert (scripted sequences + a hypothesis property),
and (4) the chaos alert oracle holds end to end — every effective
injected fault raises its mapped alert within the logical delay bound,
and the golden run raises none.
"""
import json

import pytest

from repro.obs import (Alert, Detector, HealthMonitor, LoadShiftDetector,
                       MetricsRegistry, QuantileSketch, SpikeDetector,
                       Timeline, interval_sketch, read_timeline_jsonl,
                       timeline_jsonl)
from repro.obs.timeseries import _sketch_state

from tests._hypothesis_compat import given, settings, st


# ------------------------------------------------------- interval sketch
def test_interval_sketch_counts_and_mean_are_exact():
    sk = QuantileSketch()
    for v in (1e-3, 2e-3, 5e-3):
        sk.observe(v)
    state = _sketch_state(sk)
    batch = [4e-3, 4e-3, 9e-3, -2e-3, 0.0]
    for v in batch:
        sk.observe(v)
    iv = interval_sketch(state, sk)
    assert iv.count == len(batch)
    assert iv.total == pytest.approx(sum(batch))
    assert iv.mean == pytest.approx(sum(batch) / len(batch))
    # bin-midpoint extremes stay within the sketch's relative error,
    # and a new cumulative extreme is carried exactly
    assert iv.vmin == -2e-3              # new cumulative min -> exact
    assert iv.vmax == 9e-3               # new cumulative max -> exact
    assert iv.quantile(0.5) == pytest.approx(4e-3, rel=0.05)


def test_interval_sketch_none_prev_equals_cumulative():
    sk = QuantileSketch()
    for v in (1.0, 2.0, 3.0):
        sk.observe(v)
    iv = interval_sketch(None, sk)
    assert iv.count == sk.count
    assert iv.quantile(0.5) == pytest.approx(sk.quantile(0.5))


def test_interval_sketches_merge_back_to_cumulative():
    """Splitting a stream into intervals then merging the interval
    sketches reproduces the cumulative quantiles — the property that
    makes p99-over-a-window a merge instead of a guess."""
    sk = QuantileSketch()
    merged = QuantileSketch()
    state = None
    rng_vals = [((i * 37) % 100 + 1) * 1e-4 for i in range(400)]
    for chunk in range(4):
        for v in rng_vals[chunk * 100:(chunk + 1) * 100]:
            sk.observe(v)
        iv = interval_sketch(state, sk)
        state = _sketch_state(sk)
        merged.merge(iv)
    assert merged.count == sk.count
    for q in (0.1, 0.5, 0.9, 0.99):
        assert merged.quantile(q) == pytest.approx(sk.quantile(q), rel=1e-9)


def test_empty_interval_sketch_is_zero():
    sk = QuantileSketch()
    sk.observe(1.0)
    state = _sketch_state(sk)
    iv = interval_sketch(state, sk)     # nothing new observed
    assert iv.count == 0 and iv.total == 0.0


# ------------------------------------------------------------- ring buffer
def _tl(interval=0.1, capacity=4):
    r = MetricsRegistry()
    return r, Timeline(r, interval=interval, capacity=capacity)


def test_ring_retention_and_eviction_accounting():
    r, tl = _tl(capacity=4)
    c = r.counter("engine.q.processed")
    for i in range(10):
        c.inc(5)
        tl.tick(0.1 * (i + 1))
    b = tl.block()
    assert b["intervals"] == 10
    assert b["retained"] == 4
    assert b["evicted"] == 6
    # the ring holds the NEWEST intervals
    assert [iv.t1 for iv in tl.ring] == pytest.approx([0.7, 0.8, 0.9, 1.0])
    # counter deltas are per-interval, not cumulative
    assert all(iv.deltas["engine.q.processed"] == 5 for iv in tl.ring)
    # the timeline's own meta-counters never self-count
    assert all(not k.startswith("timeline.") for iv in tl.ring
               for k in iv.deltas)


def test_select_and_series_window_filters():
    r, tl = _tl(capacity=32)
    c = r.counter("engine.q.processed")
    g = r.gauge("engine.q.queue.depth")
    for i in range(8):
        c.inc(i)
        g.set(float(i))
        tl.tick(0.1 * (i + 1))
    assert len(tl.select()) == 8
    win = tl.select(since=0.35, until=0.65)
    assert [iv.t1 for iv in win] == pytest.approx([0.4, 0.5, 0.6])
    s = tl.series("engine.q.processed", since=0.35, until=0.65)
    assert [v for _, v in s] == [3, 4, 5]
    sg = tl.series("engine.q.queue.depth", since=0.75)
    assert [v for _, v in sg] == [7.0]


def test_merged_sketch_over_window():
    r, tl = _tl(capacity=32)
    h = r.histogram("engine.sink.latency")
    for i in range(4):
        for _ in range(10):
            h.observe(1e-3 * (i + 1))
        tl.tick(0.1 * (i + 1))
    full = tl.merged_sketch("engine.sink.latency")
    assert full.count == 40
    part = tl.merged_sketch("engine.sink.latency", since=0.25)
    assert part.count == 20              # intervals ending 0.3, 0.4
    assert part.quantile(0.5) >= full.quantile(0.5)


def test_ratio_series_skips_low_volume():
    r, tl = _tl(capacity=32)
    used = r.counter("engine.q.prefetch.used")
    staged = r.counter("engine.q.prefetch.staged")
    used.inc(8), staged.inc(10)
    tl.tick(0.1)
    tl.tick(0.2)                         # empty interval: no denominator
    used.inc(3), staged.inc(10)
    tl.tick(0.3)
    s = tl.ratio_series("engine.q.prefetch.used",
                        ["engine.q.prefetch.staged"], min_den=1.0)
    assert [(round(t, 1), v) for t, v in s] == [(0.1, 0.8), (0.3, 0.3)]


def test_timeline_validates_args():
    r = MetricsRegistry()
    with pytest.raises(ValueError):
        Timeline(r, interval=0.0)
    with pytest.raises(ValueError):
        Timeline(r, capacity=1)


# ------------------------------------------------------ hysteresis detector
def test_detector_scripted_onset_and_clear():
    d = Detector("wm_lag", fire=1.0, clear=0.5,
                 fire_after=2, clear_after=2, op="q")
    seq = [0.2, 1.2,                      # 1 hot interval: not yet
           0.3,                           # resets the hot count
           1.5, 1.4,                      # 2 consecutive -> fire
           0.7, 0.4,                      # 1 cool interval only
           0.6,                           # above clear: resets cool
           0.4, 0.3]                      # 2 consecutive -> clear
    alerts = [d.update(0.1 * (i + 1), v) for i, v in enumerate(seq)]
    raised = [a for a in alerts if a is not None]
    assert len(raised) == 1
    a = raised[0]
    assert a.kind == "wm_lag" and a.op == "q"
    assert a.t == pytest.approx(0.5)     # fired on the 5th interval
    assert a.cleared_t == pytest.approx(1.0)
    assert not d.firing


def test_detector_below_direction():
    d = Detector("precision", fire=0.30, clear=0.45,
                 direction="below", fire_after=2, clear_after=1)
    assert d.update(0.1, 0.9) is None
    assert d.update(0.2, 0.1) is None    # 1 low interval
    a = d.update(0.3, 0.2)               # 2nd -> fire
    assert a is not None and a.value == 0.2
    assert d.update(0.4, 0.5) is None and not d.firing
    assert a.cleared_t == pytest.approx(0.4)


def test_detector_none_freezes_counts():
    d = Detector("stall", fire=10.0, clear=2.0, fire_after=2)
    d.update(0.1, 50.0)
    d.update(0.2, None)                  # no evidence: count holds at 1
    assert not d.firing
    assert d.update(0.3, 50.0) is not None


def test_detector_rejects_inverted_thresholds():
    with pytest.raises(ValueError):
        Detector("x", fire=1.0, clear=2.0)                 # above: fire>clear
    with pytest.raises(ValueError):
        Detector("x", fire=0.5, clear=0.2, direction="below")
    with pytest.raises(ValueError):
        Detector("x", fire=2.0, clear=1.0, fire_after=0)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(min_value=0.55, max_value=2.5,
                          allow_nan=False), min_size=1, max_size=80))
def test_no_flapping_inside_the_hysteresis_gap(values):
    """Noise that never crosses the CLEAR threshold (0.5) raises at most
    one alert no matter how often it crosses FIRE (1.0): the gap must be
    crossed twice for a second alert, which these sequences cannot do."""
    d = Detector("wm_lag", fire=1.0, clear=0.5, fire_after=2,
                 clear_after=2)
    raised = sum(1 for i, v in enumerate(values)
                 if d.update(0.1 * (i + 1), v) is not None)
    assert raised <= 1


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=0.95,
                          allow_nan=False), min_size=1, max_size=80))
def test_never_fires_below_threshold(values):
    d = Detector("wm_lag", fire=1.0, clear=0.5, fire_after=2,
                 clear_after=2)
    assert all(d.update(0.1 * (i + 1), v) is None
               for i, v in enumerate(values))
    assert not d.firing


# ------------------------------------------------- spike + load detectors
def test_spike_detector_one_alert_per_burst():
    d = SpikeDetector("migration", clear_after=2)
    a1 = d.update(0.1, 1.0)
    assert a1 is not None
    assert d.update(0.2, 2.0) is None    # burst continues, no new alert
    d.update(0.3, 0.0)
    d.update(0.4, 0.0)                    # 2 quiet intervals -> cleared
    assert a1.cleared_t == pytest.approx(0.4)
    assert d.update(0.5, 1.0) is not None  # a NEW burst alerts again


def test_load_shift_detector_fires_and_freezes_baseline():
    d = LoadShiftDetector(band=1.6, clear_band=1.25, window=8,
                          fire_after=2, min_volume=20.0)
    t = 0.0
    for _ in range(8):                    # steady 100/interval baseline
        t += 0.1
        assert d.update(t, 100.0) is None
    raised = []
    for _ in range(6):                    # 2.5x shift, sustained
        t += 0.1
        a = d.update(t, 250.0)
        if a is not None:
            raised.append(a)
    # baseline froze while firing, so the shifted rate never became the
    # new normal and the alert did not self-clear
    assert len(raised) == 1 and d.firing
    assert raised[0].value == pytest.approx(2.5)
    for _ in range(2):                    # back inside the clear band
        t += 0.1
        d.update(t, 100.0)
    assert not d.firing
    assert raised[0].cleared_t == pytest.approx(t)


def test_load_shift_detector_silent_below_min_volume():
    d = LoadShiftDetector(min_volume=20.0)
    t = 0.0
    for v in (5, 5, 5, 5, 40, 40):        # quiet baseline: never fires
        t += 0.1
        assert d.update(t, float(v)) is None


# ---------------------------------------------------------- health monitor
def test_health_monitor_wm_lag_and_stall_alerts():
    r = MetricsRegistry()
    tl = Timeline(r, interval=0.1, capacity=64)
    hm = HealthMonitor(tl, ["q"], wm_lag_fire=1.0, wm_lag_clear=0.5,
                       queue_fire=100.0, queue_clear=10.0, fire_after=2)
    lag = r.gauge("engine.q.watermark.lag")
    depth = r.gauge("engine.q.queue.depth")
    new = []
    for i, (lg, dp) in enumerate([(0.1, 5), (1.5, 5), (1.5, 500),
                                  (1.6, 500), (0.2, 2), (0.1, 2)]):
        lag.set(lg)
        depth.set(float(dp))
        new += hm.observe(tl.tick(0.1 * (i + 1)))
    kinds = sorted(a.kind for a in new)
    assert kinds == ["stall", "wm_lag"]
    assert all(a.op == "q" for a in new)
    b = hm.block()
    assert b["raised"] == 2 and b["active"] == 0 and b["cleared"] == 2
    assert r.counter("health.alerts.raised").value == 2
    assert r.counter("health.alerts.wm_lag").value == 1
    assert r.counter("health.alerts.stall").value == 1


def test_health_monitor_precision_collapse():
    r = MetricsRegistry()
    tl = Timeline(r, interval=0.1, capacity=64)
    hm = HealthMonitor(tl, ["q"], min_volume=10.0, fire_after=2)
    used = r.counter("engine.q.prefetch.used")
    staged = r.counter("engine.q.prefetch.staged")
    new = []
    for i, (u, s) in enumerate([(18, 20), (18, 20), (2, 20), (2, 20),
                                (2, 20)]):
        used.inc(u)
        staged.inc(s)
        new += hm.observe(tl.tick(0.1 * (i + 1)))
    assert [a.kind for a in new] == ["precision"]


# ------------------------------------------------------- export round-trip
def test_timeline_jsonl_round_trip(tmp_path):
    r, tl = _tl(capacity=8)
    c = r.counter("engine.q.processed")
    h = r.histogram("engine.sink.latency")
    for i in range(3):
        c.inc(10)
        h.observe(1e-3)
        tl.tick(0.1 * (i + 1))
    alerts = [Alert("wm_lag", "q", 0.2, 1.5, 1.0, "test")]
    path = str(tmp_path / "tl.jsonl")
    n = timeline_jsonl(tl, path, alerts=alerts)
    assert n == 4                        # 3 intervals + 1 alert line
    ivs, al = read_timeline_jsonl(path)
    assert len(ivs) == 3 and len(al) == 1
    assert ivs[0]["deltas"]["engine.q.processed"] == 10
    assert ivs[0]["quantiles"]["engine.sink.latency"]["count"] == 1
    assert al[0]["kind"] == "wm_lag" and al[0]["t"] == 0.2


def test_registry_export_jsonl_delta_block(tmp_path):
    r = MetricsRegistry()
    c = r.counter("engine.q.processed")
    path = str(tmp_path / "m.jsonl")
    c.inc(7)
    r.export_jsonl(path, t=0.5)
    c.inc(3)
    r.export_jsonl(path, t=1.0)
    r.export_jsonl(path, t=1.5, cumulative=True)   # legacy shape
    lines = [json.loads(x) for x in open(path)]
    assert lines[0]["delta"]["engine.q.processed"] == 7
    assert lines[1]["delta"]["engine.q.processed"] == 3
    assert lines[1]["metrics"]["engine.q.processed"] == 10
    assert "delta" not in lines[2]


# --------------------------------------------------- chaos alert oracle
@pytest.mark.slow
def test_chaos_alert_oracle_on_seeded_schedules():
    """The headline soundness check: on >= 3 seeded schedules, every
    EFFECTIVE injected fault (failure / owner-changing migrate /
    non-unit load shift) raises its mapped alert within the logical
    delay bound, the golden run raises ZERO alerts, and the
    exactly-once state oracle still passes under observation."""
    from repro.streaming.chaos import (FaultEvent, FaultSchedule,
                                       alert_oracle, compare,
                                       run_schedule)
    scheds = [
        FaultSchedule(101, (
            FaultEvent("load_shift", 0.5, (2.5, 0.5)),
            FaultEvent("migrate", 1.0, (0, 1)),
            FaultEvent("failure", 1.3, ("warmed",)))),
        FaultSchedule(202, (
            FaultEvent("failure", 0.7, ("cold",)),
            FaultEvent("load_shift", 1.1, (0.4, 0.4)),
            FaultEvent("migrate", 1.4, (1, 0)))),
        FaultSchedule(303, (
            FaultEvent("migrate", 0.5, (3, 0)),
            FaultEvent("migrate", 0.7, (2, 0)),   # no-op: owner already 0
            FaultEvent("load_shift", 0.9, (3.0, 0.4)),
            FaultEvent("failure", 1.35, ("warmed",)))),
    ]
    for sched in scheds:
        golden = run_schedule(sched.with_events(()), t_cut=2.0,
                              observe=True)
        pert = run_schedule(sched, t_cut=2.0, observe=True)
        rep = alert_oracle(sched, pert, golden)
        assert rep["recall"] == 1.0, (sched.seed, rep["per_event"])
        assert rep["golden_alerts"] == 0, (sched.seed, golden.metrics)
        assert rep["golden_false_stall"] == 0
        for kind, pk in rep["per_kind"].items():
            assert pk["matched"] == pk["injected"], (sched.seed, kind)
        assert compare(golden, pert).ok   # observation never perturbs state
    # seed 303's no-op migrate must be filtered, not silently unmatched
    from repro.streaming.chaos import effective_events
    eff = effective_events(scheds[2])
    assert sum(1 for _, k in eff if k == "migration") == 1


@pytest.mark.slow
def test_engine_timeline_smoke_q5():
    """A healthy windowed run with the plane enabled: intervals cut on
    the logical clock, zero alerts, fused fill-ratio series present,
    and a loadable Chrome trace with span + control + counter events."""
    from repro.obs import chrome_trace
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query
    cfg = NexmarkConfig(rate=3000.0, active_window=1.0, oo_bound=0.3,
                        seed=7)
    eng = build_query("q5", "tac", "prefetch", cfg, cache_entries=256,
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, buffer_timeout=0.002,
                      hint_ts="deadline", window_size=1.0,
                      window_slide=0.5)
    eng.enable_timeline(interval=0.1)
    eng.enable_tracing(sample_every=16)
    m = eng.run(duration=1.2, warmup=0.0)
    assert m["timeline"]["intervals"] >= 10
    assert m["health"]["raised"] == 0 and m["alerts"] == []
    trace = chrome_trace(eng)
    blob = json.dumps(trace)              # must be valid JSON
    evs = trace["traceEvents"]
    assert any(e.get("ph") == "X" and e.get("name") == "tuple"
               for e in evs)
    assert any(e.get("ph") == "C" for e in evs)
    assert all(isinstance(e.get("ts", 0), int) for e in evs)
    assert len(blob) > 1000
