"""Tests for the sharding resolver, param spec rules, and the loop-aware
HLO analyzer that feeds §Roofline."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_analysis as H
from repro.launch.sharding import default_rules, resolve_spec
from repro.launch.specs import ShardingPolicy, param_logical


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_resolve_spec_basic():
    rules = default_rules(False)
    spec = resolve_spec(("batch", "seq", "heads", None),
                        (256, 4096, 64, 128), MESH, rules)
    assert spec == P(("data",), None, "model", None)


def test_resolve_spec_divisibility_fallback():
    rules = default_rules(False)
    # 40 heads do not divide the 16-way model axis -> dropped
    spec = resolve_spec(("batch", "seq", "heads", None),
                        (256, 4096, 40, 128), MESH, rules)
    assert spec == P(("data",), None, None, None)


def test_resolve_spec_axis_conflict():
    rules = default_rules(False)
    # 'batch' takes data; a second data-mapped axis must be dropped
    spec = resolve_spec(("batch", "experts_data", None),
                        (256, 160, 64), MESH, rules)
    assert spec == P(("data",), None, None)


def test_resolve_spec_multi_pod_prefix_fallback():
    rules = default_rules(True)
    # batch=16 divides data(16) but not pod*data(32): prefix fallback
    spec = resolve_spec(("batch", None), (16, 64), MESH_POD, rules)
    assert spec[0] in ("pod", ("pod",))


def test_param_logical_expert_schemes():
    pol = ShardingPolicy(fsdp_params=True)
    assert param_logical(("layers", "moe", "w_gate"),
                         (59, 160, 5120, 1536), pol) \
        == (None, "tp", "fsdp", None)
    pol2 = ShardingPolicy(fsdp_params=True,
                          expert_scheme="ep_data_tp_ffn")
    assert param_logical(("layers", "moe", "w_gate"),
                         (59, 160, 5120, 1536), pol2) \
        == (None, "expert_fsdp", None, "tp")


def test_param_logical_bc_projections_replicated():
    """Hillclimb B3: mamba B/C projections must stay replicated."""
    pol = ShardingPolicy(fsdp_params=False)
    assert param_logical(("layers", "mamba", "w_Bm"), (54, 2560, 64), pol) \
        == (None, None, None)


# ------------------------------------------------------------- hlo analyzer
def _analyze(fn, *specs):
    return H.analyze(jax.jit(fn).lower(*specs).compile().as_text())


def test_analyzer_counts_scan_trip_counts():
    def scanned(x, ws):
        def f(h, w):
            return h @ w, None
        return jax.lax.scan(f, x, ws)[0]

    t = _analyze(scanned,
                 jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((12, 64, 64), jnp.float32))
    expected = 12 * 2 * 64 ** 3
    assert abs(t.flops - expected) / expected < 0.02
    assert not t.trip_warnings


def test_analyzer_dot_flops_exact():
    t = _analyze(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((32, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 16), jnp.float32))
    assert t.flops >= 2 * 32 * 128 * 16
    assert t.flops < 2.2 * 32 * 128 * 16


def test_analyzer_nested_scan_multiplies():
    def nested(x, ws):
        def outer(h, w):
            def inner(hh, _):
                return hh @ w, None
            return jax.lax.scan(inner, h, None, length=5)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    t = _analyze(nested,
                 jax.ShapeDtypeStruct((32, 32), jnp.float32),
                 jax.ShapeDtypeStruct((4, 32, 32), jnp.float32))
    expected = 4 * 5 * 2 * 32 ** 3
    assert abs(t.flops - expected) / expected < 0.05


def test_analyzer_shape_parsing_handles_tuple_comments():
    comps, entry = H.parse_hlo(
        "ENTRY %main (p0: f32[4,4]) -> (f32[4,4], s32[]) {\n"
        "  %p0 = f32[4,4]{1,0} parameter(0)\n"
        "  %t = (f32[4,4]{1,0}, /*index=1*/s32[]) tuple(%p0, %p0)\n"
        "}\n")
    assert entry == "main"
    assert comps["main"].instrs[-1].opcode == "tuple"
    assert H.shape_bytes("(f32[4,4]{1,0}, /*index=1*/s32[])") == 64 + 4
