"""Per-architecture smoke + consistency tests (reduced configs, CPU).

Covers deliverable (f): every assigned arch instantiates a reduced config and
runs one forward/train step asserting output shapes and no NaNs, plus the
prefill-vs-decode consistency invariant that validates every cache path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, count_params, get_smoke_config
from repro.models.lm import build_model

RNG = jax.random.PRNGKey(0)


def _batch_for(cfg, B, S, train=True):
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if train:
        batch["targets"] = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    if cfg.frontend and cfg.frontend.kind == "vision":
        batch["frontend_embeds"] = jax.random.normal(
            RNG, (B, cfg.frontend.num_tokens, cfg.frontend.embed_dim),
            jnp.bfloat16)
    if cfg.encoder_decoder:
        batch["frames"] = jax.random.normal(
            RNG, (B, S, cfg.frontend.embed_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(RNG)
    batch = _batch_for(cfg, B=2, S=64)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    # one gradient step exists and is finite
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn), f"{arch} grad norm not finite"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(RNG)
    B, S = 2, 64
    batch = _batch_for(cfg, B, S, train=False)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch} prefill NaN"
    pos = jnp.int32(S - 1)
    if cfg.frontend and cfg.frontend.kind == "vision":
        pos = jnp.int32(S - 1 + cfg.frontend.num_tokens)
    db = {"tokens": batch["tokens"][:, :1], "pos": pos}
    logits2, cache2 = jax.jit(model.decode)(params, cache, db)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any()), f"{arch} decode NaN"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_analytic(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init_params, RNG)
    actual = sum(x.size for x in jax.tree.leaves(shapes))
    assert actual == count_params(cfg), arch


def _no_drop_cfg(cfg):
    # fp32 + no capacity drops: the consistency check is then exact to ~1e-3
    # and catches real cache bugs instead of bf16 noise.
    cfg = cfg.replace(dtype="float32")
    if cfg.moe:
        return cfg.replace(
            moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(x[:-1]), x[-1]) must agree with prefill(x) — validates
    every KV/SSM/conv/cross-attn cache path end to end."""
    cfg = _no_drop_cfg(get_smoke_config(arch))
    model = build_model(cfg)
    params = model.init_params(RNG)
    B, S = 2, 32
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    full = {"tokens": toks}
    part = {"tokens": toks[:, :S - 1]}
    n_img = 0
    if cfg.frontend and cfg.frontend.kind == "vision":
        n_img = cfg.frontend.num_tokens
        img = jax.random.normal(RNG, (B, n_img, cfg.frontend.embed_dim),
                                jnp.float32)
        full["frontend_embeds"] = img
        part["frontend_embeds"] = img
    if cfg.encoder_decoder:
        frames = jax.random.normal(RNG, (B, S, cfg.frontend.embed_dim),
                                   jnp.float32)
        full["frames"] = frames
        part["frames"] = frames           # same encoder input on both sides

    lg_full, _ = jax.jit(model.prefill)(params, full)
    _, cache = jax.jit(model.prefill)(params, part)

    # grow every cache time-axis by one slot so the decode write fits
    t_old = S - 1 + n_img

    def pad(a):
        if hasattr(a, "ndim") and a.ndim >= 3 and a.dtype != jnp.int32:
            for ax in range(a.ndim):
                if a.shape[ax] == t_old:
                    pw = [(0, 0)] * a.ndim
                    pw[ax] = (0, 1)
                    return jnp.pad(a, pw)
        return a

    cache = jax.tree.map(pad, cache)
    db = {"tokens": toks[:, S - 1:S], "pos": jnp.int32(t_old)}
    lg_dec, _ = jax.jit(model.decode)(params, cache, db)
    denom = float(jnp.max(jnp.abs(lg_full))) + 1e-9
    rel = float(jnp.max(jnp.abs(lg_full - lg_dec))) / denom
    assert rel < 1e-3, f"{arch}: prefill/decode mismatch rel={rel}"


def test_balanced_attention_matches_masked():
    """attn_impl='balanced' (causal FLOP-skipping) must be numerically
    equivalent to the masked-rectangle baseline."""
    import jax
    import jax.numpy as jnp
    from repro.models.layers import blocked_attention
    rng = jax.random.PRNGKey(0)
    B, S, H, d = 2, 256, 4, 32
    q = jax.random.normal(rng, (B, S, H, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, d), jnp.float32)
    a = blocked_attention(q, k, v, causal=True, q_block=64, kv_block=64,
                          impl="masked")
    b = blocked_attention(q, k, v, causal=True, q_block=64, kv_block=64,
                          impl="balanced")
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
