"""Differential parity: the fused device hot path vs the interpreted
engine (DESIGN.md §14).

The same tuple stream is driven through a fused and an interpreted
operator under the QUIESCED protocol — deliver a data batch, run the
simulator until all I/O lands, deliver a watermark, quiesce again.
Batching compresses simulated time (that is the latency win), so under
CONCURRENT async I/O backend completions land at different points of
the event timeline and eviction-order counters may diverge; state and
emitted tuples match regardless.  Quiescing pins the interleaving, and
then EVERYTHING must match bit-exactly: final backend state, emitted
tuples, and the §12 counter totals (hits/misses/evictions by reason,
writebacks, late drops/updates, parked-tuple demand fetches).
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.streaming.backend import LOCAL_NVME
from repro.streaming.engine import Engine, SinkOp, StatefulOp
from repro.streaming.events import Tuple_, Watermark
from repro.streaming.fused import FusedPlane, FusedSpec, Lane
from repro.streaming.windows import WindowAssigner, WindowedStatefulOp


def count_spec():
    return FusedSpec(kind="sum", width=1,
                     weight_of=lambda tup: 1.0,
                     encode=lambda s: None if s is None else [float(s)],
                     decode=lambda v: int(round(float(v[0]))))


def max_spec():
    return FusedSpec(kind="max", width=1,
                     weight_of=lambda tup: float(tup.payload),
                     encode=lambda s: None if s is None else [float(s)],
                     decode=lambda v: int(round(float(v[0]))))


class Collect(SinkOp):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.got = []

    def process(self, sub, tup):
        self.got.append((tup.ts, tup.key, tup.payload))
        return super().process(sub, tup)


def _counters(op):
    cache = op.caches[0]
    return dict(hits=cache.hits, misses=cache.misses,
                evictions=cache.evictions, writebacks=cache.writebacks,
                by_reason=cache.eviction_block(), processed=op.processed,
                outputs=op.outputs, pf_demand=op.pf_demand.value)


def _final_state(op, state_size):
    for e in op.caches[0].flush_dirty():
        op.backends[0].write(e.key, e.state, state_size)
    return dict(op.backends[0].data)


# ------------------------------------------------------------ base operator
def run_base(keys, fused, cache_entries=8, batch=8):
    """Count-per-key through a bare StatefulOp under the quiesced
    protocol; returns (state, counters)."""
    eng = Engine()
    kw = dict(policy="tac", mode="async", cache_capacity=cache_entries * 64,
              state_size=64, io_workers=2)
    if fused:
        kw["fused"] = count_spec()
        kw["fused_batch"] = batch

    def apply_count(tup, state):
        return ((state or 0) + 1, [])

    op = StatefulOp(eng, "agg", 1, apply_count, LOCAL_NVME, **kw)
    eng.add(op)
    t = 0.0
    for i in range(0, len(keys), 6):
        op.deliver_batch(0, [Tuple_(float(j), keys[j], None, 64, 0.0)
                             for j in range(i, min(i + 6, len(keys)))])
        t += 0.05
        eng.sim.run_until(t)
    eng.sim.run_until(t + 1.0)
    return _final_state(op, 64), _counters(op)


def assert_base_parity(keys):
    si, ci = run_base(keys, fused=False)
    sf, cf = run_base(keys, fused=True)
    assert si == sf, f"state mismatch\ninterp={si}\nfused={sf}"
    assert ci == cf, f"counter mismatch\ninterp={ci}\nfused={cf}"
    return ci


def test_base_parity_with_evictions_and_parking():
    keys = [1, 2, 3, 1, 1, 4, 2, 9, 9, 1, 5, 6, 7, 8, 10, 11, 1, 2, 12, 1]
    ci = assert_base_parity(keys)
    # the workload must actually exercise the cold paths it claims to
    assert ci["evictions"] > 0
    assert ci["pf_demand"] > 0          # misses parked + demand-fetched


def test_base_parity_single_hot_key():
    # duplicate keys in one batch: the device composes the run in-lane
    assert_base_parity([7] * 23)


def test_base_parity_all_distinct():
    assert_base_parity(list(range(30)))


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=12),
                min_size=1, max_size=48))
def test_base_parity_property(keys):
    assert_base_parity(keys)


# -------------------------------------------------------- windowed operator
def run_windowed(keys_ts, fused, lateness, late_policy, size=10.0,
                 cache_entries=6, batch=8, wm_lag=6.0, spec=None,
                 agg=None, emit=None, payload_of=None):
    """Windowed count per (key, window) with a mid-stream watermark after
    every quiesced data batch; returns (emits, state, counters)."""
    eng = Engine()
    kw = dict(policy="tac", mode="async", cache_capacity=cache_entries * 64,
              state_size=64, io_workers=2, allowed_lateness=lateness,
              late_policy=late_policy)
    if fused:
        kw["fused"] = spec or count_spec()
        kw["fused_batch"] = batch
    if agg is None:
        def agg(tup, state):
            return (state or 0) + 1

        def emit(base, wid, end, acc):
            return ("count", base, acc) if acc else None
    op = WindowedStatefulOp(eng, "win", 1, WindowAssigner(size), agg, emit,
                            LOCAL_NVME, **kw)
    sink = Collect(eng, "sink", 1)
    eng.add(op)
    eng.add(sink)
    eng.connect(op, sink)
    batches = []                         # the fence-invariant check below
    if fused:
        plane = op.caches[0]
        orig = plane.batch_step

        def recording(lanes):
            batches.append(list(lanes))
            return orig(lanes)
        plane.batch_step = recording
    t = 0.0
    hi = 0.0
    for i in range(0, len(keys_ts), 6):
        chunk = keys_ts[i:i + 6]
        op.deliver_batch(0, [
            Tuple_(ts, k, payload_of(k, ts) if payload_of else None,
                   64, 0.0) for k, ts in chunk])
        hi = max([hi] + [ts for _, ts in chunk])
        t += 0.05
        eng.sim.run_until(t)             # quiesce: all I/O lands
        op.deliver_batch(0, [Watermark(hi - wm_lag)])
        t += 0.05
        eng.sim.run_until(t)
    op.deliver_batch(0, [Watermark(hi + 1000.0)])
    eng.sim.run_until(t + 2.0)
    for lanes in batches:
        fires = {ln.key for ln in lanes if ln.fire}
        upds = {ln.key for ln in lanes if not ln.fire}
        assert not (fires & upds), \
            "fire and update of the same pane shared a device batch"
    ctr = _counters(op)
    ctr.update(fires=op.fires, late_dropped=op.late_dropped,
               late_updates=op.late_updates, purged=op.panes_purged)
    return sorted(sink.got), _final_state(op, 64), ctr


def assert_windowed_parity(keys_ts, lateness, late_policy, **kw):
    gi, si, ci = run_windowed(keys_ts, False, lateness, late_policy, **kw)
    gf, sf, cf = run_windowed(keys_ts, True, lateness, late_policy, **kw)
    assert gi == gf, f"emit mismatch\ninterp={gi}\nfused={gf}"
    assert si == sf, f"state mismatch\ninterp={si}\nfused={sf}"
    assert ci == cf, f"counter mismatch\ninterp={ci}\nfused={cf}"
    return ci


def _steady_stream():
    keys = [1, 2, 3, 1, 1, 4, 2, 9, 9, 1, 5, 6, 7, 8, 10, 11, 1, 2, 12, 1,
            3, 3, 5, 1, 2, 7, 9, 4, 4, 1]
    return [(k, i * 1.7) for i, k in enumerate(keys)]


def test_windowed_parity_no_lateness():
    ci = assert_windowed_parity(_steady_stream(), 0.0, "drop")
    assert ci["fires"] > 0               # mid-stream watermarks fired panes
    assert ci["evictions"] > 0


def test_windowed_parity_update_policy_with_late_tuples():
    # watermark trails by 6s; tuples jumping 30s back are LATE on fired
    # panes (within the 40s horizon -> late-side re-aggregation)
    stream = _steady_stream()
    late = [(1, 3.0), (2, 5.0), (1, 12.0), (9, 14.0)]
    keys_ts = stream[:18] + late + stream[18:]
    ci = assert_windowed_parity(keys_ts, 40.0, "update")
    assert ci["late_updates"] > 0


def test_windowed_parity_drop_policy_drops_late():
    stream = _steady_stream()
    late = [(1, 3.0), (2, 5.0), (1, 0.5)]
    keys_ts = stream[:18] + late + stream[18:]
    ci = assert_windowed_parity(keys_ts, 40.0, "drop")
    assert ci["late_dropped"] > 0


def test_windowed_parity_horizon_drop():
    # beyond watermark - lateness: dropped in BOTH policies
    stream = _steady_stream()
    keys_ts = stream + [(5, 0.1), (6, 0.2)]
    ci = assert_windowed_parity(keys_ts, 0.0, "drop")
    assert ci["late_dropped"] >= 2


def test_windowed_parity_max_kind():
    stream = [(k, i * 1.7) for i, k in enumerate(
        [1, 2, 1, 3, 1, 2, 4, 1, 5, 2, 1, 3, 6, 1, 2, 7, 1, 1])]

    def agg(tup, state):
        p = tup.payload
        return p if state is None or p > state else state

    def emit(base, wid, end, acc):
        return ("max", base, acc) if acc is not None else None

    # payload must be a pure function of (k, ts): both runs see it
    assert_windowed_parity(
        stream, 0.0, "drop", spec=max_spec(), agg=agg, emit=emit,
        payload_of=lambda k, ts: (k * 7919 + int(ts * 10)) % 9973 + 1)


if HAVE_HYPOTHESIS:
    _streams = st.lists(
        st.tuples(st.integers(min_value=0, max_value=9),
                  st.floats(min_value=0.0, max_value=60.0, width=16,
                            allow_nan=False)),
        min_size=1, max_size=36)

    @settings(max_examples=10, deadline=None)
    @given(_streams, st.sampled_from([(0.0, "drop"), (25.0, "update"),
                                      (25.0, "drop")]))
    def test_windowed_parity_property(keys_ts, pol):
        lateness, policy = pol
        assert_windowed_parity(keys_ts, lateness, policy)


# ------------------------------------------------- chaos-schedule parity
def run_chaos_count(fused, events=True, seed=29, t_cut=0.9):
    """Count-per-key through a LIVE engine run (free-running async I/O,
    not the quiesced protocol): replayable source, periodic checkpoints,
    and a chaos-style failure + load-shift schedule on the sim clock.
    The generator is cut on the source's logical clock, so recovery
    replay and the load shift change when records arrive but never which
    records exist — final state must be a pure function of the seed.

    Migration is the one chaos kind excluded here: the fused plane
    forbids the shard plane (test_fused_forbids_shards), so parity runs
    over the remaining kinds.
    """
    import numpy as np

    from repro.streaming.engine import SourceOp
    from repro.streaming.recovery import CheckpointCoordinator

    eng = Engine()
    rng = np.random.Generator(np.random.PCG64(seed))

    def gen(lt):
        if lt >= t_cut:
            return None
        return int(rng.integers(20)), None, 64

    def apply_count(tup, state):
        return ((state or 0) + 1, [])

    kw = dict(policy="tac", mode="async", cache_capacity=8 * 64,
              state_size=64, io_workers=2)
    if fused:
        kw["fused"] = count_spec()
        kw["fused_batch"] = 8
    src = eng.add(SourceOp(eng, "src", 1, 4000.0, gen, replayable=True))
    op = eng.add(StatefulOp(eng, "agg", 1, apply_count, LOCAL_NVME, **kw))
    eng.connect(src, op)

    coord = CheckpointCoordinator(eng, interval=0.2)
    coord.start()
    if events:
        def fire_failure():
            if coord.in_recovery:
                eng.sim.after(0.05, fire_failure)
                return
            coord.fail(mode="warmed", down_time=0.05, replay_speedup=4.0)

        eng.sim.at(0.45, fire_failure)
        eng.sim.at(0.60, setattr, src, "rate_scale", 2.5)
        eng.sim.at(0.80, setattr, src, "rate_scale", 1.0)

    src.start()
    eng.sim.after(eng.marker_interval, eng._inject_marker)
    t = 0.0
    while True:
        t += 0.25
        eng.sim.run_until(t)
        log_end = src.log_base[0] + len(src.log[0])
        if (src.logical_t[0] >= t_cut and src.replay_pos[0] >= log_end
                and not coord.in_recovery):
            break
        assert t < 30.0, "chaos parity run failed to quiesce"
    eng.sim.run_until(t + 0.5)               # drain in-flight I/O
    src.stopped = True
    state = {k: v for k, v in _final_state(op, 64).items()
             if v is not None}
    return state, coord.failures


def test_chaos_schedule_parity_interpreted_vs_fused():
    """Across a failure + load-shift schedule, the fused device path and
    the interpreted path land on bit-identical final keyed state — and
    both equal the unperturbed run (exactly-once state effects)."""
    perturbed_interp, f1 = run_chaos_count(fused=False)
    perturbed_fused, f2 = run_chaos_count(fused=True)
    golden, _ = run_chaos_count(fused=False, events=False)
    assert f1 >= 1 and f2 >= 1               # the failure actually fired
    assert golden and sum(golden.values()) > 0
    assert perturbed_interp == golden
    assert perturbed_fused == golden


# -------------------------------------------------------------- unit layer
def test_fused_requires_tac_policy():
    eng = Engine()
    with pytest.raises(ValueError):
        StatefulOp(eng, "x", 1, lambda t, s: (s, []), LOCAL_NVME,
                   cache_capacity=64, policy="lru", mode="async",
                   fused=count_spec())


def test_fused_forbids_shards():
    from repro.streaming.shards import ShardPlane
    eng = Engine()
    with pytest.raises(ValueError):
        StatefulOp(eng, "x", 1, lambda t, s: (s, []), LOCAL_NVME,
                   cache_capacity=64, policy="tac", mode="async",
                   fused=count_spec(), shards=ShardPlane(2, 1))


def test_fused_spec_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FusedSpec(kind="median")


def test_fusedplane_single_key_ops():
    plane = FusedPlane(4 * 8, 8, count_spec(), batch=4)
    assert plane.lookup("a", 1.0) is None        # miss
    plane.insert("a", 3, 1.0, dirty=True)
    assert plane.lookup("a", 2.0) == 3
    plane.write("a", 5, 3.0)
    assert plane.lookup("a", 3.0) == 5
    assert plane.contains("a")
    assert len(plane) == 1
    assert plane.drop("a")
    assert not plane.contains("a")
    assert plane.hits == 2 and plane.misses == 1


def test_fusedplane_eviction_and_writeback():
    plane = FusedPlane(2 * 8, 8, count_spec(), batch=4)
    plane.insert("a", 1, 1.0, dirty=True)
    plane.insert("b", 2, 2.0, dirty=True)
    plane.insert("c", 3, 3.0, dirty=True)       # evicts "a" (min ts)
    assert plane.evictions == 1
    assert plane.eviction_block() == {"capacity.demand": 1}
    assert "a" in plane.evict_buffer            # dirty victim staged
    assert plane.lookup("a", 4.0) == 1          # restore from the buffer
    assert plane.evictions == 2                 # ...which evicted again
    wb = plane.pop_writeback()
    assert wb is not None and plane.writebacks == 1


def test_fusedplane_batch_step_composes_duplicates():
    import numpy as np
    spec = count_spec()
    plane = FusedPlane(4 * 8, 8, spec, batch=8)
    plane.insert("k", 10, 1.0, dirty=False)
    lanes = [Lane("k", 2.0, spec.weight(None), False, False, None)
             for _ in range(3)]
    res = plane.batch_step(lanes)
    assert res.hit.all()
    # prefix composition: lane i sees the value AFTER its own update
    assert [plane.decode_lane(res, i) for i in range(3)] == [11, 12, 13]
    assert plane.lookup("k", 3.0) == 13
    assert plane.device_hits == 3 and plane.lanes == 3
    assert 0.0 < plane.fill_ratio <= 1.0
    miss = plane.batch_step(
        [Lane("nope", 4.0, spec.weight(None), False, False, None)])
    assert not miss.hit.any() and plane.device_misses == 1
    assert isinstance(res.new_vals, np.ndarray)


def test_fusedplane_flush_and_export_roundtrip():
    plane = FusedPlane(4 * 8, 8, count_spec(), batch=4)
    plane.insert("a", 1, 1.0, dirty=True)
    plane.insert("b", 2, 2.0, dirty=False)
    dirty = plane.flush_dirty()
    assert [e.key for e in dirty] == ["a"]
    ents = plane.export_entries(lambda k: True)
    assert {e.key for e in ents} == {"a", "b"}
    assert len(plane) == 0
    plane.import_entries(ents)
    assert plane.lookup("a", 5.0) == 1 and plane.lookup("b", 5.0) == 2
