"""Substrate tests: data determinism, checkpoint/restart, compression,
supervisor fault tolerance, elastic resharding specs."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.train import build_training
from repro.optim import adamw
from repro.runtime.compression import make_compressor, quantize_int8
from repro.runtime.supervisor import (SupervisorConfig, TrainSupervisor,
                                      inject_failure_at)


def test_data_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=3)
    b1 = batch_at(cfg, 17)
    b2 = batch_at(cfg, 17)
    assert (np.asarray(b1["tokens"]) == np.asarray(b2["tokens"])).all()
    b3 = batch_at(cfg, 18)
    assert not (np.asarray(b1["tokens"]) == np.asarray(b3["tokens"])).all()


def test_checkpoint_roundtrip():
    state = {"w": jnp.arange(12.0).reshape(3, 4),
             "opt": (jnp.ones(3), jnp.zeros((), jnp.int32))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(10, state, extra={"data_step": 10}, blocking=True)
        mgr.save(20, jax.tree.map(lambda x: x + 1, state), blocking=True)
        step, restored, extra = mgr.restore(state)
        assert step == 20
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.arange(12.0).reshape(3, 4) + 1)
        # retention: only `keep` checkpoints remain
        mgr.save(30, state, blocking=True)
        assert mgr.list_steps() == [20, 30]


def test_compression_error_feedback_reduces_bias():
    init, transform = make_compressor()
    params = {"w": jnp.zeros((64,))}
    err = init(params)
    rng = np.random.RandomState(0)
    g_true = jnp.asarray(rng.randn(64) * 1e-3)
    total_raw = jnp.zeros(64)
    total_comp = jnp.zeros(64)
    for _ in range(50):
        out, err = transform({"w": g_true}, err)
        total_comp = total_comp + out["w"]
        total_raw = total_raw + g_true
    # error feedback keeps the long-run average unbiased
    rel = float(jnp.linalg.norm(total_comp - total_raw)
                / jnp.linalg.norm(total_raw))
    assert rel < 0.02, rel


def test_quantize_int8_range():
    x = jnp.asarray([-3.0, 0.0, 1.5, 3.0])
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(q.astype(jnp.float32) * s), x,
                               atol=float(s))


@pytest.mark.slow
def test_supervisor_recovers_from_failure_and_loss_decreases():
    state, step_fn, model, cfg = build_training(
        "gemma-7b", smoke=True, batch=4, seq=32, n_micro=1)
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=2)
        sup = TrainSupervisor(SupervisorConfig(checkpoint_every=8), ckpt)
        rep = sup.run(state, step_fn, 30,
                      failure_injector=inject_failure_at({17}))
        assert rep.restarts == 1
        assert rep.steps_run >= 30          # includes replayed steps
        assert rep.losses[-1] < rep.losses[0]


@pytest.mark.slow
def test_supervisor_detects_stragglers():
    state, step_fn, model, cfg = build_training(
        "gemma-7b", smoke=True, batch=2, seq=16, n_micro=1)
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, keep=1)
        sup = TrainSupervisor(SupervisorConfig(checkpoint_every=100,
                                               straggler_factor=2.5), ckpt)
        delays = {12: 0.5}
        rep = sup.run(state, step_fn, 16,
                      delay_injector=lambda s: delays.get(s, 0.0))
        assert rep.stragglers >= 1


@pytest.mark.slow
def test_compressed_training_converges():
    state, step_fn, model, cfg = build_training(
        "gemma-7b", smoke=True, batch=4, seq=32, n_micro=1, compress=True)
    losses = []
    for step in range(20):
        state, metrics = step_fn(state, step)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
