"""End-to-end behaviour tests for the paper's system.

The detailed coverage lives in test_core / test_streaming / test_models /
test_kernels / test_substrate / test_tac_jax; this module asserts the two
headline behaviours end to end:
  (1) Keyed Prefetching + TAC lowers tail latency vs the caching baseline
      on the paper's own workload family, without losing throughput.
  (2) The TPU serving adaptation (session-state prefetching around a REAL
      jitted model) improves time-to-first-token at the tail.
"""
import pytest

from repro.streaming.nexmark import NexmarkConfig, build_query

# end-to-end sims + a real jitted model: excluded from the quick tier-1 loop
pytestmark = pytest.mark.slow


def test_end_to_end_keyed_prefetching_beats_sync_caching():
    cfg = NexmarkConfig(rate=22_000, active_window=40.0)
    res = {}
    for name, policy, mode in [("sync", "lru", "sync"),
                               ("kp", "tac", "prefetch")]:
        eng = build_query("q13", policy, mode, cfg, cache_entries=512,
                          parallelism=2, source_parallelism=1, io_workers=2)
        res[name] = eng.run(duration=3.0, warmup=1.5)
    assert res["kp"]["p999"] < res["sync"]["p999"]
    assert res["kp"]["throughput"] >= 0.98 * res["sync"]["throughput"]
    assert res["kp"]["stateful_hit_rate"] > 0.9


def test_end_to_end_serving_prefetch_improves_tail_ttft():
    from repro.launch.serve import ServeConfig, run_serving
    cfg = ServeConfig(n_sessions=12, n_requests=24, prompt_len=16,
                      decode_tokens=2, store_latency=0.03, cache_sessions=6,
                      arrival_rate=500.0)
    base = run_serving(cfg, "sync")
    kp = run_serving(cfg, "prefetch")
    assert kp["staging_overlap"] > base["staging_overlap"]
    assert kp["ttft_p99"] < base["ttft_p99"]
