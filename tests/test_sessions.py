"""Session-window semantics: merge canonicalization, bridging-tuple state
preservation, Aion-style late re-open, moving-deadline hints (DESIGN.md
§15).

The Hypothesis properties pin the assigner's one canonical merge rule
(``SessionWindowAssigner.fold``): the final session registry of a key is
a pure function of the SET of event timestamps — independent of arrival
order — which is exactly what the chaos oracle (streaming/chaos.py)
differentially compares across perturbed runs.  The engine-level tests
then check the same guarantees end to end through the keyed two-step
merge protocol (drain -> absorb), where a pane may be parked on a
backend fetch mid-merge.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.streaming.backend import IN_MEMORY
from repro.streaming.engine import Engine, SinkOp, SourceOp
from repro.streaming.nexmark import NexmarkConfig, build_query
from repro.streaming.sessions import (SessionWindowAssigner,
                                      SessionWindowedOp)

GAP = 0.1


# ------------------------------------------------------- reference model
def _reference(ts_list, gap):
    """Gap-split over the SORTED timestamps: the textbook session
    definition the incremental fold must agree with."""
    out = []
    for ts in sorted(ts_list):
        if out and ts < out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], ts + gap))
        else:
            out.append((ts, ts + gap))
    return out


def _fold_all(assigner, ts_list):
    sessions = []
    for ts in ts_list:
        assigner.fold(sessions, ts)
    return sessions


def _check_order_independence(values, perm_seed):
    """fold(any permutation) == gap-split reference, with canonical ids."""
    assigner = SessionWindowAssigner(GAP)
    ts_list = [v * 0.03 for v in values]
    rng = np.random.Generator(np.random.PCG64(perm_seed))
    shuffled = list(ts_list)
    rng.shuffle(shuffled)
    sessions = _fold_all(assigner, shuffled)
    got = sorted((s["start"], s["end"], s["wid"]) for s in sessions)
    want = [(a, b, assigner.wid_of(a)) for a, b in
            _reference(ts_list, GAP)]
    assert got == want
    # registry invariants: disjoint, gap-separated, every ts covered
    for (_, e0, _), (s1, _, _) in zip(got, got[1:]):
        assert s1 >= e0
    for ts in ts_list:
        assert sum(1 for s, e, _ in got if s <= ts < e) == 1


# ------------------------------------------------- Hypothesis properties
@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(0, 120), min_size=1, max_size=40),
       st.integers(0, 2**32 - 1))
def test_fold_is_order_independent(values, perm_seed):
    _check_order_independence(values, perm_seed)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(0, 120), min_size=2, max_size=20),
       st.integers(0, 2**32 - 1))
def test_fold_merge_is_associative(values, perm_seed):
    """Folding the same multiset in two different interleavings (split
    into halves folded in either order) lands on the same registry."""
    assigner = SessionWindowAssigner(GAP)
    ts_list = [v * 0.03 for v in values]
    rng = np.random.Generator(np.random.PCG64(perm_seed))
    half = int(rng.integers(1, len(ts_list)))
    a, b = ts_list[:half], ts_list[half:]
    reg1 = {(s["start"], s["end"], s["wid"])
            for s in _fold_all(assigner, a + b)}
    reg2 = {(s["start"], s["end"], s["wid"])
            for s in _fold_all(assigner, b + a)}
    assert reg1 == reg2


def test_fold_order_independence_fixed_cases():
    """The property logic itself, exercised without Hypothesis so tier-1
    covers it even when the dev extra is absent."""
    for seed, values in [(1, [0, 1, 2]), (2, [0, 40, 20]),
                         (3, [5, 5, 5]), (4, [0, 3, 6, 9, 40, 43, 80]),
                         (5, list(range(0, 120, 4)))]:
        _check_order_independence(values, seed)


# ----------------------------------------------------- assigner unit tests
def test_assigner_canonical_wid_roundtrip():
    a = SessionWindowAssigner(0.5)
    wid = a.wid_of(1.234567)
    assert abs(a.start_of(wid) - 1.234567) < 1e-6
    assert a.end(wid) == pytest.approx(a.start_of(wid) + 0.5)
    with pytest.raises(ValueError):
        SessionWindowAssigner(0.0)


def test_fold_bridging_tuple_absorbs_later_session():
    a = SessionWindowAssigner(0.1)
    sessions = []
    a.fold(sessions, 0.10)                # A: [0.10, 0.20)
    a.fold(sessions, 0.25)                # B: [0.25, 0.35)
    sess, absorbed, extended, created = a.fold(sessions, 0.16)   # bridge
    assert len(sessions) == 1 and not created and extended
    assert [x["wid"] for x in absorbed] == [a.wid_of(0.25)]
    assert sess["wid"] == a.wid_of(0.10)  # earliest ts keeps the id
    assert sess["start"] == 0.10 and sess["end"] == pytest.approx(0.35)


def test_fold_predating_tuple_creates_new_survivor():
    """A tuple EARLIER than every overlapping session owns the canonical
    id: a fresh session absorbs the old pane(s)."""
    a = SessionWindowAssigner(0.1)
    sessions = []
    a.fold(sessions, 0.30)
    sess, absorbed, extended, created = a.fold(sessions, 0.25)
    assert created and extended and len(sessions) == 1
    assert sess["wid"] == a.wid_of(0.25)
    assert [x["wid"] for x in absorbed] == [a.wid_of(0.30)]
    assert sess["end"] == pytest.approx(0.40)


def test_session_op_rejects_fused_plane():
    eng = Engine()
    with pytest.raises(ValueError):
        SessionWindowedOp(
            eng, "s", 1, SessionWindowAssigner(1.0),
            lambda t, a: (a or 0) + 1, lambda *a: None,
            IN_MEMORY, 10_000, fused=object(), state_size=100)


# ------------------------------------------------- engine-level pipelines
class _CollectSink(SinkOp):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.got = []

    def process(self, sub, tup):
        self.got.append((tup.key, tup.payload))
        return super().process(sub, tup)


def _session_pipeline(eng, gen, oo_bound, lateness=0.0,
                      late_policy="drop", gap=GAP, rate=2000.0):
    src = eng.add(SourceOp(eng, "src", 1, rate, gen,
                           watermark_interval=0.05, oo_bound=oo_bound))
    win = eng.add(SessionWindowedOp(
        eng, "win", 1, SessionWindowAssigner(gap),
        agg_fn=lambda tup, acc: (acc or 0) + 1,
        emit_fn=lambda key, wid, end, acc: ("count", key, wid, acc),
        merge_fn=lambda a, b: (a or 0) + (b or 0),
        backend_model=IN_MEMORY, cache_capacity=1_000_000,
        allowed_lateness=lateness, late_policy=late_policy,
        policy="tac", mode="sync", state_size=100))
    sink = eng.add(_CollectSink(eng, "sink", 1))
    eng.connect(src, win)
    eng.connect(win, sink, partition=lambda k, n: 0)
    return win, sink


def test_bridging_tuple_never_loses_either_sides_state():
    """Two fired-apart clusters merged by a late bridging tuple: the
    surviving pane's count equals ALL five contributions — the two-step
    drain/absorb protocol preserved the absorbed pane's accumulator."""
    eng = Engine()
    script = [0.10, 0.15, 0.30, 0.35, 0.22]      # bridge arrives LAST
    state = {"n": 0}

    def gen(now):
        i = state["n"]
        state["n"] += 1
        if i < len(script):
            return (0, {}, 100, script[i])
        return (1, {}, 100, now)                 # filler drives the wm

    win, sink = _session_pipeline(eng, gen, oo_bound=0.25)
    eng.run(duration=1.0)
    a = SessionWindowAssigner(GAP)
    fired = {(k, wid): n for k, (_, _, wid, n) in sink.got}
    assert fired[(0, a.wid_of(0.10))] == len(script)
    assert (0, a.wid_of(0.30)) not in fired      # absorbed pane never fired
    assert win.sessions_merged == 1
    assert win.merge_drains == win.merge_absorbs == 1
    assert win.late_dropped == 0


def test_late_tuple_inside_lateness_reopens_session():
    """Aion-style late-side update: a tuple landing in a FIRED session
    within the lateness horizon re-opens it, and the re-fire carries the
    refreshed accumulator."""
    eng = Engine()
    state = {"n": 0, "late_sent": False}

    def gen(now):
        i = state["n"]
        state["n"] += 1
        if i == 0:
            return (0, {}, 100, 0.10)
        if i == 1:
            return (0, {}, 100, 0.15)            # session [0.10, 0.25)
        if now > 0.35 and not state["late_sent"]:
            state["late_sent"] = True
            return (0, {}, 100, 0.20)            # late, inside lateness
        # filler ts runs AHEAD of the wm it drives, so key 1's own
        # session never fires and adds no reopen/drop noise
        return (1, {}, 100, now + 0.15)

    win, sink = _session_pipeline(eng, gen, oo_bound=0.0, lateness=0.3,
                                  late_policy="update")
    eng.run(duration=1.0)
    a = SessionWindowAssigner(GAP)
    wid = a.wid_of(0.10)
    emits = [n for k, (_, _, w, n) in sink.got if k == 0 and w == wid]
    assert emits == [2, 3]                       # fire, then refreshed refire
    assert win.sessions_reopened == 1
    assert win.late_dropped == 0


def test_drop_policy_discards_late_tuple_on_fired_session():
    eng = Engine()
    state = {"n": 0, "late_sent": False}

    def gen(now):
        i = state["n"]
        state["n"] += 1
        if i == 0:
            return (0, {}, 100, 0.10)
        if now > 0.35 and not state["late_sent"]:
            state["late_sent"] = True
            return (0, {}, 100, 0.12)
        return (1, {}, 100, now + 0.15)

    win, sink = _session_pipeline(eng, gen, oo_bound=0.0, lateness=0.0,
                                  late_policy="drop")
    eng.run(duration=1.0)
    a = SessionWindowAssigner(GAP)
    emits = [n for k, (_, _, w, n) in sink.got
             if k == 0 and w == a.wid_of(0.10)]
    assert emits == [1]
    assert win.late_dropped >= 1
    assert win.sessions_reopened == 0


# ------------------------------------------- q11 + moving-deadline hints
def test_q11_session_query_moving_deadline_hints():
    """The NEXMark session query end to end under prefetching: sessions
    merge, the lookahead RE-HINTS moved deadlines (bypassing admission),
    and panes prefetch ahead of their fires."""
    cfg = NexmarkConfig(rate=3000, oo_bound=0.2, seed=7,
                        watermark_interval=0.05)
    eng = build_query("q11", "tac", "prefetch", cfg, cache_entries=512,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, session_gap=0.4)
    m = eng.run(duration=1.5, warmup=0.5)
    assert m["stateful_fires"] > 0
    assert m["stateful_sessions_created"] > 0
    assert m["sess_lookahead_rehints"] > 0       # deadlines MOVED
    assert m["stateful_hints_received"] > 0
    assert m["stateful_prefetch_hits"] > 0
    assert m["n_outputs"] > 0
    # both mirrored registries fold the same rule in lockstep
    st_op = eng.operators["stateful"]
    assert st_op.late_dropped == 0


def test_q11_requires_out_of_orderness():
    cfg = NexmarkConfig(rate=1000, oo_bound=0.0)
    with pytest.raises(ValueError):
        build_query("q11", "tac", "prefetch", cfg)
