"""Observability-plane tests (DESIGN.md §12): registry/sketch
exactness, zero-cost disabled handles, deterministic per-hint outcome
accounting, TAC eviction-reason splits, critical-path tracing, and the
live-name-vs-catalog contract.

Quick by design: the only engine run is a sub-second q5 smoke.
"""
import json
import math

import pytest

from repro.core.tac import TimestampAwareCache
from repro.obs import (METRIC_CATALOG, MetricsRegistry, NULL_COUNTER,
                       NULL_GAUGE, NULL_HISTOGRAM, PrefetchRecorder,
                       QuantileSketch, STAGES, Tracer, TupleTrace,
                       matches_catalog)


# ------------------------------------------------------------ sketch
def test_sketch_exact_moments():
    sk = QuantileSketch()
    vals = [0.001, 0.002, 0.004, 0.008, 0.5, 1.0, -0.25, 0.0]
    for v in vals:
        sk.observe(v)
    assert sk.count == len(vals)
    assert sk.total == pytest.approx(sum(vals))
    assert sk.vmin == -0.25 and sk.vmax == 1.0
    assert sk.mean == pytest.approx(sum(vals) / len(vals))


def test_sketch_quantile_relative_error():
    sk = QuantileSketch()
    n = 5000
    for i in range(1, n + 1):
        sk.observe(i / 1000.0)              # 1ms .. 5s uniform
    for q in (0.5, 0.9, 0.99):
        exact = q * n / 1000.0
        assert sk.quantile(q) == pytest.approx(exact, rel=0.03)
    # quantiles clamp to observed extremes
    assert sk.quantile(0.0) >= sk.vmin
    assert sk.quantile(1.0) <= sk.vmax


def test_sketch_signed_values_and_merge():
    a, b = QuantileSketch(), QuantileSketch()
    for v in (-0.010, -0.002, 0.003):
        a.observe(v)
    for v in (0.050, 0.200):
        b.observe(v)
    a.merge(b)
    assert a.count == 5
    assert a.vmin == -0.010 and a.vmax == 0.200
    assert a.quantile(0.01) < 0 < a.quantile(0.99)


# ---------------------------------------------------------- registry
def test_registry_typed_instruments_and_snapshot():
    r = MetricsRegistry()
    r.counter("engine.sink.count").inc(3)
    r.gauge("engine.cpu.util").set(0.5)
    r.histogram("engine.sink.latency").observe(0.004)
    assert r.counter("engine.sink.count").value == 3     # memoized handle
    snap = r.snapshot()
    assert snap["engine.sink.count"] == 3
    assert snap["engine.cpu.util"] == 0.5
    assert snap["engine.sink.latency"]["count"] == 1


def test_registry_disabled_hands_out_shared_noops():
    r = MetricsRegistry(enabled=False)
    assert r.counter("x.y") is NULL_COUNTER
    assert r.gauge("x.y") is NULL_GAUGE
    assert r.histogram("x.y") is NULL_HISTOGRAM
    NULL_COUNTER.inc()
    NULL_GAUGE.set(1.0)
    NULL_HISTOGRAM.observe(2.0)             # all no-ops, no state
    assert r.snapshot() == {}


def test_registry_export_jsonl(tmp_path):
    r = MetricsRegistry()
    r.counter("engine.sink.count").inc()
    path = tmp_path / "snap.jsonl"
    r.export_jsonl(str(path), t=1.0)
    r.counter("engine.sink.count").inc()
    r.export_jsonl(str(path), t=2.0)
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert [x["t"] for x in lines] == [1.0, 2.0]
    assert lines[1]["metrics"]["engine.sink.count"] == 2


def test_catalog_template_matching():
    assert matches_catalog("engine.sink.latency")
    assert matches_catalog("engine.stateful.prefetch.lead")
    assert matches_catalog("engine.join.evict.capacity.prefetched")
    assert matches_catalog("engine.stateful.shard.7.hints_routed")
    assert matches_catalog("trace.stage.park_wait")
    assert not matches_catalog("engine.nope")
    assert not matches_catalog("engine.stateful.evict.capacity")  # arity
    assert not matches_catalog("made.up.metric")


# ------------------------------------------- hint outcomes (recorder)
def test_recorder_outcomes_and_signed_leads():
    clock = [0.0]
    r = MetricsRegistry()
    rec = PrefetchRecorder(r, "engine.op", lambda: clock[0])
    cache = TimestampAwareCache(capacity=2)
    cache.recorder = rec

    # staged at t=1.0, first read at t=1.5 -> used, lead +0.5
    clock[0] = 1.0
    cache.insert("a", "A", ts=1.0, prefetched=True)
    clock[0] = 1.5
    assert cache.lookup("a", 1.5) == "A"
    # second read must NOT double-count the use
    cache.lookup("a", 1.6)
    # staged, never read, evicted by capacity -> wasted
    clock[0] = 2.0
    cache.insert("b", "B", ts=0.5, prefetched=True)
    cache.insert("c", "C", ts=3.0)          # demand; evicts min-ts "b"
    cache.insert("d", "D", ts=4.0)          # evicts "a" (used, not wasted)
    # late staging: the tuple parked at t=5.0, staging completed at 5.4
    clock[0] = 5.4
    rec.on_late(first_need_t=5.0)

    assert rec.staged.value == 2
    assert rec.used.value == 1
    assert rec.wasted.value == 1
    assert rec.late.value == 1
    sk = rec.lead.sketch
    assert sk.count == 2                    # one used + one late
    assert sk.vmax == pytest.approx(0.5)    # timely lead
    assert sk.vmin == pytest.approx(-0.4)   # late lead is negative

    q = rec.quality_block(prefetch_hits=3, demand_fetches=1,
                          duplicates=2, late_wm=1)
    assert q["staged"] == 2 and q["used"] == 1 and q["wasted"] == 1
    assert q["late"] == 1 and q["duplicate"] == 2
    assert q["late_watermark"] == 1
    assert q["precision"] == pytest.approx(1 / 3)   # used/(staged+late)
    assert q["recall"] == pytest.approx(3 / 4)
    assert q["lead_min"] == pytest.approx(-0.4)
    assert q["lead_max"] == pytest.approx(0.5)


def test_eviction_reason_split_capacity():
    cache = TimestampAwareCache(capacity=2)
    cache.insert("a", 1, ts=1.0, prefetched=True)
    cache.insert("b", 2, ts=2.0)
    cache.insert("c", 3, ts=3.0)            # evicts "a" (prefetched)
    cache.insert("d", 4, ts=4.0)            # evicts "b" (demand)
    assert cache.eviction_block() == {"capacity.demand": 1,
                                      "capacity.prefetched": 1}


def test_eviction_reason_split_deadline_and_stale():
    cache = TimestampAwareCache(capacity=2, deadline_aware=True)
    cache.set_clock(5.0)
    cache.insert("stale", 1, ts=1.0)        # behind the clock
    cache.insert("near", 2, ts=6.0, prefetched=True)
    cache.insert("far", 3, ts=9.0)          # evicts "stale" first
    assert cache.eviction_block() == {"stale.demand": 1}
    cache.insert("mid", 4, ts=7.0)          # all live: farthest ("far") goes
    assert cache.eviction_block() == {"stale.demand": 1,
                                      "deadline.demand": 1}


# ------------------------------------------------------------ tracer
def test_trace_stage_decomposition():
    tr = TupleTrace(t0=0.0)
    tr.mark_state("op", 0.010)
    tr.mark_park(0.010)
    tr.mark_resume(0.014)
    tr.fetch_s += 0.002
    tr.mark_apply(0.015)
    st = tr.stages(t_sink=0.020)
    assert st["upstream"] == pytest.approx(0.010)
    assert st["park_wait"] == pytest.approx(0.004)
    assert st["sync_fetch"] == pytest.approx(0.002)
    assert st["downstream"] == pytest.approx(0.005)
    assert set(st) == set(STAGES)


def test_tracer_sampling_and_summary():
    r = MetricsRegistry()
    t = Tracer(r)
    assert not t.active
    assert t.maybe_start(0.0) is None       # disabled: never samples
    t.enable(sample_every=4)
    traces = [t.maybe_start(i * 0.1) for i in range(8)]
    live = [x for x in traces if x is not None]
    assert len(live) == 2                   # exactly 1 in 4
    for tr in live:
        tr.mark_state("op", tr.t0 + 0.001)
        tr.hit = True
        t.finish(tr, tr.t0 + 0.003)
        t.finish(tr, tr.t0 + 9.9)           # double-finish is a no-op
    s = t.summary()
    assert s["sampled"] == 2 and s["finished"] == 2
    assert s["probe_hits"] == 2 and s["probe_misses"] == 0
    assert s["dominant_stage"] in STAGES
    assert sum(s[x]["share"] for x in STAGES) == pytest.approx(1.0)
    assert len(t.spans) == 2


# ---------------------------------------------- device-side counters
def test_tac_probe_counted_matches_host_tally():
    jnp = pytest.importorskip("jax.numpy")
    import numpy as np
    from repro.kernels.tac_probe.ops import (bucket_of, tac_probe_counted)

    n_buckets, ways = 8, 2
    keys = jnp.full((n_buckets, ways), -1, jnp.int32)
    vals = jnp.zeros((n_buckets, ways, 1), jnp.int32)
    resident = jnp.asarray([3, 7, 11, 19], jnp.int32)
    b = np.asarray(bucket_of(resident, n_buckets))
    keys_np = np.asarray(keys).copy()
    for i, k in enumerate(np.asarray(resident)):
        w = int(np.argmax(keys_np[b[i]] == -1))
        keys_np[b[i], w] = k
    keys = jnp.asarray(keys_np)
    queries = jnp.asarray([3, 7, 5, 19, 23, 11], jnp.int32)
    _, hit, _, counts = tac_probe_counted(queries, keys, vals)
    hit = np.asarray(hit).astype(bool)
    qb = np.asarray(bucket_of(queries, n_buckets))
    full = np.all(keys_np[qb] != -1, axis=1)
    assert int(counts[0]) == int(hit.sum())
    assert int(counts[1]) == int((~hit & full).sum())


# ------------------------------------------- live engine integration
@pytest.fixture(scope="module")
def q5_metrics():
    from repro.streaming.backend import LOCAL_NVME
    from repro.streaming.nexmark import NexmarkConfig, build_query
    cfg = NexmarkConfig(rate=2_000.0, active_window=1.0, oo_bound=0.3,
                        seed=7)
    eng = build_query("q5", "tac", "prefetch", cfg, cache_entries=128,
                      backend=LOCAL_NVME, parallelism=2,
                      source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, hint_ts="deadline",
                      window_size=0.5, window_slide=0.25)
    eng.enable_tracing(sample_every=8)
    m = eng.run(duration=1.2, warmup=0.4)
    return eng, m


def test_live_names_all_catalogued(q5_metrics):
    eng, _ = q5_metrics
    uncatalogued = [n for n in eng.registry.names()
                    if not matches_catalog(n)]
    assert uncatalogued == [], uncatalogued


def test_live_hint_quality_block(q5_metrics):
    _, m = q5_metrics
    hq = m["stateful_hint_quality"]
    assert hq["staged"] > 0
    assert hq["used"] > 0
    assert 0.0 < hq["precision"] <= 1.0
    assert 0.0 < hq["recall"] <= 1.0
    # outcomes partition issued stagings
    assert hq["used"] + hq["wasted"] + hq["resident_unused"] \
        == hq["staged"]
    assert "lead_p50" in hq and "lead_p99" in hq
    assert m["stateful_hints_duplicate"] >= 0
    assert m["stateful_access_p99"] >= m["stateful_access_p50"] >= 0.0


def test_live_trace_and_eviction_split(q5_metrics):
    _, m = q5_metrics
    tr = m["trace"]
    assert tr["finished"] > 0
    assert tr["dominant_stage"] in STAGES
    assert sum(tr[s]["share"] for s in STAGES) == pytest.approx(1.0)
    ev = m["stateful_evictions"]
    assert ev and all("." in k for k in ev)
    for k in ev:
        reason, adm = k.split(".")
        assert reason in ("capacity", "deadline", "stale")
        assert adm in ("prefetched", "demand")
    assert m["stateful_watermark_lag"] >= 0.0


def test_live_sink_percentiles_from_sketch(q5_metrics):
    eng, m = q5_metrics
    # percentiles come from the uncapped sketch, not the recent window
    assert 0.0 < m["p50"] <= m["p99"] <= m["p999"] <= m["max"]
    assert m["n_outputs"] == eng._sink_count.value
    assert eng._sink_hist.sketch.count == m["n_outputs"]
    assert math.isfinite(m["throughput"]) and m["throughput"] > 0


def test_catalog_descriptions_nonempty():
    assert len(METRIC_CATALOG) >= 40
    for tmpl, desc in METRIC_CATALOG.items():
        assert desc.strip(), tmpl
