"""Event-time windowing + watermark propagation tests (DESIGN.md §10).

Quick by design (sub-second discrete-event runs): these belong to the
tier-1 loop, unlike the full-duration sims in test_streaming.py.
"""
import math

import pytest

from repro.core.tac import TimestampAwareCache
from repro.streaming.backend import IN_MEMORY, LOCAL_NVME
from repro.streaming.engine import Engine, MapOp, SinkOp, SourceOp
from repro.streaming.events import Tuple_, Watermark, WindowKey
from repro.streaming.nexmark import NexmarkConfig, build_query
from repro.streaming.windows import (WindowAssigner, WindowedLookaheadOp,
                                     WindowedStatefulOp)


# ------------------------------------------------------------- assigner
def test_window_assigner_tumbling():
    a = WindowAssigner(2.0)
    assert a.assign(3.5) == [1]
    assert a.assign(0.0) == [0]
    assert a.end(1) == 4.0 and a.start(1) == 2.0


def test_window_assigner_sliding():
    a = WindowAssigner(4.0, 1.0)
    assert a.assign(3.5) == [3, 2, 1, 0]
    assert a.end(3) == 7.0 and a.start(3) == 3.0
    with pytest.raises(ValueError):
        WindowAssigner(1.0, 2.0)          # slide > size


# ------------------------------------------- deadline-aware TAC eviction
def test_tac_deadline_aware_eviction_order():
    """Stale entries (ts behind the watermark clock) evict oldest-first;
    among live deadlines the FARTHEST goes first (Belady), so the pane
    firing next stays resident."""
    c = TimestampAwareCache(3, deadline_aware=True)
    c.set_clock(10.0)
    c.insert("stale", 1, 5.0)
    c.insert("soon", 1, 12.0)
    c.insert("far", 1, 20.0)
    c.insert("x", 1, 15.0)               # needs room: stale goes first
    assert not c.contains("stale")
    c.insert("y", 1, 13.0)               # all live: farthest (20) goes
    assert not c.contains("far")
    assert c.contains("soon") and c.contains("x") and c.contains("y")


def test_tac_default_order_unchanged():
    c = TimestampAwareCache(2)
    c.insert("a", 1, 10.0)
    c.insert("b", 1, 20.0)
    c.insert("c", 1, 15.0)               # min-ts (a) evicted, paper §IV-D
    assert not c.contains("a")
    assert c.contains("b") and c.contains("c")


def test_tac_drop_removes_without_writeback():
    c = TimestampAwareCache(10)
    c.write("k", {"v": 1}, 1.0)          # dirty
    assert c.drop("k") and not c.contains("k")
    assert c.pop_writeback() is None     # nothing staged for write-back
    assert not c.drop("k")


# --------------------------------------------------- watermark propagation
def _noop_gen(now):
    return (0, {"v": 1}, 100)


def test_watermark_min_of_inputs():
    """A multi-input operator advances to the MINIMUM of its inputs'
    watermarks, only after every input has reported."""
    eng = Engine()
    a = eng.add(SourceOp(eng, "a", 1, 2000.0, _noop_gen,
                         watermark_interval=0.02, oo_bound=0.05))
    b = eng.add(SourceOp(eng, "b", 1, 2000.0, _noop_gen,
                         watermark_interval=0.02, oo_bound=0.30))
    m = eng.add(MapOp(eng, "m", 2))
    sink = eng.add(SinkOp(eng, "sink", 1))
    eng.connect(a, m)
    eng.connect(b, m)
    eng.connect(m, sink)
    eng.run(duration=1.0)
    for s in range(m.parallelism):
        # bounded by the laggard input (oo_bound=0.30), not the fast one
        assert m.wm[s] > float("-inf")
        assert m.wm[s] <= 1.0 - 0.30 + 0.001
        assert m.wm[s] >= 0.5 - 0.30
    # and it propagates downstream (min-of-inputs again at the sink)
    assert sink.wm[0] > float("-inf")
    assert sink.wm[0] <= m.wm[0]


def test_watermark_held_back_until_all_inputs_report():
    """An input that never emits watermarks pins downstream at -inf."""
    eng = Engine()
    a = eng.add(SourceOp(eng, "a", 1, 2000.0, _noop_gen,
                         watermark_interval=0.02))
    b = eng.add(SourceOp(eng, "b", 1, 2000.0, _noop_gen))   # no watermarks
    m = eng.add(MapOp(eng, "m", 1))
    eng.connect(a, m)
    eng.connect(b, m)
    eng.run(duration=0.5)
    assert m.wm[0] == float("-inf")


# ----------------------------------------------------- windowed correctness
class _CollectSink(SinkOp):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.got = []

    def process(self, sub, tup):
        self.got.append((tup.key, tup.payload))
        return super().process(sub, tup)


def _count_pipeline(eng, assigner, emitted, rate=2000.0, lateness=0.0,
                    late_policy="drop", gen=None):
    def default_gen(now):
        k = int(now * 1000) % 5
        emitted.append((now, k))
        return (k, {"k": k}, 100)

    src = eng.add(SourceOp(eng, "src", 1, rate, gen or default_gen,
                           watermark_interval=0.05, oo_bound=0.0))
    win = eng.add(WindowedStatefulOp(
        eng, "win", 1, assigner,
        agg_fn=lambda tup, acc: (acc or 0) + 1,
        emit_fn=lambda key, wid, end, acc: ("count", key, wid, acc),
        backend_model=IN_MEMORY, cache_capacity=1_000_000,
        allowed_lateness=lateness, late_policy=late_policy,
        policy="tac", mode="sync", state_size=100))
    sink = eng.add(_CollectSink(eng, "sink", 1))
    eng.connect(src, win)
    eng.connect(win, sink, partition=lambda k, n: 0)
    return win, sink


def test_tumbling_fire_counts_are_exact():
    """Every fired pane's count equals the number of source tuples whose
    event time fell in that (key, window)."""
    eng = Engine()
    assigner = WindowAssigner(0.2)
    emitted = []
    win, sink = _count_pipeline(eng, assigner, emitted)
    eng.run(duration=1.2)
    fired = {(k, wid): n for k, (_, _, wid, n) in
             ((key, payload) for key, payload in sink.got)}
    assert fired, "no windows fired"
    expected = {}
    for ts, k in emitted:
        wid = math.floor(ts / 0.2)
        expected[(k, wid)] = expected.get((k, wid), 0) + 1
    for (k, wid), n in fired.items():
        assert expected.get((k, wid)) == n, (k, wid)
    # zero lateness: every fired pane purged, state fully reclaimed
    assert win.panes_purged == win.fires == len(sink.got)
    assert len(win.caches[0].entries) <= 5 * 2   # only unfired panes left


def test_late_tuples_dropped_and_counted():
    eng = Engine()
    assigner = WindowAssigner(0.1)
    emitted = []
    state = {"n": 0}

    def gen(now):
        state["n"] += 1
        ts = now - 0.5 if state["n"] % 40 == 0 else now   # 2.5% very late
        k = state["n"] % 5
        emitted.append((ts, k))
        return (k, {"k": k}, 100, ts)

    win, sink = _count_pipeline(eng, assigner, emitted, gen=gen)
    eng.run(duration=1.0)
    assert win.late_dropped > 0
    assert win.fires > 0


def test_late_tuples_update_path_re_emits():
    eng = Engine()
    assigner = WindowAssigner(0.1)
    emitted = []
    state = {"n": 0}

    def gen(now):
        state["n"] += 1
        # late by 0.15: within allowed_lateness=0.3 of recent windows
        ts = now - 0.15 if state["n"] % 20 == 0 else now
        k = state["n"] % 5
        emitted.append((ts, k))
        return (k, {"k": k}, 100, ts)

    win, sink = _count_pipeline(eng, assigner, emitted, lateness=0.3,
                                late_policy="update", gen=gen)
    eng.run(duration=1.0)
    assert win.late_updates > 0
    assert win.late_dropped == 0
    # late-side updates add outputs beyond one-per-fire
    assert len(sink.got) > win.fires - 10
    assert win.panes_purged > 0          # horizon purge pass ran


def test_update_policy_requires_lateness():
    eng = Engine()
    with pytest.raises(ValueError):
        WindowedStatefulOp(eng, "w", 1, WindowAssigner(1.0),
                           lambda t, a: a, lambda *a: None,
                           IN_MEMORY, 100, allowed_lateness=0.0,
                           late_policy="update")


# --------------------------------------------- hints + prefetch integration
def test_deadline_hints_drive_prefetch_and_burst():
    cfg = NexmarkConfig(rate=3000, active_window=1.0, oo_bound=0.2, seed=7)
    eng = build_query("q7", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, window_size=0.5)
    m = eng.run(duration=1.5, warmup=0.5)
    assert m["stateful_hints_received"] > 0
    assert m["stateful_fires"] > 0
    assert m["win_lookahead_burst_hints"] > 0
    assert m["stateful_prefetch_hits"] > 0
    assert m["n_outputs"] > 0
    # hint keys are panes: the windowed lookahead is the active candidate
    assert eng.controller.active["stateful"] == "win_lookahead"


def test_windowed_query_requires_out_of_orderness():
    cfg = NexmarkConfig(rate=1000, oo_bound=0.0)
    with pytest.raises(ValueError):
        build_query("q5", "tac", "prefetch", cfg)


# ------------------------------------------------------------- shard plane
def test_watermark_forwarding_on_shard_plane():
    """Watermarks broadcast to every subtask of a shard-routed windowed
    operator, and windows fire on all owners."""
    cfg = NexmarkConfig(rate=3000, active_window=1.0, oo_bound=0.2, seed=7)
    eng = build_query("q7", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, window_size=0.5, n_shards=8)
    m = eng.run(duration=1.5, warmup=0.5)
    st = eng.operators["stateful"]
    assert all(w > float("-inf") for w in st.wm)
    assert m["stateful_fires"] > 0
    plane = m["stateful_shard_plane"]
    assert sum(plane["tuples_routed"]) > 0
    assert sum(plane["hints_routed"]) > 0
    assert m["n_outputs"] > 0


def test_windowed_migration_moves_live_windows():
    """Mid-run shard migration on a windowed operator: pane state AND the
    live-window registrations move, so fires continue at the new owner."""
    cfg = NexmarkConfig(rate=3000, active_window=1.0, oo_bound=0.2, seed=7)
    eng = build_query("q7", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, window_size=0.5, n_shards=8)
    eng.migrate_shard("stateful", 0, 1, at=0.9)
    m = eng.run(duration=1.6, warmup=0.5)
    st = eng.operators["stateful"]
    assert st.shards.migrations == 1
    assert m["stateful_fires"] > 0
    assert m["n_outputs"] > 0


def test_parked_tuple_resuming_after_fire_does_not_duplicate_output():
    """An on-time tuple that parked on a state fetch across its window's
    fire must not take the late-update emit path under drop policy (it
    would duplicate the pane result); under update policy it emits one
    late-side refresh."""
    eng = Engine()

    def mk(name, **kw):
        win = WindowedStatefulOp(
            eng, name, 1, WindowAssigner(1.0),
            lambda t, a: (a or 0) + 1,
            lambda k, wid, end, acc: ("c", k, acc),
            IN_MEMORY, 10_000, policy="tac", mode="async",
            state_size=100, **kw)
        outs = []
        win.emit = lambda sub, msg: outs.append(msg)
        win.windows[0][0] = {"keys": {7}, "fired": True,
                             "fired_keys": {7}}
        return win, outs

    wk = WindowKey(7, 0)
    drop, outs = mk("w_drop")
    drop._apply(0, Tuple_(0.5, wk, {"k": 7}, 100, 0.4), 1)
    assert outs == [] and drop.late_dropped == 1

    upd, outs = mk("w_upd", allowed_lateness=0.5, late_policy="update")
    upd._apply(0, Tuple_(0.5, wk, {"k": 7}, 100, 0.4), 1)
    assert len(outs) == 1 and upd.late_updates == 1


def test_migration_merges_fired_state_per_key():
    """Watermark skew across a migration can merge fired and unfired pane
    populations of the SAME window: the moved unfired keys must still
    fire at the destination, and already-fired keys must not refire."""
    from repro.streaming.shards import ShardPlane
    eng = Engine()
    plane = ShardPlane(4, 2)
    win = WindowedStatefulOp(
        eng, "w", 2, WindowAssigner(1.0),
        lambda t, a: (a or 0) + 1, lambda k, wid, end, acc: ("c", k, acc),
        IN_MEMORY, 10_000, policy="tac", mode="sync", shards=plane)
    # keys 0/4 live in shard 0 (owner sub 0), key 1 in shard 1 (sub 1)
    win.windows[0][5] = {"keys": {0, 4}, "fired": False,
                         "fired_keys": set()}
    win.windows[1][5] = {"keys": {1}, "fired": True, "fired_keys": {1}}
    win.migrate_shard(0, 1)
    assert 5 not in win.windows[0]
    d = win.windows[1][5]
    assert d["keys"] == {0, 1, 4}
    assert d["fired_keys"] == {1}        # moved keys stay fire-eligible
    batches = []
    win.deliver_batch = lambda sub, batch: batches.append((sub, batch))
    win.on_watermark(1, 6.0)             # dst watermark crosses end(5)=6
    fired = {t.key.base for _, b in batches for t in b}
    assert fired == {0, 4}               # key 1 not refired
    assert d["fired_keys"] == {0, 1, 4}


def test_hash_partition_unwraps_window_keys():
    from repro.streaming.shards import hash_partition
    assert hash_partition(WindowKey(42, 7), 8) == hash_partition(42, 8)
    assert hash_partition(WindowKey(("a", 1), 3), 4) == \
        hash_partition(("a", 1), 4)
