"""Tests for the paged session-state serving subsystem.

Covers the ISSUE acceptance points: batched-admit equivalence vs the
sequential ``tac_jax.admit`` scan, eviction write-back of dirty pages
through the tiered store, arena-backed paged attention (see also
test_integration_tac_paged.py), and the scheduler's sync/async/prefetch
TTFT ordering under equal offered load.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tac_jax
from repro.serving import (ContinuousBatchingScheduler, PagedStateArena,
                           Request, ServingMetrics, SimClock, TieredStore,
                           percentiles)


# ------------------------------------------------------------- admit_batch
@pytest.mark.parametrize("seed", range(4))
def test_admit_batch_matches_sequential_admit(seed):
    """admit_batch must equal the lax.scan admit on any trace, including
    duplicate keys and same-bucket collisions (resolved in batch order)."""
    rng = np.random.RandomState(seed)
    nb, ways, D = (1, 4, 2) if seed % 2 else (4, 3, 2)
    state_seq = tac_jax.init(nb, ways, D)
    state_bat = tac_jax.init(nb, ways, D)
    for _ in range(3):                       # successive batches compose too
        B = rng.randint(1, 16)
        keys = jnp.asarray(rng.randint(0, 12, B), jnp.int32)
        ts = jnp.asarray(rng.uniform(1, 100, B), jnp.float32)
        vals = jnp.asarray(rng.randn(B, D), jnp.float32)
        dirty = jnp.asarray(rng.rand(B) < 0.5)
        state_seq = tac_jax.admit(state_seq, keys, ts, vals, dirty)
        res = tac_jax.admit_batch(state_bat, keys, ts, vals, dirty)
        state_bat = res.state
    np.testing.assert_array_equal(np.asarray(state_seq.keys),
                                  np.asarray(state_bat.keys))
    np.testing.assert_allclose(np.asarray(state_seq.ts),
                               np.asarray(state_bat.ts))
    np.testing.assert_array_equal(np.asarray(state_seq.dirty),
                                  np.asarray(state_bat.dirty))
    np.testing.assert_allclose(np.asarray(state_seq.vals),
                               np.asarray(state_bat.vals))


def test_admit_batch_reports_slots_and_victims():
    state = tac_jax.init(1, 2, 1)
    res = tac_jax.admit_batch(state, jnp.asarray([1, 2], jnp.int32),
                              jnp.asarray([10.0, 20.0]))
    assert set(np.asarray(res.slots).tolist()) == {0, 1}
    assert (np.asarray(res.evicted_keys) == -1).all()
    # bucket full: admitting key 3 must displace min-ts key 1
    res2 = tac_jax.admit_batch(res.state, jnp.asarray([3], jnp.int32),
                               jnp.asarray([30.0]))
    assert list(np.asarray(res2.evicted_keys)) == [1]


# ------------------------------------------------------------------- arena
def test_arena_stage_gather_roundtrip():
    arena = PagedStateArena(4, 2, {"state": ((8, 4), jnp.float32)})
    rng = np.random.RandomState(0)
    keys = np.asarray([3, 9, 17], np.int32)
    blocks = rng.randn(3, 8, 4).astype(np.float32)
    adm = arena.admit(keys, np.asarray([1.0, 2.0, 3.0], np.float32))
    arena.stage(adm.slots, {"state": jnp.asarray(blocks)})
    hit, slots = arena.probe(keys)
    assert hit.all()
    np.testing.assert_array_equal(slots, adm.slots)
    got = np.asarray(arena.gather(jnp.asarray(slots))["state"])
    np.testing.assert_allclose(got, blocks)


def test_arena_eviction_surfaces_dirty_victims_with_contents():
    """A dirty page displaced by admission must come back (key, dirty bit,
    page contents gathered BEFORE restaging overwrites the slot)."""
    arena = PagedStateArena(1, 2, {"state": ((4, 2), jnp.float32)})
    rng = np.random.RandomState(1)
    k01 = np.asarray([1, 2], np.int32)
    blocks = rng.randn(2, 4, 2).astype(np.float32)
    adm = arena.admit(k01, np.asarray([10.0, 20.0], np.float32))
    arena.stage(adm.slots, {"state": jnp.asarray(blocks)})
    arena.mark_dirty(np.asarray([1], np.int32))      # decode mutated page 1
    adm2 = arena.admit(np.asarray([5], np.int32),
                       np.asarray([30.0], np.float32))
    assert list(adm2.evicted_keys) == [1]
    assert list(adm2.evicted_dirty) == [True]
    victim = np.asarray(adm2.evicted_blocks["state"][0])
    np.testing.assert_allclose(victim, blocks[0])    # pre-overwrite contents


def test_arena_flush_dirty_clears_and_returns_pages():
    arena = PagedStateArena(2, 2, {"state": ((4, 1), jnp.float32)})
    keys = np.asarray([1, 2, 3], np.int32)
    adm = arena.admit(keys, np.ones(3, np.float32))
    arena.stage(adm.slots, {"state": jnp.ones((3, 4, 1))})
    arena.mark_dirty(keys[:2])
    fkeys, blocks = arena.flush_dirty()
    assert set(fkeys.tolist()) == {1, 2}
    assert blocks["state"].shape[0] == 2
    fkeys2, _ = arena.flush_dirty()
    assert fkeys2.size == 0                          # bits cleared


# ------------------------------------------------------------ tiered store
def test_store_writeback_then_restage_roundtrips_content():
    store = TieredStore(page_bytes=64, workers=2)
    store.seed(7, {"state": np.zeros((2, 2), np.float32)})
    newer = {"state": np.ones((2, 2), np.float32)}
    store.writeback(7, newer)                        # dirty victim
    store.request_stage([7], now=0.0)
    done = store.poll(now=10.0)
    assert len(done) == 1
    np.testing.assert_allclose(done[0][1]["state"], newer["state"])
    assert store.persist() == 1                      # host -> backing flush
    blocks, _ = store.backing.fetch(7)
    np.testing.assert_allclose(blocks["state"], newer["state"])


def test_store_async_staging_hides_latency_sync_charges_it():
    store = TieredStore(page_bytes=1024, workers=4)
    for k in (1, 2, 3):
        store.seed(k, {"state": np.float32(k)})
    store.request_stage([1, 2], now=0.0)
    assert store.poll(now=0.0) == []                 # I/O still in flight
    assert len(store.poll(now=1.0)) == 2
    _, lat = store.fetch_sync([3], now=1.0)
    assert lat > 0.0
    s = store.stats()
    assert s["store_hidden_latency"] > 0
    assert s["store_critical_latency"] == pytest.approx(lat)
    assert 0.0 < s["staging_overlap"] < 1.0


# --------------------------------------------------------------- scheduler
def _run_mode(mode, n_requests=24, rate=2000.0, decode_s=0.8e-3):
    arena = PagedStateArena(6, 2, {"state": ((4, 2), jnp.float32)})
    store = TieredStore(page_bytes=32 * 1024, workers=4)
    rng = np.random.RandomState(0)
    n_sessions, pages_per = 8, 3

    def pkeys(sid):
        return np.asarray([sid * 64 + p + 1 for p in range(pages_per)],
                          np.int32)

    for sid in range(n_sessions):
        for k in pkeys(sid):
            store.seed(int(k), {"state": np.zeros((4, 2), np.float32)})
    clock = SimClock()
    sched = ContinuousBatchingScheduler(arena, store, mode=mode, max_batch=2,
                                        clock=clock, metrics=ServingMetrics())
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    reqs = [Request(rid=i, session=int(rng.randint(n_sessions)),
                    page_keys=None, n_tokens=2) for i in range(n_requests)]
    for r in reqs:
        r.page_keys = pkeys(r.session)
    i = 0
    while i < n_requests or sched.pending:
        while i < n_requests and arrivals[i] <= clock.now():
            sched.submit(reqs[i])
            i += 1
        batch = sched.schedule()
        if not batch:
            if sched.wait_for_progress():
                continue
            if i < n_requests:
                clock.sleep(max(1e-6, arrivals[i] - clock.now()))
                continue
            break
        for req in batch:
            clock.advance(decode_s)
            sched.complete_token(req, dirty_keys=req.page_keys[:1])
    return sched.stats()


def test_scheduler_prefetch_beats_on_demand_ttft_at_equal_load():
    res = {m: _run_mode(m) for m in ("sync", "async", "prefetch")}
    assert res["prefetch"]["ttft_p99"] < res["sync"]["ttft_p99"]
    assert res["prefetch"]["ttft_p50"] <= res["async"]["ttft_p50"] * 1.01
    # same offered load -> same token count served
    assert res["prefetch"]["n_tokens"] == res["sync"]["n_tokens"]
    # prefetch/async hide staging I/O behind compute; sync cannot
    assert res["prefetch"]["staging_overlap"] == pytest.approx(1.0)
    assert res["sync"]["staging_overlap"] < 1.0


def test_scheduler_parks_until_pages_resident():
    arena = PagedStateArena(4, 2, {"state": ((2, 1), jnp.float32)})
    store = TieredStore(page_bytes=1 << 20, workers=1)   # slow: ~ms reads
    store.seed(1, {"state": np.zeros((2, 1), np.float32)})
    clock = SimClock()
    sched = ContinuousBatchingScheduler(arena, store, mode="async",
                                        clock=clock)
    req = Request(rid=0, session=0, page_keys=np.asarray([1], np.int32))
    sched.submit(req)
    assert sched.schedule() == []                    # staging in flight
    assert req.state == "parked"
    assert sched.wait_for_progress()
    batch = sched.schedule()                         # completion absorbed
    assert batch == [req]


# ----------------------------------------------------------------- metrics
def test_metrics_ttft_tpot_split():
    m = ServingMetrics()
    m.record_enqueue(0, 1.0)
    m.record_token(0, 1.5)                           # ttft = 0.5
    m.record_token(0, 1.7)                           # tpot = 0.2
    m.record_done(0, 1.7)
    assert m.ttft == [pytest.approx(0.5)]
    assert m.tpot == [pytest.approx(0.2)]
    assert percentiles([1.0, 2.0, 3.0])["p50"] == 2.0
