"""Device-TAC vs Python-TAC equivalence (fully-associative configuration)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import tac_jax
from repro.core.tac import TimestampAwareCache


def test_lookup_hit_and_miss():
    state = tac_jax.init(4, 4, 8)
    keys = jnp.asarray([5, 9, 13], jnp.int32)
    vals = jnp.arange(24, dtype=jnp.float32).reshape(3, 8)
    state = tac_jax.admit(state, keys, jnp.asarray([1., 2., 3.]), vals)
    out, hit, state = tac_jax.lookup(
        state, jnp.asarray([9, 77], jnp.int32), jnp.asarray([10., 10.]))
    assert bool(hit[0]) and not bool(hit[1])
    np.testing.assert_allclose(np.asarray(out[0]), np.arange(8, 16))


def test_admit_evicts_min_timestamp():
    # single bucket => fully associative, exactly the paper's policy
    state = tac_jax.init(1, 3, 4)
    keys = jnp.asarray([1, 2, 3], jnp.int32)
    state = tac_jax.admit(state, keys, jnp.asarray([10., 20., 30.]),
                          jnp.ones((3, 4)))
    # full; admitting key 4 with ts 25 must evict key 1 (min ts)
    state = tac_jax.admit(state, jnp.asarray([4], jnp.int32),
                          jnp.asarray([25.]), jnp.ones((1, 4)))
    _, hit, _ = tac_jax.lookup(state, jnp.asarray([1, 2, 3, 4], jnp.int32),
                               jnp.zeros(4))
    assert list(np.asarray(hit)) == [False, True, True, True]


def test_renew_protects_entry():
    state = tac_jax.init(1, 2, 4)
    state = tac_jax.admit(state, jnp.asarray([1, 2], jnp.int32),
                          jnp.asarray([10., 20.]), jnp.ones((2, 4)))
    state = tac_jax.renew(state, jnp.asarray([1], jnp.int32),
                          jnp.asarray([99.]))
    state = tac_jax.admit(state, jnp.asarray([3], jnp.int32),
                          jnp.asarray([50.]), jnp.ones((1, 4)))
    _, hit, _ = tac_jax.lookup(state, jnp.asarray([1, 2, 3], jnp.int32),
                               jnp.zeros(3))
    assert list(np.asarray(hit)) == [True, False, True]


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 15), st.floats(1, 100)),
                min_size=4, max_size=40))
def test_equivalence_with_python_tac(trace):
    """Fully-associative device TAC evicts in the same order as the Python
    TAC on any insert trace (unique final contents match)."""
    ways = 6
    py = TimestampAwareCache(capacity=ways)
    dev = tac_jax.init(1, ways, 2)
    for key, ts in trace:
        py.insert(key, None, ts=float(np.float32(ts)))
        dev = tac_jax.admit(dev, jnp.asarray([key], jnp.int32),
                            jnp.asarray([np.float32(ts)]),
                            jnp.zeros((1, 2)))
    py_keys = set(py.entries.keys())
    dev_keys = set(int(k) for k in np.asarray(dev.keys[0]) if k >= 0)
    assert dev_keys == py_keys


def test_evict_expired_reclaims_fired_panes():
    """Watermark-driven bulk reclaim (DESIGN.md §10): slots whose ts fell
    behind the watermark — fired window panes — are invalidated in one
    fused update, dirty bits cleared (purged, not written back)."""
    state = tac_jax.init(2, 4, 4)
    keys = jnp.asarray([1, 2, 3, 4], jnp.int32)
    state = tac_jax.admit(state, keys, jnp.asarray([1., 5., 9., 12.]),
                          jnp.ones((4, 4)),
                          jnp.asarray([True, True, False, False]))
    state, n = tac_jax.evict_expired(state, 6.0)
    assert int(n) == 2
    _, hit, _ = tac_jax.lookup(state, keys, jnp.zeros(4))
    assert list(np.asarray(hit)) == [False, False, True, True]
    assert not bool(np.asarray(state.dirty).any())
    state, n = tac_jax.evict_expired(state, 6.0)     # idempotent
    assert int(n) == 0


def test_evict_expired_retention_expires_by_interval_end():
    """Interval-join entries (DESIGN.md §11) are admitted at their
    insertion/access ts but stay matchable until ts + retention: expiry
    must use the INTERVAL END, not the insertion time."""
    state = tac_jax.init(2, 4, 4)
    keys = jnp.asarray([1, 2, 3, 4], jnp.int32)
    state = tac_jax.admit(state, keys, jnp.asarray([1., 5., 9., 12.]),
                          jnp.ones((4, 4)))
    # plain ts < 6.0 would reclaim keys 1 and 2; with retention=5 only
    # key 1 (interval end 6.0, not strictly behind 6.0... end 1+5=6) —
    # nothing expires at wm=6.0, key 1 expires at wm=6.5
    state, n = tac_jax.evict_expired(state, 6.0, retention=5.0)
    assert int(n) == 0
    state, n = tac_jax.evict_expired(state, 6.5, retention=5.0)
    assert int(n) == 1
    _, hit, _ = tac_jax.lookup(state, keys, jnp.zeros(4))
    assert list(np.asarray(hit)) == [False, True, True, True]


def test_evict_expired_per_slot_retention():
    """Per-slot retention (side-dependent interval bounds): a [n_buckets,
    ways] array applies each slot's own bound."""
    state = tac_jax.init(1, 4, 2)
    keys = jnp.asarray([1, 2], jnp.int32)
    state = tac_jax.admit(state, keys, jnp.asarray([10., 10.]),
                          jnp.ones((2, 2)))
    ret = np.zeros((1, 4), np.float32)
    kslots = np.asarray(state.keys)[0]
    ret[0, list(kslots).index(1)] = 0.0       # left: expires at 10
    ret[0, list(kslots).index(2)] = 8.0       # right: expires at 18
    state, n = tac_jax.evict_expired(state, 15.0, retention=jnp.asarray(ret))
    assert int(n) == 1
    _, hit, _ = tac_jax.lookup(state, keys, jnp.zeros(2))
    assert list(np.asarray(hit)) == [False, True]


def test_flush_dirty_exports_and_clears_without_evicting():
    """Barrier-time dirty export (DESIGN.md §7): dirty rows come back as
    the write-back batch, their dirty bits clear, and — unlike the
    migration drain — the entries STAY resident."""
    state = tac_jax.init(2, 4, 3)
    keys = jnp.asarray([1, 2, 3, 4], jnp.int32)
    vals = jnp.arange(12.0).reshape(4, 3)
    dirty = jnp.asarray([True, False, True, False])
    state = tac_jax.admit(state, keys, jnp.asarray([1., 2., 3., 4.]),
                          vals, dirty)
    state, exp = tac_jax.flush_dirty(state)
    assert sorted(exp.keys.tolist()) == [1, 3]
    assert bool(exp.dirty.all())
    # values rode along with their slots
    for k, v, slot in zip(exp.keys, exp.vals, exp.slots):
        b, w = divmod(int(slot), state.keys.shape[1])
        assert int(np.asarray(state.keys)[b, w]) == int(k)
        np.testing.assert_allclose(np.asarray(state.vals)[b, w], v)
    # nothing evicted, nothing dirty any more
    _, hit, _ = tac_jax.lookup(state, keys, jnp.zeros(4))
    assert bool(np.asarray(hit).all())
    assert not bool(np.asarray(state.dirty).any())
    # second flush is empty
    state, exp2 = tac_jax.flush_dirty(state)
    assert exp2.keys.shape[0] == 0
