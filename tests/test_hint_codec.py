"""Hint-channel delta codec tests (DESIGN.md §13): roundtrip over the
sorted key multiset, wire-format edges, batch sizing for composite keys,
and the int8 quantiser's integer-safety guard."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.runtime.compression import (delta_decode_keys, delta_encode_keys,
                                       hint_batch_nbytes)

U64_MAX = (1 << 64) - 1


# ---------------------------------------------------------------- roundtrip
@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, U64_MAX), max_size=200))
def test_roundtrip_is_sorted_multiset(keys):
    """decode(encode(keys)) == sorted(keys) — duplicates survive as zero
    deltas, order does not (hints are order-free)."""
    assert delta_decode_keys(delta_encode_keys(keys)) == sorted(keys)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 10 ** 6), min_size=2, max_size=200))
def test_dense_batches_approach_one_byte_per_key(keys):
    """Keys within a 254-wide span encode as base + 1 byte each."""
    lo = min(keys)
    if max(keys) - lo >= 0xFF:
        keys = [lo + (k - lo) % 0xFF for k in keys]
    assert len(delta_encode_keys(keys)) == 4 + 8 + (len(keys) - 1)


def test_empty_batch():
    buf = delta_encode_keys([])
    assert buf == b"\x00\x00\x00\x00"
    assert delta_decode_keys(buf) == []


def test_single_key():
    buf = delta_encode_keys([12345])
    assert len(buf) == 12
    assert delta_decode_keys(buf) == [12345]


def test_duplicates_survive():
    assert delta_decode_keys(delta_encode_keys([5, 5, 5, 1])) == [1, 5, 5, 5]


def test_non_monotonic_input_is_sorted():
    assert delta_decode_keys(delta_encode_keys([9, 2, 7, 2])) == [2, 2, 7, 9]


def test_wide_gaps_take_escape_path():
    keys = [0, 1, U64_MAX]                   # last delta needs the escape
    buf = delta_encode_keys(keys)
    assert len(buf) == 4 + 8 + 1 + (1 + 8)
    assert delta_decode_keys(buf) == keys


def test_u64_bounds():
    assert delta_decode_keys(delta_encode_keys([U64_MAX])) == [U64_MAX]
    with pytest.raises(ValueError):
        delta_encode_keys([U64_MAX + 1])
    with pytest.raises(ValueError):
        delta_encode_keys([-1])


def test_decode_rejects_trailing_bytes():
    with pytest.raises(ValueError):
        delta_decode_keys(delta_encode_keys([1, 2]) + b"\x00")
    with pytest.raises(ValueError):
        delta_decode_keys(b"\x00\x00\x00\x00junk")


# ------------------------------------------------------------- batch sizing
def test_nbytes_int_batch():
    keys = [100, 101, 103, 103]
    # one delta stream (4+8+3) + one f32 timestamp per hint
    assert hint_batch_nbytes(keys) == 15 + 4 * len(keys)


def test_nbytes_tuple_streams_grouped_by_arity():
    keys = [(10, 1), (11, 1), (12, 1)]       # WindowKey-shaped
    # two position streams of 3 keys each: 2*(4+8+2), plus timestamps
    assert hint_batch_nbytes(keys) == 2 * 14 + 4 * 3
    mixed = [(1, 2), (3, 4, 5)]              # different arities don't mix
    assert hint_batch_nbytes(mixed) == (2 * 12) + (3 * 12) + 4 * 2


def test_nbytes_fallback_for_unencodable_keys():
    # strings, bools, negatives and overwide ints ship fixed-width
    assert hint_batch_nbytes(["abc"]) == 8 + 4
    assert hint_batch_nbytes([True]) == 8 + 4
    assert hint_batch_nbytes([-5]) == 8 + 4
    assert hint_batch_nbytes([U64_MAX + 1]) == 8 + 4
    assert hint_batch_nbytes([("a", 1)]) == 8 + 4


def test_nbytes_beats_fixed_width_on_clustered_batch():
    keys = list(range(5000, 5200))
    assert hint_batch_nbytes(keys) < len(keys) * 8


# ------------------------------------------------------- int8 integer path
def test_quantize_int8_integer_payload_is_lossless():
    jnp = pytest.importorskip("jax.numpy")
    from repro.runtime.compression import dequantize_int8, quantize_int8
    x = jnp.asarray([0, 1, -127, 127, 64], dtype=jnp.int32)
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    assert float(scale) == 1.0
    assert (dequantize_int8(q, scale) == x.astype(jnp.float32)).all()


def test_quantize_int8_rejects_overwide_integers():
    pytest.importorskip("jax.numpy")
    import jax.numpy as jnp
    from repro.runtime.compression import quantize_int8
    with pytest.raises(ValueError):
        quantize_int8(jnp.asarray([128], dtype=jnp.int32))


def test_quantize_int8_float_path_still_lossy_roundtrip():
    pytest.importorskip("jax.numpy")
    import jax.numpy as jnp
    from repro.runtime.compression import dequantize_int8, quantize_int8
    x = jnp.linspace(-3.0, 3.0, 64)
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) / 2 + 1e-6
