"""Stream-stream join tests (DESIGN.md §11).

Quick by design (sub-second discrete-event runs, unit-level races):
tier-1 loop, like test_windows.py.
"""
import pytest

from repro.streaming.backend import IN_MEMORY, LOCAL_NVME
from repro.streaming.engine import Engine, MapOp, SinkOp, SourceOp
from repro.streaming.events import Hint, Tuple_, Watermark
from repro.streaming.joins import (LEFT, RIGHT, IntervalJoinOp,
                                   JoinLookaheadOp, WindowedJoinOp)
from repro.streaming.nexmark import NexmarkConfig, build_query
from repro.streaming.windows import WindowAssigner


# --------------------------------------------------------------- helpers
class _CollectSink(SinkOp):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.got = []

    def process(self, sub, tup):
        self.got.append((tup.key, tup.payload))
        return super().process(sub, tup)


def _mk_interval(eng, lo=0.0, hi=1.0, lateness=0.0, mode="sync",
                 parallelism=1, shards=None, **kw):
    kw.setdefault("backend_model", IN_MEMORY)
    return IntervalJoinOp(
        eng, "join", parallelism,
        side_of=lambda p: p.get("side"),
        join_fn=lambda key, l, r: ("match", l["v"], r["v"]),
        bounds=(lo, hi), cache_capacity=1_000_000,
        allowed_lateness=lateness, policy="tac", mode=mode,
        state_size=100, shards=shards, **kw)


def _interval_pipeline(eng, gen, rate=2000.0, lo=0.0, hi=0.5,
                       lateness=0.0, oo_bound=0.0):
    src = eng.add(SourceOp(eng, "src", 1, rate, gen,
                           watermark_interval=0.05, oo_bound=oo_bound))
    join = eng.add(_mk_interval(eng, lo=lo, hi=hi, lateness=lateness))
    sink = eng.add(_CollectSink(eng, "sink", 1))
    eng.connect(src, join)
    eng.connect(join, sink, partition=lambda k, n: 0)
    return join, sink


def _lr(side, v):
    return {"side": side, "v": v}


# -------------------------------------------------- interval correctness
def test_interval_join_matches_within_bounds():
    """Pairs with r.ts - l.ts in [lo, hi] match regardless of arrival
    order; pairs outside do not."""
    eng = Engine()
    seq = [  # (delay_index, key, side, ts_offset)
        (0, "k", LEFT, 0.00),
        (1, "k", RIGHT, 0.10),    # in  [0, 0.5]  -> match
        (2, "k", RIGHT, 0.60),    # out (> hi)    -> no match
        (3, "j", RIGHT, 0.05),    # right before its left (out of order)
        (4, "j", LEFT, 0.02),     # matches the buffered right (0.03 in)
    ]
    emitted = {"i": 0}

    def gen(now):
        i = emitted["i"]
        if i >= len(seq):
            return None
        emitted["i"] += 1
        _, key, side, off = seq[i]
        return (key, _lr(side, i), 100, off)

    # oo_bound covers the fixture's event-time spread so the watermark
    # never classifies the deliberately out-of-order arrivals as late
    join, sink = _interval_pipeline(eng, gen, rate=100.0, hi=0.5,
                                    oo_bound=1.0)
    eng.run(duration=1.0)
    assert join.joined == 2
    vals = sorted(p[1:] for _, p in sink.got)
    assert vals == [(0, 1), (4, 3)]


def test_interval_join_one_sided_only_arrivals_expire_silently():
    """Left entries that never see a right partner produce no output and
    their keys purge — cache drop + backend delete, no write-back — once
    the watermark passes their retention deadline."""
    eng = Engine()
    n = {"i": 0}

    def gen(now):
        n["i"] += 1
        return (n["i"], _lr(LEFT, n["i"]), 100)    # unique keys, left only

    join, sink = _interval_pipeline(eng, gen, rate=500.0, hi=0.1)
    eng.run(duration=1.0)
    assert join.joined == 0 and sink.got == []
    assert join.keys_expired > 0
    # purged keys are gone everywhere: registry, cache, and backend
    assert sum(len(r) for r in join.retention) < 500 * 1.0
    assert join.backends[0].writes == 0 or \
        len(join.backends[0].data) < join.keys_expired
    assert join.caches[0].writebacks == 0    # purge never stages write-back


def test_interval_join_late_inside_and_outside_lateness():
    """A tuple whose retention deadline is inside the allowed-lateness
    horizon still joins (late join); beyond the horizon it drops."""
    eng = Engine()
    seq = [
        (0, "k", LEFT, 0.00),     # left at ts 0, deadline 0.1
        (1, "k", RIGHT, 0.05),    # on-time match
    ]
    # after the watermark passes ~0.5: a right at ts=0.08 has deadline
    # 0.08; with lateness 0.5 it is INSIDE the horizon -> late join;
    # with lateness 0 it is outside -> dropped
    extra = [("k", RIGHT, 0.08)]
    state = {"i": 0, "x": 0}

    def gen(now):
        if state["i"] < len(seq):
            i = state["i"]
            state["i"] += 1
            _, key, side, off = seq[i]
            return (key, _lr(side, i), 100, off)
        if now > 0.5 and state["x"] < len(extra):
            key, side, off = extra[state["x"]]
            state["x"] += 1
            return (key, _lr(side, 90 + state["x"]), 100, off)
        return (999, _lr(LEFT, -1), 50, now)       # watermark driver
    join, sink = _interval_pipeline(eng, gen, rate=200.0, hi=0.1,
                                    lateness=0.5)
    eng.run(duration=1.0)
    assert join.joined == 2
    assert join.late_joins >= 1

    # same shape with zero lateness: the straggler drops
    eng2 = Engine()
    state["i"], state["x"] = 0, 0
    join2, sink2 = _interval_pipeline(eng2, gen, rate=200.0, hi=0.1,
                                      lateness=0.0)
    eng2.run(duration=1.0)
    assert join2.joined == 1
    assert join2.late_dropped >= 1


def test_interval_expiry_races_in_flight_prefetch():
    """A key expiring while its prefetch is in flight: the completion
    must be dropped (no resurrection in cache or backend) and tuples
    parked on it count late."""
    eng = Engine()
    join = eng.add(_mk_interval(eng, hi=0.1, mode="prefetch",
                                backend_model=LOCAL_NVME))
    join.managers[0].enabled = True
    # a left entry registers the key with deadline 0.1
    join.deliver_batch(0, [Tuple_(0.0, "k", _lr(LEFT, 1), 100, 0.0)])
    eng.sim.run_until(0.01)
    assert "k" in join.retention[0]
    # evict the resident entry so a hint must schedule a real prefetch
    join.caches[0].drop("k")
    join.handle(0, Hint("k", 0.05, origin="la"))
    assert "k" in join.in_flight[0]
    # a data tuple parks on the same in-flight key
    join.waiting[0]["k"].append(Tuple_(0.05, "k", _lr(RIGHT, 2), 100, 0.05))
    # watermark passes the retention deadline before the I/O completes
    join._recv_watermark(0, Watermark(5.0, origin=("c", 0)))
    join.on_watermark(0, 5.0)
    assert "k" not in join.retention[0]
    assert "k" in join._purged[0]
    before_late = join.late_dropped
    eng.sim.run_until(1.0)                   # let the fetch complete
    assert not join.caches[0].contains("k")  # completion dropped
    assert "k" not in join.backends[0].data
    assert join.late_dropped == before_late + 1   # parked tuple was late
    assert "k" not in join.in_flight[0]


def test_keys_with_all_entries_declined_still_expire():
    """A key whose tuples keep_fn all declines still materializes
    (empty) state on the read path; the retention registry must learn it
    anyway so the watermark purge reclaims it."""
    eng = Engine()
    join = eng.add(_mk_interval(eng, hi=0.1, mode="sync",
                                keep_fn=lambda side, p: False))
    join.deliver_batch(0, [Tuple_(1.0, "k", _lr(LEFT, 1), 100, 1.0)])
    eng.sim.run_until(0.01)
    assert join.retention[0]["k"] == pytest.approx(1.1)
    join.on_watermark(0, 5.0)
    assert "k" not in join.retention[0]
    assert "k" not in join.backends[0].data
    assert not join.caches[0].contains("k")


def test_interval_key_rebirth_clears_purge_mark():
    """New data for a purged key revives it: its I/O is valid again and
    the retention registry re-learns the deadline."""
    eng = Engine()
    join = eng.add(_mk_interval(eng, hi=0.1, mode="sync"))
    join._purged[0].add("k")
    join.deliver_batch(0, [Tuple_(10.0, "k", _lr(LEFT, 1), 100, 10.0)])
    eng.sim.run_until(0.01)
    assert "k" not in join._purged[0]
    assert join.retention[0]["k"] == pytest.approx(10.1)


# ----------------------------------------------------------- windowed q8
def test_windowed_join_fires_cogrouped_panes():
    """Co-grouped pane fires emit only when both sides are present in
    the (key, window); one-sided panes count as unmatched."""
    eng = Engine()
    assigner = WindowAssigner(0.2)
    seq = {"i": 0}

    def gen(now):
        i = seq["i"]
        seq["i"] += 1
        key = i % 4
        # keys 0/1 get both sides, 2 only left, 3 only right
        side = LEFT if (key in (0, 1) and i % 8 < 4) or key == 2 \
            else RIGHT
        return (key, _lr(side, i), 100)

    src = eng.add(SourceOp(eng, "src", 1, 2000.0, gen,
                           watermark_interval=0.05, oo_bound=0.0))
    join = eng.add(WindowedJoinOp(
        eng, "join", 1, assigner,
        side_of=lambda p: p.get("side"),
        join_fn=lambda key, L, R: ("both", key, len(L), len(R)),
        backend_model=IN_MEMORY, cache_capacity=1_000_000,
        policy="tac", mode="sync", state_size=100))
    sink = eng.add(_CollectSink(eng, "sink", 1))
    eng.connect(src, join)
    eng.connect(join, sink, partition=lambda k, n: 0)
    eng.run(duration=1.0)
    assert join.joined > 0
    assert join.unmatched[LEFT] > 0 and join.unmatched[RIGHT] > 0
    keys = {k for k, _ in sink.got}
    assert keys <= {0, 1}                    # only two-sided panes emit


def test_q8_end_to_end_with_prefetch():
    cfg = NexmarkConfig(rate=4000, active_window=1.0, oo_bound=0.2, seed=7)
    eng = build_query("q8", "tac", "prefetch", cfg, cache_entries=256,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, window_size=0.5)
    m = eng.run(duration=2.0, warmup=0.5)
    assert m["join_fires"] > 0 and m["join_joined"] > 0
    assert m["join_hints_received"] > 0
    assert m["join_prefetch_hits"] > 0
    assert m["n_outputs"] > 0
    assert eng.controller.active["join"] == "join_lookahead"


# ------------------------------------------------------------ lookaheads
def test_join_lookahead_one_sided_suppresses_build_side():
    eng = Engine()
    la = JoinLookaheadOp(eng, "la", 1,
                         side_of=lambda p: p.get("side"),
                         key_of=lambda t: t.key,
                         hint_sides=(RIGHT,), bounds=(0.0, 1.0),
                         probe_ahead=0.5)
    la.hint_active = True
    hints = []
    la.emit_hint = lambda sub, h: hints.append(h)
    la._emit_hints_for(0, Tuple_(1.0, "k", _lr(LEFT, 1), 100, 1.0))
    assert hints == [] and la.side_suppressed == 1
    la._emit_hints_for(0, Tuple_(1.0, "k", _lr(RIGHT, 2), 100, 1.0))
    assert len(hints) == 1 and la.side_hints[RIGHT] == 1


def test_join_lookahead_interval_deadline_capped_at_probe_ahead():
    """Build-side hints carry the predicted FIRST probe time (capped
    retention deadline), never the full interval end (which would pin
    the key for its whole matchable life) and never less than the
    tuple's own access time."""
    eng = Engine()
    la = JoinLookaheadOp(eng, "la", 1,
                         side_of=lambda p: p.get("side"),
                         key_of=lambda t: t.key,
                         bounds=(0.0, 30.0), probe_ahead=0.5)
    la.hint_active = True
    hints = []
    la.emit_hint = lambda sub, h: hints.append(h)
    la._emit_hints_for(0, Tuple_(10.0, "a", _lr(LEFT, 1), 100, 10.0))
    assert hints[-1].ts == pytest.approx(10.5)     # not 40.0
    la._emit_hints_for(0, Tuple_(10.0, "b", _lr(RIGHT, 2), 100, 10.0))
    assert hints[-1].ts == pytest.approx(10.0)     # floored at access ts
    # arrival ablation: plain event ts on both sides
    la.hint_ts_mode = "arrival"
    la._emit_hints_for(0, Tuple_(20.0, "c", _lr(LEFT, 3), 100, 20.0))
    assert hints[-1].ts == pytest.approx(20.0)


def test_join_lookahead_requires_exactly_one_kind():
    eng = Engine()
    with pytest.raises(ValueError):
        JoinLookaheadOp(eng, "la", 1, side_of=lambda p: LEFT,
                        key_of=lambda t: t.key)
    with pytest.raises(ValueError):
        JoinLookaheadOp(eng, "la2", 1, side_of=lambda p: LEFT,
                        key_of=lambda t: t.key,
                        assigner=WindowAssigner(1.0), bounds=(0.0, 1.0))
    with pytest.raises(ValueError):          # deadline mode needs a cap
        JoinLookaheadOp(eng, "la3", 1, side_of=lambda p: LEFT,
                        key_of=lambda t: t.key, bounds=(0.0, 1.0))


# ------------------------------------------------------------ shard plane
def test_cross_side_hint_mid_migration():
    """A cross-side hint arriving while its shard's state is in transit
    parks at the new owner and still triggers its prefetch there; the
    retention registry migrates with the shard."""
    from repro.streaming.shards import ShardPlane
    eng = Engine()
    plane = ShardPlane(4, 2)
    join = eng.add(_mk_interval(eng, hi=5.0, mode="prefetch",
                                parallelism=2, shards=plane,
                                backend_model=LOCAL_NVME))
    for mgr in join.managers:
        mgr.enabled = True
    # key 0 lives in shard 0, owned by sub 0; register it with a deadline
    join.deliver_batch(0, [Tuple_(0.0, 0, _lr(LEFT, 1), 100, 0.0)])
    eng.sim.run_until(0.01)
    assert join.retention[0][0] == pytest.approx(5.0)
    # start migrating shard 0 -> sub 1 (state in transit)
    join.migrate_shard(0, 1)
    assert 0 in plane.migrating
    assert join.retention[1][0] == pytest.approx(5.0)  # registry moved
    assert 0 not in join.retention[0]
    # a cross-side hint for the migrating key lands at the NEW owner and
    # parks (shard guard), then replays after re-admission
    join.deliver_batch(1, [Hint(0, 1.0, origin="la")])
    eng.sim.run_until(0.02)
    assert plane.parked_in_migration >= 1
    eng.sim.run_until(0.5)                    # transfer + replay complete
    assert 0 not in plane.migrating
    assert join.managers[1].hints_received >= 1
    # the replayed hint's prefetch ran at the destination
    assert join.caches[1].contains(0) or 0 in join.in_flight[1]


def test_q20_interval_join_end_to_end_sharded_migration():
    """q20 on the sharded plane with a mid-run rebalance keeps joining
    and expiring across the move."""
    cfg = NexmarkConfig(rate=6000, active_window=2.0, oo_bound=0.2, seed=7)
    eng = build_query("q20", "tac", "prefetch", cfg, cache_entries=128,
                      parallelism=2, source_parallelism=1, io_workers=4,
                      buffer_timeout=0.002, n_shards=8,
                      allowed_lateness=0.1)
    eng.migrate_shard("join", 0, 1, at=0.9)
    m = eng.run(duration=1.8, warmup=0.5)
    join = eng.operators["join"]
    assert join.shards.migrations == 1
    assert m["join_joined"] > 0
    assert m["join_keys_expired"] > 0
    assert m["n_outputs"] > 0


def test_q20_without_event_time_keeps_legacy_plan():
    """cfg.oo_bound == 0 keeps the original processing-time incremental
    q20 (the paper-figure baseline): a plain StatefulOp, no join op."""
    cfg = NexmarkConfig(rate=1000)
    eng = build_query("q20", "lru", "sync", cfg)
    assert "join" not in eng.operators
    assert "stateful" in eng.operators


def test_interval_join_rejects_bad_bounds():
    eng = Engine()
    with pytest.raises(ValueError):
        _mk_interval(eng, lo=2.0, hi=1.0)
