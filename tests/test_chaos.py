"""Chaos harness + exactly-once oracle tests (DESIGN.md §15).

Covers the three claims the harness makes: (1) a fault schedule replays
BIT-EXACTLY on the discrete-event clock, (2) concurrent faults do not
change the session query's state effects (the oracle passes), and (3)
when state IS corrupted the oracle catches it and the greedy minimizer
shrinks the schedule to a <= 2-event reproducer that pickles/loads.

Plus the seed-determinism audit: every workload generator (synthetic,
NEXMark, YSB) draws from one counter-based ``numpy.random.Generator``,
so two same-seed runs produce identical sink streams.
"""
import pickle

import pytest

from repro.streaming.chaos import (FaultEvent, FaultSchedule,
                                   check_schedule, compare, minimize,
                                   run_schedule, save_artifact)
from repro.streaming.nexmark import NexmarkConfig, NexmarkGen, build_query
from repro.streaming.synthetic import SyntheticConfig, build_synthetic
from repro.streaming.ysb import YSBConfig, YSBGen

T_CUT = 1.2                               # short logical stream: fast tests


# ------------------------------------------------------------- schedules
def test_random_schedule_is_reproducible_and_multi_kind():
    for seed in (5, 17, 901):
        a = FaultSchedule.random(seed)
        b = FaultSchedule.random(seed)
        assert a == b                     # pure function of the seed
        assert len(set(e.kind for e in a.events)) >= 2
        assert "corrupt" not in a.kinds()  # only injected explicitly
        assert all(0.4 <= e.at <= 1.6 for e in a.events)
    assert FaultSchedule.random(5) != FaultSchedule.random(6)


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultEvent("power_surge", 1.0)


# ----------------------------------------------------- bit-exact replay
def test_perturbed_run_replays_bit_exactly():
    """Same schedule, same seed, fresh engine: every observable state
    effect is identical — the property the differential oracle needs."""
    sched = FaultSchedule.random(41, n_events=3)
    r1 = run_schedule(sched, t_cut=T_CUT)
    r2 = run_schedule(sched, t_cut=T_CUT)
    assert r1.final_state == r2.final_state
    assert r1.registry == r2.registry
    assert r1.last_emit == r2.last_emit
    assert r1.emit_counts == r2.emit_counts
    assert r1.absorbed == r2.absorbed


# --------------------------------------------------------------- oracle
def test_oracle_passes_under_concurrent_faults():
    """A >= 2-kind schedule (the CI smoke shape) leaves final keyed
    state, session registry, and last-emit-per-pane bit-identical to the
    unperturbed golden run."""
    sched = FaultSchedule.random(101, n_events=4)
    assert len(set(e.kind for e in sched.events)) >= 2
    report, golden, perturbed = check_schedule(sched, t_cut=T_CUT)
    assert report.ok, report.violations[:3]
    assert golden.registry            # non-vacuous: sessions survived
    assert golden.last_emit           # ... and fired
    assert perturbed.metrics["fires"] > 0


def test_oracle_self_compare_is_clean():
    golden = run_schedule(FaultSchedule(seed=55), t_cut=T_CUT)
    report = compare(golden, golden)
    assert report.ok and not report.violations
    assert report.deviations.get("duplicate_emits", 0) == 0


# ------------------------------------------------- minimizer + artifact
def test_minimizer_shrinks_corruption_to_two_events(tmp_path):
    """An intentional state corruption hidden inside a wider schedule:
    the oracle flags it and greedy delta-debugging shrinks the schedule
    to <= 2 events that still reproduce the violation, pickled as a
    loadable artifact."""
    base = FaultSchedule(seed=77, chaos_seed=770)
    sched = base.with_events([
        FaultEvent("hint_drop", 0.5, (0.5, 0.4)),
        FaultEvent("migrate", 0.7, (1, 1)),
        FaultEvent("corrupt", 0.8),
    ])
    report, golden, _ = check_schedule(sched, t_cut=T_CUT)
    assert not report.ok
    assert any("__corrupt__" in str(v) for v in report.violations)

    mini = minimize(sched, t_cut=T_CUT, golden=golden)
    assert len(mini.events) <= 2
    assert "corrupt" in mini.kinds()
    mini_report, _, _ = check_schedule(mini, t_cut=T_CUT, golden=golden)
    assert not mini_report.ok         # still reproduces

    path = save_artifact(mini, mini_report, out_dir=str(tmp_path))
    with open(path, "rb") as fh:
        art = pickle.load(fh)
    assert art["schedule"] == mini    # round-trips through pickle
    assert art["violations"]


def test_minimize_returns_passing_schedule_unchanged():
    sched = FaultSchedule.random(101, n_events=2)
    golden = run_schedule(FaultSchedule(seed=sched.seed,
                                        chaos_seed=sched.chaos_seed),
                          t_cut=T_CUT)
    assert minimize(sched, t_cut=T_CUT, golden=golden) == sched


# ------------------------------------------- seed-determinism audit (§15)
def test_generators_are_seed_deterministic():
    """Every workload generator draws from one counter-based numpy
    Generator: same seed => identical tuple stream, different seed =>
    different stream."""
    n = 400
    for mk in (lambda s: NexmarkGen(NexmarkConfig(seed=s)),
               lambda s: YSBGen(YSBConfig(seed=s))):
        a = [mk(9)(i * 1e-3) for i in range(n)]
        b = [mk(9)(i * 1e-3) for i in range(n)]
        c = [mk(10)(i * 1e-3) for i in range(n)]
        assert a == b
        assert a != c


def _sink_stream(eng, duration):
    sink = eng.operators["sink"]
    got = []
    orig = sink.process
    sink.process = lambda sub, tup: (
        got.append((round(tup.ts, 9), tup.key)), orig(sub, tup))[1]
    eng.run(duration=duration)
    return got


def test_same_seed_runs_produce_identical_sink_streams():
    """End to end through prefetching, caching, and I/O timing: the
    whole discrete-event run is a pure function of the seed."""
    def synth():
        return build_synthetic(SyntheticConfig(rate=8000.0, seed=13),
                               parallelism=2)

    def q11():
        cfg = NexmarkConfig(rate=3000, oo_bound=0.2, seed=13,
                            watermark_interval=0.05)
        return build_query("q11", "tac", "prefetch", cfg,
                           cache_entries=512, parallelism=2,
                           source_parallelism=1, io_workers=4,
                           buffer_timeout=0.002, session_gap=0.4)

    for builder, dur in ((synth, 0.4), (q11, 2.0)):
        s1 = _sink_stream(builder(), dur)
        s2 = _sink_stream(builder(), dur)
        assert s1, "no sink output"
        assert s1 == s2
