"""Device-resident paged state arena (DESIGN.md §6).

The physical page pool lives in fixed device slots (one or more parallel
pools — e.g. K pages and V pages — sharing slot indices); the device TAC
(``repro.core.tac_jax``) is its page table.  All APIs are BATCHED: a probe,
admit, stage or victim-gather over N pages is one fused device op, never a
per-page Python loop.

Admission reuses the TAC's eviction rule (min-timestamp way within the
key's bucket); dirty victims are surfaced — with their page contents
gathered BEFORE restaging overwrites the slots — so the caller (the tiered
store / scheduler) can write them back asynchronously.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tac_jax
from repro.kernels.page_gather.ops import page_gather, page_scatter
from repro.kernels.tac_probe.ops import (bucket_of, tac_probe,
                                         tac_probe_counted)
from repro.obs import NULL_COUNTER


class Admitted(NamedTuple):
    slots: np.ndarray           # [N] flat physical slot per admitted key
    evicted_keys: np.ndarray    # [N] displaced key (-1 = none)
    evicted_dirty: np.ndarray   # [N] displaced key's dirty bit
    evicted_blocks: Dict[str, jax.Array]  # victim page contents per pool,
    #                             gathered pre-staging; rows align with slots


class PagedStateArena:
    """Fixed-slot page pool with a TAC page table.

    ``pools`` maps pool name -> ((page, d), dtype); every pool holds
    ``n_buckets * ways`` physical pages addressed by the same slot ids.
    """

    def __init__(self, n_buckets: int, ways: int,
                 pools: Dict[str, Tuple[Tuple[int, int], Any]],
                 interpret: bool = True):
        self.n_buckets = n_buckets
        self.ways = ways
        self.n_slots = n_buckets * ways
        self.interpret = interpret
        self.tac = tac_jax.init(n_buckets, ways, 1)
        self.pools: Dict[str, jax.Array] = {
            name: jnp.zeros((self.n_slots, *shape), dtype)
            for name, (shape, dtype) in pools.items()}
        self.hits = 0
        self.misses = 0
        self.conflicts = 0
        self.admits = 0
        self.evictions = 0
        self.dirty_evictions = 0
        self.staged_pages = 0
        self._c_hits = self._c_misses = self._c_conflicts = NULL_COUNTER

    def bind_registry(self, registry) -> None:
        """Publish device probe tallies into a MetricsRegistry
        (DESIGN.md §12)."""
        self._c_hits = registry.counter("serving.arena.probe.hits")
        self._c_misses = registry.counter("serving.arena.probe.misses")
        self._c_conflicts = registry.counter(
            "serving.arena.probe.conflicts")

    # -------------------------------------------------------------- probing
    def probe(self, keys: jax.Array, now_ts: Optional[jax.Array] = None,
              count: bool = True) -> Tuple[np.ndarray, np.ndarray]:
        """Batched residency probe.  Returns (hit [N] bool, slots [N] int32,
        -1 for misses).  With ``now_ts`` the probe is an ACCESS: hit
        timestamps are refreshed (max with now).  ``count=False`` keeps
        polling/hint probes out of the hit-rate stats (a parked request is
        probed every scheduler tick; counting those would turn the hit rate
        into a poll-frequency artifact)."""
        keys = jnp.asarray(keys, jnp.int32)
        if keys.shape[0] == 0:                # empty batch: nothing to probe
            return (np.zeros((0,), bool), np.zeros((0,), np.int32))
        if count:
            # counted variant: hit/conflict tallies reduced ON DEVICE in
            # the same launch feed the registry (DESIGN.md §12)
            _, hit_d, way, tallies = tac_probe_counted(
                keys, self.tac.keys, self.tac.vals,
                interpret=self.interpret)
        else:
            _, hit_d, way = tac_probe(keys, self.tac.keys, self.tac.vals,
                                      interpret=self.interpret)
        bucket_d = bucket_of(keys, self.n_buckets)
        if now_ts is not None:                # access: refresh hit ts
            safe = jnp.maximum(way, 0)
            cur = self.tac.ts[bucket_d, safe]
            new_ts = self.tac.ts.at[bucket_d, safe].max(
                jnp.where(hit_d.astype(bool),
                          jnp.asarray(now_ts, jnp.float32), cur))
            self.tac = self.tac._replace(ts=new_ts)
        hit = np.asarray(hit_d).astype(bool)
        bucket = np.asarray(bucket_d)
        slots = np.where(hit, bucket * self.ways + np.asarray(way), -1)
        if count:
            n_hit, n_conflict = (int(x) for x in np.asarray(tallies))
            self.hits += n_hit
            self.misses += len(hit) - n_hit
            self.conflicts += n_conflict
            self._c_hits.inc(n_hit)
            self._c_misses.inc(len(hit) - n_hit)
            self._c_conflicts.inc(n_conflict)
        return hit, slots.astype(np.int32)

    def count_access(self, hits: int, misses: int) -> None:
        """Explicit hit-rate bookkeeping for callers that probe with
        ``count=False`` and decide afterwards what constituted an access."""
        self.hits += int(hits)
        self.misses += int(misses)
        self._c_hits.inc(int(hits))
        self._c_misses.inc(int(misses))

    def page_table(self, keys: jax.Array) -> Tuple[np.ndarray, jax.Array]:
        """keys [B, P] -> (hit [B, P], table [B, P] slot ids) for
        ``paged_decode_attention`` — one batched probe for all sequences."""
        keys = jnp.asarray(keys, jnp.int32)
        B, P = keys.shape
        hit, slots = self.probe(keys.reshape(-1))
        return hit.reshape(B, P), jnp.asarray(slots.reshape(B, P))

    def renew(self, keys: jax.Array, ts: jax.Array) -> None:
        """Hint for already-resident pages: bump predicted relevance."""
        keys = jnp.asarray(keys, jnp.int32)
        if keys.shape[0] == 0:
            return
        self.tac = tac_jax.renew(self.tac, keys,
                                 jnp.asarray(ts, jnp.float32))

    # ------------------------------------------------------------- admission
    def admit(self, keys: jax.Array, ts: jax.Array,
              dirty: Optional[jax.Array] = None) -> Admitted:
        """Batched multi-key admission via ``tac_jax.admit_batch``.  Chooses
        slots (evicting min-ts ways), gathers victim page contents before
        they can be overwritten, and returns everything the caller needs to
        stage new pages and write dirty victims back."""
        keys = jnp.asarray(keys, jnp.int32)
        if keys.shape[0] == 0:                # empty batch: nothing to admit
            return Admitted(np.zeros((0,), np.int32),
                            np.zeros((0,), np.int32),
                            np.zeros((0,), bool), {})
        res = tac_jax.admit_batch(
            self.tac, keys, jnp.asarray(ts, jnp.float32), None,
            None if dirty is None else jnp.asarray(dirty, bool))
        self.tac = res.state
        slots = np.asarray(res.slots)
        ev_k = np.asarray(res.evicted_keys)
        ev_d = np.asarray(res.evicted_dirty)
        # victim contents: gather the chosen slots BEFORE staging overwrites
        # them (rows where evicted_keys == -1 are garbage; callers filter).
        # Only DIRTY victims are ever written back, so all-clean eviction
        # rounds skip the gather entirely
        evicted_blocks = {name: page_gather(jnp.asarray(slots), pool,
                                            interpret=self.interpret)
                          for name, pool in self.pools.items()} \
            if bool(((ev_k >= 0) & ev_d).any()) else {}
        self.admits += len(slots)
        self.evictions += int((ev_k >= 0).sum())
        self.dirty_evictions += int((ev_d & (ev_k >= 0)).sum())
        return Admitted(slots.astype(np.int32), ev_k, ev_d, evicted_blocks)

    def stage(self, slots: jax.Array,
              blocks: Dict[str, jax.Array]) -> None:
        """Scatter N staged pages into their physical slots (one kernel
        launch per pool)."""
        slots = jnp.asarray(slots, jnp.int32)
        if slots.shape[0] == 0:
            return
        for name, blk in blocks.items():
            self.pools[name] = page_scatter(slots, blk.astype(
                self.pools[name].dtype), self.pools[name],
                interpret=self.interpret)
        self.staged_pages += int(slots.shape[0])

    def gather(self, slots: jax.Array) -> Dict[str, jax.Array]:
        """Batched read of N physical pages from every pool."""
        slots = jnp.asarray(slots, jnp.int32)
        return {name: page_gather(slots, pool, interpret=self.interpret)
                for name, pool in self.pools.items()}

    # ------------------------------------------------------------- migration
    def export_where(self, pred) -> Tuple[np.ndarray, np.ndarray,
                                          np.ndarray, Dict[str, jax.Array]]:
        """Migration drain (DESIGN.md §9): pop every resident entry whose key
        satisfies ``pred`` (vectorized numpy predicate) out of the page
        table, gather its page contents (one batched ``page_gather`` per
        pool), and return (keys, ts, dirty, blocks) with timestamps and
        dirty bits preserved — the destination re-admits with the same
        eviction priority via ``admit(keys, ts, dirty)`` + ``stage``."""
        exp = tac_jax.export_mask(self.tac, pred(np.asarray(self.tac.keys)))
        self.tac = exp.state
        blocks = self.gather(jnp.asarray(exp.slots)) if len(exp.keys) else {}
        return exp.keys, exp.ts, exp.dirty, blocks

    # ----------------------------------------------------------- dirty state
    def mark_dirty(self, keys: jax.Array) -> None:
        """Decode mutated these pages in place: flag them for write-back."""
        keys = jnp.asarray(keys, jnp.int32)
        if keys.shape[0] == 0:
            return
        self.tac = tac_jax.set_dirty(self.tac, keys, True)

    def flush_dirty(self) -> Tuple[np.ndarray, Dict[str, jax.Array]]:
        """Checkpoint/shutdown: return (keys, page contents) of every dirty
        resident page and clear the dirty bits."""
        dirty = np.asarray(self.tac.dirty)
        keys = np.asarray(self.tac.keys)
        mask = dirty & (keys >= 0)
        if not mask.any():
            return np.zeros((0,), np.int32), {}
        b, w = np.nonzero(mask)
        slots = (b * self.ways + w).astype(np.int32)
        blocks = self.gather(jnp.asarray(slots))
        self.tac = self.tac._replace(dirty=jnp.zeros_like(self.tac.dirty))
        return keys[mask], blocks

    # --------------------------------------------------------------- metrics
    def stats(self) -> Dict[str, float]:
        tot = self.hits + self.misses
        return {"arena_hits": self.hits, "arena_misses": self.misses,
                "arena_hit_rate": self.hits / tot if tot else 0.0,
                "arena_conflicts": self.conflicts,
                "arena_admits": self.admits,
                "arena_evictions": self.evictions,
                "arena_dirty_evictions": self.dirty_evictions,
                "arena_staged_pages": self.staged_pages}
