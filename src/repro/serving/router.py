"""Sharded keyed-state plane for serving (DESIGN.md §9).

``ShardRouter`` runs N per-shard ``PagedStateArena`` + ``TieredStore``
pairs behind the SAME batched interface the single-owner pair exposes, so
``ContinuousBatchingScheduler`` drives a sharded plane unchanged — it is
handed the router as both its ``arena`` and its ``store``.

Ownership is bin-based (Megaphone-style): keys hash into ``n_bins``
logical bins (``bin = key % n_bins``, the device twin of the engine's
``hash_partition``) and an owner table maps bins to shards.  Every batched
call is SPLIT by owner, dispatched to the owning shard's arena/store, and
merged back in the caller's key order; physical slots are globalized as
``shard * slots_per_shard + local_slot`` so an admit's slots can be handed
straight back to ``stage``.

``migrate_bins`` is the key-range migration primitive: drain the moving
bins out of each source arena (one batched ``page_gather`` per pool),
carry tier contents and in-flight stage requests across, flip ownership,
and re-admit at the destination with PRESERVED timestamps and dirty bits —
a prefetched page whose hint timestamp lies in the future stays protected
across the move, and the prefetch-timeliness accounting stays correct per
shard.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.serving.arena import Admitted, PagedStateArena
from repro.serving.store import TieredStore


class ShardRouter:
    """Arena + store facade over per-shard (PagedStateArena, TieredStore).

    ``arena_factory(shard)`` / ``store_factory(shard)`` build one shard's
    pair; all arenas must have identical geometry (slot ids are globalized
    by uniform stride).  ``owners`` optionally seeds the bin->shard table
    (default: round-robin).
    """

    def __init__(self, n_shards: int,
                 arena_factory: Callable[[int], PagedStateArena],
                 store_factory: Callable[[int], TieredStore],
                 n_bins: int = 64,
                 owners: Optional[Sequence[int]] = None):
        if n_bins < n_shards:
            raise ValueError(f"n_bins={n_bins} < n_shards={n_shards}")
        self.n_shards = n_shards
        self.n_bins = n_bins
        self.arenas = [arena_factory(s) for s in range(n_shards)]
        self.stores = [store_factory(s) for s in range(n_shards)]
        slots = {a.n_slots for a in self.arenas}
        if len(slots) != 1:
            raise ValueError("all shard arenas must share one geometry "
                             f"(got n_slots {sorted(slots)})")
        self.slots_per_shard = self.arenas[0].n_slots
        from repro.launch.sharding import shard_owner_map
        self.owner = np.asarray(
            owners if owners is not None
            else shard_owner_map(n_bins, n_shards), np.int32)
        if self.owner.shape != (n_bins,) or \
                not ((0 <= self.owner) & (self.owner < n_shards)).all():
            raise ValueError("owners must map every bin to a valid shard")
        # routed-plane counters (per shard; Engine.metrics analogue)
        self.hints_routed = np.zeros(n_shards, np.int64)
        self.pages_routed = np.zeros(n_shards, np.int64)
        self.hits = 0
        self.misses = 0
        self.migrations = 0
        self.pages_migrated = 0
        self.tier_entries_migrated = 0

    # -------------------------------------------------------------- routing
    def bin_of(self, keys: np.ndarray) -> np.ndarray:
        return np.mod(np.asarray(keys, np.int64), self.n_bins)

    def shard_of(self, keys: np.ndarray) -> np.ndarray:
        """Owning shard per key via the bin table."""
        return self.owner[self.bin_of(keys)]

    def _split(self, keys: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        """(shard, caller-order indices) for each shard with any keys."""
        keys = np.asarray(keys)
        if keys.size == 0:
            return []
        shards = self.shard_of(keys)
        return [(s, np.nonzero(shards == s)[0])
                for s in np.unique(shards)]

    # --------------------------------------------------- arena facade: probe
    @property
    def n_slots(self) -> int:
        return self.slots_per_shard * self.n_shards

    def probe(self, keys, now_ts=None, count: bool = True
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched cross-shard residency probe; slots come back globalized.
        Misrouted keys cannot refresh a foreign shard's entries because
        each subset only ever reaches its owner."""
        keys = np.asarray(keys)
        hit = np.zeros(keys.shape[0], bool)
        slots = np.full(keys.shape[0], -1, np.int32)
        for s, idx in self._split(keys):
            ts_s = None if now_ts is None else np.asarray(now_ts)[idx]
            h, sl = self.arenas[s].probe(keys[idx], now_ts=ts_s, count=count)
            hit[idx] = h
            slots[idx] = np.where(sl >= 0,
                                  sl + s * self.slots_per_shard, -1)
        if count:
            self.hits += int(hit.sum())
            self.misses += int((~hit).sum())
        return hit, slots

    def count_access(self, hits: int, misses: int) -> None:
        """Scheduler-side access accounting (probes ran with count=False)."""
        self.hits += int(hits)
        self.misses += int(misses)

    def renew(self, keys, ts) -> None:
        keys = np.asarray(keys)
        ts = np.asarray(ts)
        for s, idx in self._split(keys):
            self.arenas[s].renew(keys[idx], ts[idx])
            self.hints_routed[s] += len(idx)

    # --------------------------------------------------- arena facade: admit
    def _pool_row_shapes(self) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
        a = self.arenas[0]
        return {name: (pool.shape[1:], pool.dtype)
                for name, pool in a.pools.items()}

    def admit(self, keys, ts, dirty=None) -> Admitted:
        """Batched multi-shard admission, merged in caller key order.
        ``evicted_blocks`` rows align with the merged batch; shards with no
        dirty victims contribute zero rows (filtered by the -1/dirty mask
        exactly as with a single arena)."""
        keys = np.asarray(keys)
        n = keys.shape[0]
        slots = np.zeros(n, np.int32)
        ev_k = np.full(n, -1, np.int32)
        ev_d = np.zeros(n, bool)
        parts: List[Tuple[np.ndarray, Dict[str, jax.Array]]] = []
        for s, idx in self._split(keys):
            d_s = None if dirty is None else np.asarray(dirty)[idx]
            adm = self.arenas[s].admit(keys[idx], np.asarray(ts)[idx],
                                       dirty=d_s)
            slots[idx] = adm.slots + s * self.slots_per_shard
            ev_k[idx] = adm.evicted_keys
            ev_d[idx] = adm.evicted_dirty
            self.pages_routed[s] += len(idx)
            if adm.evicted_blocks:
                parts.append((idx, adm.evicted_blocks))
        blocks: Dict[str, jax.Array] = {}
        if parts:
            for name, (shape, dtype) in self._pool_row_shapes().items():
                rows = np.zeros((n, *shape), dtype)
                for idx, blk in parts:
                    rows[idx] = np.asarray(blk[name])
                blocks[name] = rows
        return Admitted(slots, ev_k, ev_d, blocks)

    def stage(self, slots, blocks: Dict[str, Any]) -> None:
        """Scatter staged pages through each owning shard's arena; ``slots``
        are the globalized ids ``admit`` returned."""
        slots = np.asarray(slots, np.int32)
        if slots.size == 0:
            return
        shards = slots // self.slots_per_shard
        for s in np.unique(shards):
            idx = np.nonzero(shards == s)[0]
            self.arenas[s].stage(slots[idx] - s * self.slots_per_shard,
                                 {name: np.asarray(blk)[idx]
                                  for name, blk in blocks.items()})

    def mark_dirty(self, keys) -> None:
        keys = np.asarray(keys)
        for s, idx in self._split(keys):
            self.arenas[s].mark_dirty(keys[idx])

    def flush_dirty(self) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        keys_all: List[np.ndarray] = []
        rows: Dict[str, List[np.ndarray]] = {}
        for a in self.arenas:
            keys, blocks = a.flush_dirty()
            if len(keys) == 0:
                continue
            keys_all.append(keys)
            for name, blk in blocks.items():
                rows.setdefault(name, []).append(np.asarray(blk))
        if not keys_all:
            return np.zeros((0,), np.int32), {}
        return (np.concatenate(keys_all),
                {name: np.concatenate(parts) for name, parts in rows.items()})

    # ----------------------------------------------------------- store facade
    def seed(self, key: Any, blocks: Any) -> None:
        self.stores[int(self.shard_of(np.asarray([key]))[0])].seed(key,
                                                                   blocks)

    def request_stage(self, keys: List[Any], now: float,
                      hint_ts: Optional[List[float]] = None) -> int:
        """Hint routing: each key's stage request goes to the shard that
        owns it (never broadcast)."""
        keys_arr = np.asarray(keys)
        n = 0
        for s, idx in self._split(keys_arr):
            hs = None if hint_ts is None else [hint_ts[i] for i in idx]
            n += self.stores[s].request_stage([keys[i] for i in idx],
                                              now, hs)
            self.hints_routed[s] += len(idx)
        return n

    def poll(self, now: float) -> List[Tuple[Any, Any, float]]:
        out: List[Tuple[Any, Any, float]] = []
        for st in self.stores:
            out.extend(st.poll(now))
        return out

    def fetch_sync(self, keys: List[Any], now: float
                   ) -> Tuple[List[Any], float]:
        """On-demand staging across shards: per-shard makespans overlap
        (independent lane pools), so the critical path is their max."""
        blocks: List[Any] = [None] * len(keys)
        lat = 0.0
        for s, idx in self._split(np.asarray(keys)):
            blk, l = self.stores[s].fetch_sync([keys[i] for i in idx], now)
            for j, i in enumerate(idx):
                blocks[i] = blk[j]
            lat = max(lat, l)
        return blocks, lat

    def writeback(self, key: Any, blocks: Any) -> None:
        self.stores[int(self.shard_of(np.asarray([key]))[0])].writeback(
            key, blocks)

    def persist(self) -> int:
        return sum(st.persist() for st in self.stores)

    @property
    def in_flight(self) -> Dict[Any, Tuple[float, Any, float, float]]:
        merged: Dict[Any, Tuple[float, Any, float, float]] = {}
        for st in self.stores:
            merged.update(st.in_flight)
        return merged

    # -------------------------------------------------------------- migration
    def migrate_bins(self, bins: Sequence[int], dst: int) -> Dict[str, int]:
        """Move ownership of ``bins`` to shard ``dst`` (drain -> batched
        page transfer -> re-admit with preserved timestamps).  Dirty victims
        displaced at the destination go through its store's write-back path,
        exactly like a workload admission."""
        bins_arr = np.asarray(sorted(set(int(b) for b in bins)), np.int64)
        if ((bins_arr < 0) | (bins_arr >= self.n_bins)).any():
            raise ValueError("bin out of range")
        if not 0 <= dst < self.n_shards:
            raise ValueError("dst shard out of range")
        srcs = {int(s) for s in np.unique(self.owner[bins_arr])} - {dst}
        pages = entries = 0
        key_pred = lambda k: bool(np.isin(int(k) % self.n_bins, bins_arr))
        vec_pred = lambda keys: np.isin(np.mod(keys, self.n_bins), bins_arr)
        for src in srcs:
            keys, ts, dirty, blocks = self.arenas[src].export_where(vec_pred)
            if len(keys):
                adm = self.arenas[dst].admit(keys, ts, dirty=dirty)
                mask = (adm.evicted_keys >= 0) & adm.evicted_dirty
                for i in np.nonzero(mask)[0]:
                    self.stores[dst].writeback(
                        int(adm.evicted_keys[i]),
                        {p: blk[i] for p, blk in
                         adm.evicted_blocks.items()})
                self.arenas[dst].stage(adm.slots, blocks)
                pages += len(keys)
            entries += self.stores[dst].import_keys(
                self.stores[src].export_keys(key_pred))
        self.owner[bins_arr] = dst
        self.migrations += 1
        self.pages_migrated += pages
        self.tier_entries_migrated += entries
        return {"pages": pages, "tier_entries": entries,
                "sources": len(srcs)}

    # ---------------------------------------------------------------- metrics
    def stats(self) -> Dict[str, Any]:
        tot = self.hits + self.misses
        out: Dict[str, Any] = {
            "arena_hits": self.hits, "arena_misses": self.misses,
            "arena_hit_rate": self.hits / tot if tot else 0.0,
            "n_shards": self.n_shards, "n_bins": self.n_bins,
            "router_migrations": self.migrations,
            "router_pages_migrated": self.pages_migrated,
            "router_tier_entries_migrated": self.tier_entries_migrated,
            "shard_hints_routed": self.hints_routed.tolist(),
            "shard_pages_routed": self.pages_routed.tolist(),
        }
        arena_stats = [a.stats() for a in self.arenas]
        store_stats = [st.stats() for st in self.stores]
        sums: Dict[str, float] = {}
        for s in arena_stats:
            for k, v in s.items():
                if k not in ("arena_hits", "arena_misses", "arena_hit_rate"):
                    sums[k] = sums.get(k, 0) + v
        hidden = critical = 0.0
        for s in store_stats:
            hidden += s["store_hidden_latency"]
            critical += s["store_critical_latency"]
            for k, v in s.items():
                if k != "staging_overlap":
                    sums[k] = sums.get(k, 0) + v
        out.update(sums)
        tot_lat = hidden + critical
        out["staging_overlap"] = hidden / tot_lat if tot_lat else 0.0
        out["shard_arena_hit_rate"] = [s["arena_hit_rate"]
                                       for s in arena_stats]
        out["shard_prefetch_staged"] = [s["store_staged_pages"]
                                        for s in store_stats]
        return out
