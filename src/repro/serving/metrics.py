"""Serving-side metrics: TTFT/TPOT percentiles and staging accounting.

TTFT is measured from ENQUEUE (the moment the session key becomes known to
the lookahead/ingest stage) to the first emitted token, so it includes queue
wait plus any state-staging latency left on the critical path; TPOT is the
gap between consecutive tokens of one request.  Staging overlap is tracked
by the TieredStore (hidden vs critical-path latency) and folded into
``summary``.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def percentiles(samples: List[float], qs=(50, 90, 99)) -> Dict[str, float]:
    if not samples:
        return {f"p{q}": 0.0 for q in qs}
    arr = np.asarray(samples, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class ServingMetrics:
    def __init__(self):
        self.enqueue_t: Dict[int, float] = {}
        self.last_token_t: Dict[int, float] = {}
        self.ttft: List[float] = []
        self.tpot: List[float] = []
        self.done_t: List[float] = []
        self.t_start: Optional[float] = None
        self.t_end: float = 0.0
        self.n_requests = 0
        self.n_tokens = 0

    def record_enqueue(self, rid: int, now: float) -> None:
        self.enqueue_t[rid] = now
        self.n_requests += 1
        if self.t_start is None:
            self.t_start = now

    def record_token(self, rid: int, now: float) -> None:
        self.n_tokens += 1
        self.t_end = max(self.t_end, now)
        prev = self.last_token_t.get(rid)
        if prev is None:                        # first token of the request
            self.ttft.append(now - self.enqueue_t[rid])
        else:
            self.tpot.append(now - prev)
        self.last_token_t[rid] = now

    def record_done(self, rid: int, now: float) -> None:
        self.done_t.append(now)
        self.t_end = max(self.t_end, now)

    def summary(self, arena=None, store=None) -> Dict[str, float]:
        out: Dict[str, float] = {"n_requests": self.n_requests,
                                 "n_tokens": self.n_tokens}
        for name, v in percentiles(self.ttft).items():
            out[f"ttft_{name}"] = v
        out["ttft_mean"] = float(np.mean(self.ttft)) if self.ttft else 0.0
        for name, v in percentiles(self.tpot).items():
            out[f"tpot_{name}"] = v
        span = (self.t_end - self.t_start) if self.t_start is not None \
            else 0.0
        out["duration"] = span
        out["throughput_tok_s"] = self.n_tokens / span if span > 0 else 0.0
        if arena is not None:
            out.update(arena.stats())
        if store is not None:
            out.update(store.stats())
        return out
