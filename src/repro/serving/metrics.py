"""Serving-side metrics: TTFT/TPOT percentiles and staging accounting.

TTFT is measured from ENQUEUE (the moment the session key becomes known to
the lookahead/ingest stage) to the first emitted token, so it includes queue
wait plus any state-staging latency left on the critical path; TPOT is the
gap between consecutive tokens of one request.  Staging overlap is tracked
by the TieredStore (hidden vs critical-path latency) and folded into
``summary``.

Samples feed the unified metrics registry (DESIGN.md §12): attach one via
``bind_registry`` and every TTFT/TPOT observation also lands in the
``serving.ttft`` / ``serving.tpot`` streaming sketches, alongside the
``serving.requests`` / ``serving.tokens`` counters.  The raw sample lists
stay — short serving runs want exact percentiles and tests assert on them.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.obs import NULL_COUNTER, NULL_HISTOGRAM


def percentiles(samples: List[float], qs=(50, 90, 99)) -> Dict[str, float]:
    if not samples:
        return {f"p{q}": 0.0 for q in qs}
    arr = np.asarray(samples, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


class ServingMetrics:
    def __init__(self, registry=None):
        self.enqueue_t: Dict[int, float] = {}
        self.last_token_t: Dict[int, float] = {}
        self.ttft: List[float] = []
        self.tpot: List[float] = []
        self.done_t: List[float] = []
        self.t_start: Optional[float] = None
        self.t_end: float = 0.0
        self.n_requests = 0
        self.n_tokens = 0
        self._h_ttft = self._h_tpot = NULL_HISTOGRAM
        self._c_req = self._c_tok = NULL_COUNTER
        if registry is not None:
            self.bind_registry(registry)

    def bind_registry(self, registry) -> None:
        """Publish into a MetricsRegistry (DESIGN.md §12) on top of the
        local sample lists."""
        self._h_ttft = registry.histogram("serving.ttft")
        self._h_tpot = registry.histogram("serving.tpot")
        self._c_req = registry.counter("serving.requests")
        self._c_tok = registry.counter("serving.tokens")

    def record_enqueue(self, rid: int, now: float) -> None:
        self.enqueue_t[rid] = now
        self.n_requests += 1
        self._c_req.inc()
        if self.t_start is None:
            self.t_start = now

    def record_token(self, rid: int, now: float) -> None:
        self.n_tokens += 1
        self._c_tok.inc()
        self.t_end = max(self.t_end, now)
        prev = self.last_token_t.get(rid)
        if prev is None:                        # first token of the request
            self.ttft.append(now - self.enqueue_t[rid])
            self._h_ttft.observe(now - self.enqueue_t[rid])
        else:
            self.tpot.append(now - prev)
            self._h_tpot.observe(now - prev)
        self.last_token_t[rid] = now

    def record_done(self, rid: int, now: float) -> None:
        self.done_t.append(now)
        self.t_end = max(self.t_end, now)

    def summary(self, arena=None, store=None) -> Dict[str, float]:
        out: Dict[str, float] = {"n_requests": self.n_requests,
                                 "n_tokens": self.n_tokens}
        for name, v in percentiles(self.ttft).items():
            out[f"ttft_{name}"] = v
        out["ttft_mean"] = float(np.mean(self.ttft)) if self.ttft else 0.0
        for name, v in percentiles(self.tpot).items():
            out[f"tpot_{name}"] = v
        span = (self.t_end - self.t_start) if self.t_start is not None \
            else 0.0
        out["duration"] = span
        out["throughput_tok_s"] = self.n_tokens / span if span > 0 else 0.0
        if arena is not None:
            out.update(arena.stats())
        if store is not None:
            out.update(store.stats())
        return out
