"""Tiered session-state store: arena <-> host DRAM <-> backing tier.

The backing tier reuses ``streaming.backend`` (calibrated latency model,
DESIGN.md §8): the container has no real NVMe/remote KV, so page payloads
are held for real while only the clock is modelled.  Host DRAM is a second
``StateBackend`` with the in-memory model; pages read from backing are
promoted to host, and dirty victims written back land in host and are
flushed to backing by ``persist()`` (checkpoint) — the arena <-> host <->
backing walk of a real disaggregated deployment.

Staging is BATCHED and ASYNC: ``request_stage`` schedules reads over a
bounded lane pool (the paper's state-thread-pool parallelism) and returns
immediately; ``poll(now)`` surfaces completed pages for admission into the
arena.  Latency paid before the scheduler needed the page is HIDDEN
(overlapped with decode compute); ``fetch_sync`` charges the makespan on
the critical path instead — the on-demand baseline.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.streaming.backend import (DISAGGREGATED, IN_MEMORY, BackendModel,
                                     StateBackend)


class TieredStore:
    def __init__(self, backing_model: BackendModel = DISAGGREGATED,
                 host_model: BackendModel = IN_MEMORY,
                 page_bytes: int = 64 * 1024, workers: int = 8):
        self.backing = StateBackend(backing_model)
        self.host = StateBackend(host_model)
        self.page_bytes = page_bytes
        self._lane_free = [0.0] * workers
        # key -> (ready_at, blocks, latency, hint_ts)
        self.in_flight: Dict[Any, Tuple[float, Any, float, float]] = {}
        self._host_dirty: set = set()
        self.staged_pages = 0
        self.sync_fetches = 0
        self.writebacks = 0
        self.hidden_latency = 0.0      # staging latency overlapped w/ compute
        self.critical_latency = 0.0    # staging latency on the request path

    # ----------------------------------------------------------------- tiers
    def seed(self, key: Any, blocks: Any) -> None:
        """Populate the backing tier (session history persisted earlier)."""
        self.backing.write(key, blocks, self.page_bytes)

    def _read_tier(self, key: Any) -> Tuple[Any, float]:
        """Read one page from the fastest tier holding it; promote to host."""
        if key in self.host.data:
            return self.host.fetch(key, self.page_bytes)
        blocks, lat = self.backing.fetch(key, self.page_bytes)
        if blocks is not None:
            self.host.write(key, blocks, self.page_bytes)   # promotion
        return blocks, lat

    # --------------------------------------------------------- async staging
    def _issue(self, key: Any, now: float, hint_ts: float) -> float:
        """Schedule one read on the least-loaded lane; returns ready_at."""
        blocks, lat = self._read_tier(key)
        lane = min(range(len(self._lane_free)),
                   key=lambda i: self._lane_free[i])
        start = max(now, self._lane_free[lane])
        ready = start + lat
        self._lane_free[lane] = ready
        self.in_flight[key] = (ready, blocks, lat, hint_ts)
        return ready

    def request_stage(self, keys: List[Any], now: float,
                      hint_ts: Optional[List[float]] = None) -> int:
        """Batched async staging: schedule every key not already in flight.
        ``hint_ts`` carries each page's PREDICTED ACCESS TIME (the hint
        timestamp the arena will admit it with).  Returns the number of new
        requests issued."""
        n = 0
        for i, k in enumerate(keys):
            t_pred = hint_ts[i] if hint_ts is not None else now
            if k in self.in_flight:
                # a fresher (earlier) prediction refines the pending one
                ready, blocks, lat, old = self.in_flight[k]
                self.in_flight[k] = (ready, blocks, lat, min(old, t_pred))
                continue
            self._issue(k, now, t_pred)
            n += 1
        return n

    def poll(self, now: float) -> List[Tuple[Any, Any, float]]:
        """Surface staged (key, blocks, hint_ts) whose I/O has completed."""
        done = [(k, blocks, hint) for k, (ready, blocks, _, hint) in
                self.in_flight.items() if ready <= now]
        for k, _, _ in done:
            _, _, lat, _ = self.in_flight.pop(k)
            self.hidden_latency += lat
            self.staged_pages += 1
        return done

    # ---------------------------------------------------------- sync staging
    def fetch_sync(self, keys: List[Any], now: float
                   ) -> Tuple[List[Any], float]:
        """On-demand staging: block until every page (including any already
        in flight) is ready; the makespan is charged to the critical path."""
        ready_until = now
        out = []
        for k in keys:
            if k in self.in_flight:                # adopt the async request
                ready, blocks, lat, _ = self.in_flight.pop(k)
                # the part of the I/O that elapsed before now was hidden;
                # only the remainder lands on the request path
                self.hidden_latency += min(lat, max(0.0, now - (ready - lat)))
                self.critical_latency += max(0.0, ready - now)
                self.staged_pages += 1
            else:
                ready = self._issue(k, now, now)
                _, blocks, lat, _ = self.in_flight.pop(k)
                self.critical_latency += lat
                self.staged_pages += 1
            self.sync_fetches += 1
            ready_until = max(ready_until, ready)
            out.append(blocks)
        return out, ready_until - now

    # ------------------------------------------------------------- migration
    def export_keys(self, pred) -> Dict[str, Any]:
        """Key-range migration (DESIGN.md §9): pop every tier entry — host,
        backing, host-dirty flag, and in-flight stage requests — whose key
        satisfies ``pred`` (scalar predicate).  In-flight requests keep
        their ready times: a page already being staged at the source keeps
        overlapping I/O with compute at the destination."""
        moved: Dict[str, Any] = {
            "host": {k: self.host.data.pop(k)
                     for k in [k for k in self.host.data if pred(k)]},
            "backing": {k: self.backing.data.pop(k)
                        for k in [k for k in self.backing.data if pred(k)]},
            "in_flight": {k: self.in_flight.pop(k)
                          for k in [k for k in self.in_flight if pred(k)]},
        }
        moved["dirty"] = {k for k in list(self._host_dirty) if pred(k)}
        self._host_dirty -= moved["dirty"]
        return moved

    def import_keys(self, moved: Dict[str, Any]) -> int:
        """Land a migration export in this store's tiers (bulk transfer,
        off the request path; tier read/write counters track workload I/O,
        so migration moves the dicts directly)."""
        self.host.data.update(moved["host"])
        self.backing.data.update(moved["backing"])
        self.in_flight.update(moved["in_flight"])
        self._host_dirty |= moved["dirty"]
        return sum(len(moved[t]) for t in ("host", "backing", "in_flight"))

    # ------------------------------------------------------------ write-back
    def writeback(self, key: Any, blocks: Any) -> None:
        """Dirty victim evicted from the arena: lands in host DRAM, flushed
        to backing asynchronously (never on the request path)."""
        self.host.write(key, blocks, self.page_bytes)
        self._host_dirty.add(key)
        self.writebacks += 1

    def persist(self) -> int:
        """Checkpoint: flush host-dirty pages to the backing tier."""
        n = 0
        for k in list(self._host_dirty):
            self.backing.write(k, self.host.data[k], self.page_bytes)
            self._host_dirty.discard(k)
            n += 1
        return n

    # --------------------------------------------------------------- metrics
    def stats(self) -> Dict[str, float]:
        tot = self.hidden_latency + self.critical_latency
        return {"store_staged_pages": self.staged_pages,
                "store_sync_fetches": self.sync_fetches,
                "store_writebacks": self.writebacks,
                "store_backing_reads": self.backing.reads,
                "store_backing_writes": self.backing.writes,
                "store_host_reads": self.host.reads,
                "store_hidden_latency": self.hidden_latency,
                "store_critical_latency": self.critical_latency,
                "staging_overlap": self.hidden_latency / tot if tot else 0.0}
