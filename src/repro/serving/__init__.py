"""Paged session-state serving subsystem (DESIGN.md §6).

The paper's pipeline, recast for stateful LM serving: request session keys
are known at ENQUEUE time (the upstream-lookahead role), so KV-cache pages
can be staged from the slow session store into fixed device slots before the
scheduler picks the request up.

    arena.py     - PagedStateArena: physical page pool + device TAC page table
    store.py     - TieredStore: arena <-> host DRAM <-> modelled backing tier
    scheduler.py - continuous-batching scheduler with enqueue-time hints
    router.py    - ShardRouter: per-shard arenas/stores + key-range migration
    metrics.py   - TTFT/TPOT percentiles, hit-rate, staging-overlap accounting
"""
from repro.serving.arena import PagedStateArena
from repro.serving.metrics import ServingMetrics, percentiles
from repro.serving.router import ShardRouter
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SimClock, WallClock)
from repro.serving.store import TieredStore

__all__ = ["PagedStateArena", "TieredStore", "ContinuousBatchingScheduler",
           "Request", "ServingMetrics", "ShardRouter", "SimClock",
           "WallClock", "percentiles"]
