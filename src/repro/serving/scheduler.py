"""Continuous-batching request scheduler with enqueue-time key hints.

The INGEST stage plays the paper's upstream-lookahead role: a request's
session key (hence the exact set of state pages it will touch) is known the
moment it is enqueued, long before the scheduler picks it up.  In
``prefetch`` mode, ``submit`` immediately hints the tiered store, which
stages the pages toward the arena while the request waits in the queue — so
decode starts the instant the request is scheduled.

Modes mirror ``StatefulOp`` (streaming/engine.py), so the paper's
sync/async/prefetch comparison runs on the serving path too:

  sync     - missing pages are fetched ON DEMAND, blocking the scheduler
             (staging makespan on the critical path);
  async    - missing pages are requested when the request first comes up
             for scheduling; the request PARKS and the scheduler moves on
             (I/O overlapped, but no lookahead window);
  prefetch - async + staging begins at ENQUEUE time via the ingest hint.

Only requests whose pages are all resident are scheduled; everything else
parks until ``poll``ed completions admit their pages.

The scheduler is storage-topology-agnostic: ``arena``/``store`` can be one
``PagedStateArena`` + ``TieredStore`` pair, or a ``ShardRouter``
(serving/router.py) passed as BOTH — the router exposes the same batched
interface over per-shard pairs, so hints route to owning shards and
key-range migrations happen underneath without scheduler changes
(DESIGN.md §9).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.arena import PagedStateArena
from repro.serving.metrics import ServingMetrics
from repro.serving.store import TieredStore


class WallClock:
    """Real time; ``sleep`` actually blocks (live serving)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)

    def advance(self, dt: float) -> None:      # compute time passes for real
        pass


class SimClock:
    """Virtual time: modelled I/O latencies and measured compute advance the
    same clock, so benchmarks mix REAL jitted decode cost with modelled
    store latency without wall-clock sleeping."""

    def __init__(self, t0: float = 0.0):
        self.t = t0

    def now(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        if dt > 0:
            self.t += dt

    def advance(self, dt: float) -> None:
        if dt > 0:
            self.t += dt


@dataclass
class Request:
    rid: int
    session: int
    page_keys: np.ndarray                  # int32 page keys this request uses
    n_tokens: int = 1                      # decode steps wanted
    enqueue_t: float = 0.0
    state: str = "queued"                  # queued | parked | ready | done
    tokens_done: int = 0
    meta: Dict[str, Any] = field(default_factory=dict)  # e.g. decode pos


class ContinuousBatchingScheduler:
    def __init__(self, arena: PagedStateArena, store: TieredStore,
                 mode: str = "prefetch", max_batch: int = 4,
                 clock=None, metrics: Optional[ServingMetrics] = None,
                 hint_horizon: float = 1e-3,
                 stage_ahead: Optional[int] = None):
        assert mode in ("sync", "async", "prefetch")
        self.arena = arena
        self.store = store
        self.mode = mode
        self.max_batch = max_batch
        self.clock = clock if clock is not None else WallClock()
        self.metrics = metrics if metrics is not None else ServingMetrics()
        # minimum hint lead: a prefetched page's timestamp must sit in the
        # future so it is protected until its request runs (paper §IV-D)
        self.hint_horizon = hint_horizon
        # timeliness bound: only stage for the first `stage_ahead` queue
        # positions, so prefetch for deep-queue requests cannot thrash the
        # arena out from under the requests about to run
        self.stage_ahead = stage_ahead
        self.queue: List[Request] = []
        self.hints_emitted = 0
        self.parked_events = 0
        # EWMA of per-request service time: spaces predicted access times
        self.service_est = 2e-3
        self._last_sched_t: Optional[float] = None

    # ---------------------------------------------------------------- ingest
    def submit(self, req: Request) -> None:
        now = self.clock.now()
        req.enqueue_t = now
        self.metrics.record_enqueue(req.rid, now)
        self.queue.append(req)
        if self.mode == "prefetch":        # ingest = the lookahead operator
            self._hint(req, now, queue_pos=len(self.queue) - 1)

    def _stage_window(self, req: Request) -> int:
        """How many queue positions ahead staging is allowed to run: at most
        what the arena can hold on top of the running batch."""
        if self.stage_ahead is not None:
            return self.stage_ahead
        per_req = max(1, len(req.page_keys))
        return max(self.max_batch,
                   self.arena.n_slots // per_req - self.max_batch)

    def _predicted_access(self, now: float, queue_pos: int) -> float:
        """Hint timestamp = predicted access time.  FIFO order spaces the
        predictions by the measured service rate, so min-ts eviction
        prefers pages needed FURTHEST in the future (the paper's
        timestamp-ordering argument, transplanted to serving)."""
        waves = queue_pos // max(1, self.max_batch)
        return now + self.hint_horizon + waves * self.service_est

    def _hint(self, req: Request, now: float, queue_pos: int) -> None:
        """Keyed-prefetching hint: renew resident pages (protect them until
        the request runs), stage the rest from the store."""
        if queue_pos >= self._stage_window(req):
            return                          # too early to be timely
        self.hints_emitted += 1
        t_pred = self._predicted_access(now, queue_pos)
        hit, _ = self.arena.probe(req.page_keys, count=False)
        resident = req.page_keys[hit]
        if resident.size:
            self.arena.renew(resident,
                             np.full(resident.shape, t_pred, np.float32))
        missing = [int(k) for k in req.page_keys[~hit]]
        if missing:
            self.store.request_stage(missing, now,
                                     [t_pred] * len(missing))
        req.meta["hinted"] = True

    # ------------------------------------------------------------ completion
    def absorb_completions(self) -> int:
        """Admit every staged page that completed: one batched admit + one
        batched stage; dirty victims go back to the store."""
        now = self.clock.now()
        done = self.store.poll(now)
        if not done:
            return 0
        keys = np.asarray([k for k, _, _ in done], np.int32)
        # admit with the PREDICTED ACCESS TIME captured when the stage was
        # requested (never in the past: stale predictions stay evictable)
        ts = np.asarray([max(h, now + self.hint_horizon)
                         for _, _, h in done], np.float32)
        adm = self.arena.admit(keys, ts)
        self._writeback_victims(adm)
        blocks = self._collate([b for _, b, _ in done])
        self.arena.stage(adm.slots, blocks)
        return len(done)

    def _collate(self, block_dicts: List[Dict[str, Any]]) -> Dict[str, Any]:
        pools = block_dicts[0].keys()
        return {p: jnp.stack([jnp.asarray(d[p]) for d in block_dicts])
                for p in pools}

    def _writeback_victims(self, adm) -> None:
        mask = (adm.evicted_keys >= 0) & adm.evicted_dirty
        for i in np.nonzero(mask)[0]:
            self.store.writeback(
                int(adm.evicted_keys[i]),
                {p: blk[i] for p, blk in adm.evicted_blocks.items()})

    # ------------------------------------------------------------ scheduling
    def schedule(self) -> List[Request]:
        """Pick up to ``max_batch`` requests whose pages are ALL resident;
        park the rest (sync mode blocks and stages instead of parking)."""
        self.absorb_completions()
        now = self.clock.now()
        if self._last_sched_t is not None and now > self._last_sched_t:
            # per-wave service estimate feeds the access-time predictions
            self.service_est = (0.8 * self.service_est
                                + 0.2 * min(now - self._last_sched_t, 0.25))
        batch: List[Request] = []
        for pos, req in enumerate(self.queue):
            if len(batch) >= self.max_batch:
                break
            hit, _ = self.arena.probe(req.page_keys,
                                      now_ts=np.full(len(req.page_keys), now,
                                                     np.float32),
                                      count=False)
            # hit-rate accounting: one access per page per SCHEDULING
            # ATTEMPT transition — ready counts its hits, the first failed
            # attempt counts the misses; re-polls of parked requests don't
            if bool(hit.all()):
                self.arena.count_access(len(req.page_keys), 0)
                req.state = "ready"
                batch.append(req)
                continue
            if req.state != "parked":
                self.arena.count_access(int(hit.sum()), int((~hit).sum()))
            missing = [int(k) for k in req.page_keys[~hit]]
            if self.mode == "sync":
                # on-demand staging blocks the scheduler: the makespan sits
                # on this (and every queued) request's critical path
                blocks, lat = self.store.fetch_sync(missing, now)
                self.clock.sleep(lat)
                now = self.clock.now()
                adm = self.arena.admit(
                    np.asarray(missing, np.int32),
                    np.full(len(missing), now, np.float32))
                self._writeback_victims(adm)
                self.arena.stage(adm.slots, self._collate(blocks))
                req.state = "ready"
                batch.append(req)
            elif pos < self._stage_window(req):
                # async: on-demand but non-blocking; prefetch already staged
                # at enqueue, so this covers pages evicted meanwhile and
                # requests that entered the timeliness window just now
                t_pred = self._predicted_access(now, pos)
                self.store.request_stage(missing, now,
                                         [t_pred] * len(missing))
                if req.state != "parked":
                    req.state = "parked"
                    self.parked_events += 1
        if batch:
            self._last_sched_t = now
        return batch

    # --------------------------------------------------------------- tokens
    def complete_token(self, req: Request,
                       dirty_keys: Optional[np.ndarray] = None) -> None:
        """One decode step finished for ``req``; pages it mutated in place
        are flagged dirty so eviction writes them back."""
        now = self.clock.now()
        req.tokens_done += 1
        self.metrics.record_token(req.rid, now)
        if dirty_keys is not None and len(dirty_keys):
            self.arena.mark_dirty(np.asarray(dirty_keys, np.int32))
        if req.tokens_done >= req.n_tokens:
            req.state = "done"
            self.metrics.record_done(req.rid, now)
            self.queue.remove(req)

    def wait_for_progress(self) -> bool:
        """Nothing schedulable: sleep until the next staging completion (the
        serving loop's idle edge).  Returns False when no I/O is in flight —
        the caller must submit work or stop."""
        if not self.store.in_flight:
            return False
        now = self.clock.now()
        ready = min(r for r, *_ in self.store.in_flight.values())
        self.clock.sleep(max(0.0, ready - now) + 1e-6)
        return True

    # ------------------------------------------------------------------ misc
    @property
    def pending(self) -> int:
        return len(self.queue)

    def drain_dirty(self) -> int:
        """Shutdown/checkpoint: push all dirty arena pages through the store
        write-back path and persist the host tier."""
        keys, blocks = self.arena.flush_dirty()
        for i, k in enumerate(keys):
            self.store.writeback(int(k),
                                 {p: blk[i] for p, blk in blocks.items()})
        return self.store.persist()

    def stats(self) -> Dict[str, float]:
        out = self.metrics.summary(self.arena, self.store)
        out["hints_emitted"] = self.hints_emitted
        out["parked_events"] = self.parked_events
        out["mode"] = self.mode
        return out
