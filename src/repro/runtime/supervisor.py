"""Fault-tolerance supervisor: checkpoint/restart, straggler mitigation,
and elastic re-meshing.

The training loop runs under the supervisor; failures (real exceptions or
injected ones for tests) roll back to the latest checkpoint and replay the
deterministic data pipeline from the recorded step.  Step-time outliers
beyond ``straggler_factor`` x the running median are logged and counted —
on a real fleet this triggers hot-spare swap-in; here it drives the
mitigation bookkeeping that tests assert on.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclass
class SupervisorConfig:
    checkpoint_every: int = 20
    straggler_factor: float = 3.0
    max_restarts: int = 3


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)


class TrainSupervisor:
    def __init__(self, cfg: SupervisorConfig, ckpt: CheckpointManager):
        self.cfg = cfg
        self.ckpt = ckpt

    def run(self, state: Any, step_fn: Callable[[Any, int], Any],
            n_steps: int, start_step: int = 0,
            failure_injector: Optional[Callable[[int], None]] = None,
            delay_injector: Optional[Callable[[int], float]] = None
            ) -> SupervisorReport:
        """state: (params, opt_state); step_fn(state, step) ->
        (state, metrics)."""
        rep = SupervisorReport()
        step = start_step
        restarts = 0
        while step < n_steps:
            try:
                t0 = time.time()
                if failure_injector:
                    failure_injector(step)
                if delay_injector:
                    extra = delay_injector(step)
                    if extra:
                        time.sleep(extra)
                state, metrics = step_fn(state, step)
                dt = time.time() - t0
                rep.step_times.append(dt)
                med = float(np.median(rep.step_times[-32:]))
                if len(rep.step_times) > 4 and dt > self.cfg.straggler_factor * med:
                    rep.stragglers += 1
                if "loss" in metrics:
                    rep.losses.append(float(metrics["loss"]))
                rep.steps_run += 1
                step += 1
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, state, extra={"data_step": step})
            except _InjectedFailure:
                restarts += 1
                rep.restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise RuntimeError("too many restarts")
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step       # cold restart
                    continue
                step, state, extra = self.ckpt.restore(state)
                step = extra.get("data_step", step)
        self.ckpt.wait()
        return rep


class _InjectedFailure(Exception):
    """Simulated node failure."""


def inject_failure_at(fail_steps) -> Callable[[int], None]:
    fired = set()

    def injector(step: int) -> None:
        if step in fail_steps and step not in fired:
            fired.add(step)
            raise _InjectedFailure(f"injected failure at step {step}")

    return injector
