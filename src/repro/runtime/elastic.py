"""Elastic re-meshing: reshard a checkpointed state onto a different mesh.

When the device pool changes (node loss, pool grow), training resumes on a
new (data', model') mesh: parameter PartitionSpecs are re-derived by the
same rules and the state is re-placed with jax.device_put — the spec logic
is mesh-shape-agnostic, so elasticity is a pure relaunch concern.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.launch.sharding import Axis, default_rules
from repro.launch.specs import ShardingPolicy, param_pspec_tree


def reshard_params(params: Any, new_mesh: Mesh,
                   rules: Dict[str, Axis] = None,
                   policy: ShardingPolicy = None) -> Any:
    rules = rules or default_rules(multi_pod="pod" in new_mesh.shape)
    policy = policy or ShardingPolicy(fsdp_params=True)
    specs = param_pspec_tree(params, new_mesh, rules, policy)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        params, specs)
