"""int8 gradient compression with error feedback (distributed-optimization
trick for the 1000+ node posture).

``make_compressor`` returns a grad_transform for ``make_train_step``: each
tensor is quantised to int8 with a per-tensor scale before entering the
optimizer; the quantisation error is carried into the next step (error
feedback), which keeps SGD/Adam convergence intact (Karimireddy et al. 2019).
On a real mesh the int8 payload is what crosses the wire — ``int8_allreduce``
below is the shard_map collective that performs the reduction in int8 —
while under GSPMD auto-parallelisation we apply the numerics transform and
let XLA keep the reduction fused.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_compressor() -> Tuple[Callable, Callable]:
    """Returns (init_error_state, grad_transform(grads, err) ->
    (grads', err'))."""

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

    def transform(grads, err):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, s = quantize_int8(g32)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), g32 - deq
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(err)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
                jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))

    return init, transform


def int8_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """shard_map-style collective: quantise locally, all-reduce the int8
    payload (summed in int32), dequantise with the max scale."""
    q, scale = quantize_int8(x)
    scale = jax.lax.pmax(scale, axis_name)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
