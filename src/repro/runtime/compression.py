"""Wire compression: hint-key delta codec + int8 gradient compression.

Two independent planes share this module:

* **Hint-channel delta codec** (DESIGN.md §13) — stdlib/numpy-free
  encoding of a BATCH of integer state-access keys for the hint side
  channel.  Keys in one flushed hint batch cluster tightly (NEXMark
  auction ids are dense and monotone; window panes share the wid), so
  sorting the batch and sending base + per-key deltas shrinks 8-byte
  keys to ~1 byte each.  Format (little-endian):

      [u32 count n] [u64 base] ([u8 delta] | [0xFF escape][u64 delta]) * (n-1)

  Decoding returns the sorted key MULTISET (duplicates survive as zero
  deltas); hint semantics are order-free, so sorting is lossless for the
  prefetcher.  Composite keys (``WindowKey`` and other int tuples)
  encode as one stream per tuple position.  ``hint_batch_nbytes`` is the
  engine-facing entry point: it sizes a flushed hint batch for the
  channel's byte accounting (``streaming/engine.py``) without the
  engine importing jax.

* **int8 gradient compression with error feedback** (the distributed-
  optimization trick for the 1000+ node posture): ``make_compressor``
  returns a grad_transform for ``make_train_step``; the quantisation
  error carries into the next step, keeping SGD/Adam convergence intact
  (Karimireddy et al. 2019).  ``int8_allreduce`` is the shard_map
  collective twin.  jax imports are LAZY so the streaming engine can use
  the codec above without pulling in the accelerator toolchain.

``quantize_int8`` was written for float gradient tensors; its per-tensor
float scale silently corrupts integer key deltas (``round(k/scale)*scale``
is not ``k``).  Integer dtypes now take a lossless scale-1 path and raise
when a value cannot be represented exactly in int8 — callers with wider
integer payloads must delta-encode first (``delta_encode_keys``).
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, List, Tuple

_U64_MAX = (1 << 64) - 1
_ESCAPE = 0xFF


# --------------------------------------------------------- hint-key codec
def delta_encode_keys(keys: Iterable[int]) -> bytes:
    """Encode an integer key batch as sorted base + deltas (format above).

    Input order is NOT preserved (hints are order-free); duplicates are.
    Raises ``ValueError`` for negative keys or keys above 2**64 - 1 —
    the caller falls back to fixed-width for such batches.
    """
    ks = sorted(int(k) for k in keys)
    if ks and (ks[0] < 0 or ks[-1] > _U64_MAX):
        raise ValueError(f"key out of u64 range: "
                         f"[{ks[0]}, {ks[-1]}] not in [0, 2**64)")
    out = bytearray(len(ks).to_bytes(4, "little"))
    if not ks:
        return bytes(out)
    out += ks[0].to_bytes(8, "little")
    prev = ks[0]
    for k in ks[1:]:
        d = k - prev
        prev = k
        if d < _ESCAPE:
            out.append(d)
        else:
            out.append(_ESCAPE)
            out += d.to_bytes(8, "little")
    return bytes(out)


def delta_decode_keys(buf: bytes) -> List[int]:
    """Inverse of ``delta_encode_keys``: the sorted key multiset."""
    n = int.from_bytes(buf[:4], "little")
    if n == 0:
        if len(buf) != 4:
            raise ValueError("trailing bytes after empty batch")
        return []
    ks = [int.from_bytes(buf[4:12], "little")]
    i = 12
    for _ in range(n - 1):
        d = buf[i]
        i += 1
        if d == _ESCAPE:
            d = int.from_bytes(buf[i:i + 8], "little")
            i += 8
        ks.append(ks[-1] + d)
    if i != len(buf):
        raise ValueError(f"trailing bytes: consumed {i} of {len(buf)}")
    return ks


def hint_batch_nbytes(keys: Iterable[Any], ts_bytes: int = 4) -> int:
    """Wire size of one flushed hint batch under the delta codec
    (DESIGN.md §13).  Plain int keys form one delta stream; int tuples
    (``WindowKey`` et al.) form one stream per position, grouped by
    arity; anything else (string keys, negatives) falls back to 8 bytes.
    Each hint additionally carries its access timestamp as float32
    (``ts_bytes``) — timestamps do not cluster like keys, so they ship
    uncompressed."""
    ints: List[int] = []
    tuple_streams: dict = {}        # arity -> list of position streams
    fallback = 0
    n = 0
    for k in keys:
        n += 1
        if isinstance(k, bool):
            fallback += 8
        elif isinstance(k, int):
            if 0 <= k <= _U64_MAX:
                ints.append(k)
            else:
                fallback += 8
        elif isinstance(k, tuple) and k and \
                all(isinstance(p, int) and 0 <= p <= _U64_MAX for p in k):
            streams = tuple_streams.setdefault(
                len(k), [[] for _ in range(len(k))])
            for i, p in enumerate(k):
                streams[i].append(p)
        else:
            fallback += 8
    total = fallback + ts_bytes * n
    if ints:
        total += len(delta_encode_keys(ints))
    for streams in tuple_streams.values():
        for stream in streams:
            total += len(delta_encode_keys(stream))
    return total


# ---------------------------------------------------- int8 grad compression
def quantize_int8(x) -> Tuple[Any, Any]:
    """Quantise to int8 with a per-tensor scale.

    Float tensors keep the gradient-compression semantics (lossy, max-abs
    scale).  INTEGER tensors take a lossless scale-1 path — a float scale
    would corrupt key deltas — and raise when any value falls outside
    [-127, 127] (callers escape to ``delta_encode_keys``)."""
    import jax.numpy as jnp
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.integer):
        import numpy as np
        xn = np.asarray(x)
        if xn.size and int(np.abs(xn.astype(np.int64)).max()) > 127:
            raise ValueError(
                "integer payload exceeds int8 range; int8 quantisation "
                "would be lossy — delta-encode keys first "
                "(delta_encode_keys)")
        return (jnp.asarray(xn.astype(np.int8)),
                jnp.asarray(1.0, dtype=jnp.float32))
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    import jax.numpy as jnp
    return q.astype(jnp.float32) * scale


def make_compressor() -> Tuple[Callable, Callable]:
    """Returns (init_error_state, grad_transform(grads, err) ->
    (grads', err'))."""
    import jax
    import jax.numpy as jnp

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)

    def transform(grads, err):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, s = quantize_int8(g32)
            deq = dequantize_int8(q, s)
            return deq.astype(g.dtype), g32 - deq
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(err)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
                jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))

    return init, transform


def int8_allreduce(x, axis_name: str):
    """shard_map-style collective: quantise locally, all-reduce the int8
    payload (summed in int32), dequantise with the max scale."""
    import jax
    import jax.numpy as jnp
    q, scale = quantize_int8(x)
    scale = jax.lax.pmax(scale, axis_name)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
