"""State backends with calibrated latency models (DESIGN.md §8).

The container has no NVMe array or remote Redis; the backends model access
latency (seek + size/bandwidth) and bounded I/O parallelism while holding the
actual key->state dict, so policy behaviour (what is fetched, when, hit
ratios, write-back volume) is real and only the clock is simulated.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass
from typing import Any, Dict, Optional, Set, Tuple


@dataclass(frozen=True)
class BackendModel:
    name: str
    base_latency: float           # seconds per op
    bandwidth: float              # bytes/s
    parallelism: int = 8          # concurrent ops per subtask


# effective RocksDB-on-NVMe read (device ~80us + read-amp/block decode)
LOCAL_NVME = BackendModel("nvme", 250e-6, 2.0e9, parallelism=8)
# remote KV (same-DC Redis-class RTT + transfer)
DISAGGREGATED = BackendModel("disagg", 300e-6, 1.2e9, parallelism=32)
IN_MEMORY = BackendModel("mem", 1e-6, 50e9, parallelism=64)


class StateBackend:
    """Key-value store for one stateful subtask."""

    def __init__(self, model: BackendModel, default_factory=None,
                 assume_present: bool = False):
        self.model = model
        self.data: Dict[Any, Any] = {}
        self.default_factory = default_factory
        # static/enrichment tables (YSB campaigns, Q13 side input) are fully
        # populated: every lookup pays the full read, no bloom fast path
        self.assume_present = assume_present
        self.reads = 0
        self.writes = 0
        self.bytes_read = 0
        self.bytes_written = 0
        # incremental-checkpoint delta (DESIGN.md §7): keys materialized/
        # written and keys deleted since the last snapshot_delta() cut.
        # Tracking is OFF until a CheckpointCoordinator attaches (it must
        # attach before data flows so the first epoch's delta covers all
        # state) — otherwise the tombstone set would grow without bound
        # in runs that never checkpoint
        self.track_deltas = False
        self._epoch_dirty: Set[Any] = set()
        self._epoch_deleted: Set[Any] = set()

    NEGATIVE_LOOKUP = 20e-6   # bloom-filter fast path for absent keys

    def latency(self, size: int) -> float:
        return self.model.base_latency + size / self.model.bandwidth

    def peek_latency(self, key: Any, size: int = 200):
        '''(would-be state?, latency) without counting a read.'''
        if self.assume_present or key in self.data:
            return True, self.latency(size)
        return False, self.NEGATIVE_LOOKUP

    def fetch(self, key: Any, size: int = 200):
        '''Read with presence-aware latency: absent keys are answered by the
        store's bloom filters (paper Q18 discussion).'''
        present, lat = self.peek_latency(key, size)
        state = self.read(key, size)
        return state, lat

    def read(self, key: Any, size: int = 200) -> Any:
        self.reads += 1
        self.bytes_read += size
        if key not in self.data and self.default_factory is not None:
            # first touch materializes state: it belongs to the epoch's
            # delta like any other write (DESIGN.md §7)
            self.data[key] = self.default_factory(key)
            if self.track_deltas:
                self._epoch_dirty.add(key)
                self._epoch_deleted.discard(key)
        return self.data.get(key)

    def write(self, key: Any, value: Any, size: int = 200) -> None:
        self.writes += 1
        self.bytes_written += size
        self.data[key] = value
        if self.track_deltas:
            self._epoch_dirty.add(key)
            self._epoch_deleted.discard(key)

    def delete(self, key: Any) -> bool:
        """Drop a key (fired-window purge, DESIGN.md §10).  Tombstone
        writes are cheap and batched in real stores, so this is not
        charged as workload I/O.  The tombstone IS logged in the epoch
        delta (§7): an incremental restore must not resurrect the key."""
        if self.data.pop(key, None) is not None:
            if self.track_deltas:
                self._epoch_deleted.add(key)
                self._epoch_dirty.discard(key)
            return True
        return False

    # ------------------------------------------------------ shard migration
    def export_keys(self, pred) -> Dict[Any, Any]:
        """Migration handoff (DESIGN.md §9): pop every entry whose key
        satisfies ``pred``.  The authoritative copy of a migrating shard
        moves with it; the bulk transfer runs off the tuple path, so read/
        write counters (workload I/O) are not charged.  The departures are
        logged as epoch-delta tombstones (§7) so an incremental snapshot of
        THIS partition stops covering the moved keys."""
        out = {}
        for k in [k for k in self.data if pred(k)]:
            out[k] = self.data.pop(k)
            if self.track_deltas:
                self._epoch_deleted.add(k)
                self._epoch_dirty.discard(k)
        return out

    def import_keys(self, items: Dict[Any, Any]) -> int:
        """Land a migration export in this backend's partition (logged as
        epoch-delta writes, DESIGN.md §7)."""
        self.data.update(items)
        if self.track_deltas:
            self._epoch_dirty.update(items)
            self._epoch_deleted.difference_update(items)
        return len(items)

    # ------------------------------------------------- checkpoint (§7)
    def snapshot_delta(self) -> Tuple[Dict[Any, Any], Set[Any]]:
        """Barrier-time incremental export (DESIGN.md §7): deep copies of
        every entry written since the last cut, plus the tombstone set.
        Deep copies because operators mutate hot state in place (§11) —
        a shallow snapshot would keep mutating after the barrier.  Like
        the migration drain, the export runs off the tuple path and is
        metered as snapshot bytes, not workload reads; the restore of
        these bytes IS charged at backend speed (streaming/recovery.py).
        """
        delta = {k: copy.deepcopy(self.data[k])
                 for k in self._epoch_dirty if k in self.data}
        deleted = set(self._epoch_deleted)
        self._epoch_dirty.clear()
        self._epoch_deleted.clear()
        return delta, deleted

    def restore_snapshot(self, items: Dict[Any, Any]) -> int:
        """Recovery (DESIGN.md §7): replace this partition with the
        materialized snapshot state.  The caller charges the bulk read at
        backend speed (no free reads on the restore path)."""
        self.data = dict(items)
        self._epoch_dirty.clear()
        self._epoch_deleted.clear()
        return len(self.data)

    def reset(self) -> None:
        """Failure handling: drop the (volatile stand-in) partition before
        restore re-imports the durable snapshot."""
        self.data.clear()
        self._epoch_dirty.clear()
        self._epoch_deleted.clear()
