"""Deterministic chaos harness with an exactly-once state-effect oracle
(DESIGN.md §15).

The paper's claim — prefetching hides state-access latency for queries
that run forever — is only credible if correctness survives what long
runs actually see: failures, migrations, and load shifts landing
CONCURRENTLY.  This module turns that into a falsifiable check:

  * ``FaultSchedule`` — a seeded, picklable schedule of ``FaultEvent``s
    (failure@t, migrate_shard@t, load_shift@t, hint-channel drop/delay
    windows, state corruption), composable and overlapping.  Events fire
    on the engine's DISCRETE-EVENT clock, and every random draw (the
    workload's and the chaos plane's) comes from a counter-based
    generator, so a schedule replays bit-exactly: same schedule, same
    run, down to the last cache eviction.
  * ``run_schedule`` — drives the NEXMark q11 session query (the window
    type whose fire deadlines MOVE, stressing re-hints and the TAC's
    renew path) under a schedule and returns the run's observable state
    effects.
  * ``compare`` — the exactly-once oracle: a perturbed run must match
    the unperturbed golden run of the same seed on (1) final keyed
    state, (2) the final session registry, and (3) the LAST emitted
    result of every surviving pane.  The recovery plane is exactly-once
    in STATE but at-least-once in EMISSION (DESIGN.md §7), and fire/
    merge races move intermediate emits between runs — so duplicate
    emissions and transient fires of merged-away panes are recorded as
    DEVIATIONS, not violations.
  * ``minimize`` — greedy delta-debugging: drop one event at a time,
    keep any subset that still violates the oracle, repeat to a fixed
    point.  The minimal reproducer pickles as an artifact.

Oracle soundness (why state effects are perturbation-invariant here):
the chaos workload fixes ``gap + lateness > oo_bound`` and ``lateness
>= oo_bound``.  A tuple is at most ``2*oo_bound`` behind arrival and a
watermark is at least ``oo_bound`` behind it, so ``ts >= wm - oo`` at
every operator — which makes the lateness-horizon drop (needs
``ts + gap + lateness < wm``) and the tuple-after-purge race (needs
``lateness < oo``) both IMPOSSIBLE.  Every tuple folds into the same
canonical session (sessions.py derives ids from the earliest event
time) in every run, so final state, registry, and last-emit-per-pane
are pure functions of the workload seed.
"""
from __future__ import annotations

import os
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.streaming.engine import Engine, SourceOp
from repro.streaming.nexmark import NexmarkConfig, build_query
from repro.streaming.recovery import CheckpointCoordinator

KINDS = ("failure", "migrate", "load_shift", "hint_drop", "hint_delay",
         "corrupt")

# chaos workload geometry (the soundness condition above): gap 0.4 s,
# lateness = oo_bound = 0.2 s, update late policy (wired by build_query)
GAP = 0.4
OO_BOUND = 0.2
LATENESS = 0.2
RATE = 3000.0
N_SHARDS = 4
PARALLELISM = 2


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``at`` is engine (discrete-event) time;
    ``params`` is kind-specific and hashable:

      failure:    (mode,)             mode in {"warmed", "cold"}
      migrate:    (shard, dst_sub)
      load_shift: (scale, duration)   rate_scale while active
      hint_drop:  (drop_p, duration)  hint loss probability while active
      hint_delay: (extra, duration)   extra hint flush delay while active
      corrupt:    ()                  deterministic state corruption (the
                                      intentional violation the minimizer
                                      test reproduces)
    """
    kind: str
    at: float
    params: Tuple = ()

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind {self.kind!r}")


@dataclass(frozen=True)
class FaultSchedule:
    """A seeded fault schedule.  ``seed`` drives the workload generator
    (golden = same seed, zero events); ``chaos_seed`` drives the
    hint-channel drop draws.  Frozen + tuple-of-frozen => hashable and
    picklable, so failing schedules ship as artifacts."""
    seed: int
    events: Tuple[FaultEvent, ...] = ()
    chaos_seed: int = 0

    def with_events(self, events) -> "FaultSchedule":
        return FaultSchedule(self.seed, tuple(events), self.chaos_seed)

    def kinds(self) -> Tuple[str, ...]:
        return tuple(sorted({e.kind for e in self.events}))

    @staticmethod
    def random(seed: int, n_events: int = 4, t_lo: float = 0.4,
               t_hi: float = 1.6) -> "FaultSchedule":
        """A reproducible random schedule with >= 2 distinct fault kinds
        (never ``corrupt`` — that one is an intentional violation, only
        injected explicitly).  Windowed faults overlap point faults by
        construction: durations stretch past neighbouring event times.
        """
        rng = np.random.Generator(np.random.PCG64(seed))
        pool = ["failure", "migrate", "load_shift", "hint_drop",
                "hint_delay"]
        n = max(2, n_events)
        kinds = [pool[int(rng.integers(len(pool)))] for _ in range(n)]
        while len(set(kinds)) < 2:
            kinds[-1] = pool[int(rng.integers(len(pool)))]
        times = sorted(float(t)
                       for t in rng.uniform(t_lo, t_hi, size=n))
        events = []
        for kind, at in zip(kinds, times):
            if kind == "failure":
                mode = "warmed" if rng.random() < 0.7 else "cold"
                events.append(FaultEvent(kind, at, (mode,)))
            elif kind == "migrate":
                shard = int(rng.integers(N_SHARDS))
                dst = int(rng.integers(PARALLELISM))
                events.append(FaultEvent(kind, at, (shard, dst)))
            elif kind == "load_shift":
                scale = float(rng.choice([0.4, 2.0, 3.0]))
                dur = float(rng.uniform(0.3, 0.8))
                events.append(FaultEvent(kind, at, (scale, dur)))
            elif kind == "hint_drop":
                p = float(rng.uniform(0.3, 0.9))
                dur = float(rng.uniform(0.3, 0.8))
                events.append(FaultEvent(kind, at, (p, dur)))
            else:                          # hint_delay
                extra = float(rng.uniform(0.002, 0.02))
                dur = float(rng.uniform(0.3, 0.8))
                events.append(FaultEvent(kind, at, (extra, dur)))
        return FaultSchedule(seed, tuple(events), chaos_seed=seed * 31 + 7)


class ChannelChaos:
    """Per-channel fault injector (engine.Channel.chaos hook).  Draws
    come from a seeded generator in simulation-event order, so a given
    schedule produces the identical drop pattern every run."""

    def __init__(self, rng: np.random.Generator):
        self.rng = rng
        self.drop_p = 0.0
        self.extra = 0.0
        self.dropped = 0

    def drop(self, msg) -> bool:
        if self.drop_p > 0.0 and self.rng.random() < self.drop_p:
            self.dropped += 1
            return True
        return False

    def delay(self) -> float:
        return self.extra


@dataclass
class RunResult:
    """Observable state effects of one run, in oracle-comparable form."""
    final_state: Dict[Any, Any]
    registry: Dict[Tuple, Tuple]          # (base, wid) -> (start, end)
    last_emit: Dict[Tuple, Any]           # (base, wid) -> last count
    emit_counts: Dict[Tuple, int]         # (base, wid) -> times emitted
    absorbed: frozenset                   # (base, wid) merged away
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class OracleReport:
    ok: bool
    violations: List[str]
    deviations: Dict[str, int]

    def __str__(self):
        head = "OK" if self.ok else "VIOLATED"
        lines = [f"oracle {head}; deviations {self.deviations}"]
        lines += [f"  - {v}" for v in self.violations[:8]]
        return "\n".join(lines)


def build_chaos_engine(seed: int, mode: str = "prefetch") -> Engine:
    cfg = NexmarkConfig(rate=RATE, seed=seed, oo_bound=OO_BOUND,
                        watermark_interval=0.05)
    return build_query("q11", "tac", mode, cfg, cache_entries=512,
                       parallelism=PARALLELISM, source_parallelism=1,
                       io_workers=4, n_shards=N_SHARDS,
                       buffer_timeout=0.002, session_gap=GAP,
                       allowed_lateness=LATENESS, replayable=True)


def _install(eng: Engine, coord: CheckpointCoordinator,
             chaos: ChannelChaos, ev: FaultEvent) -> None:
    sim = eng.sim
    if ev.kind == "failure":
        (mode,) = ev.params

        def fire_failure():
            if coord.in_recovery:
                # overlapping failures are out of scope for the recovery
                # plane (recovery.py fails loud): retry shortly after —
                # deterministically, the retry delay is fixed
                sim.after(0.05, fire_failure)
                return
            coord.fail(mode=mode, down_time=0.05, replay_speedup=4.0)

        sim.at(ev.at, fire_failure)
    elif ev.kind == "migrate":
        shard, dst = ev.params
        sim.at(ev.at, eng.migrate_shard, "stateful",
               shard % N_SHARDS, dst % PARALLELISM)
    elif ev.kind == "load_shift":
        scale, dur = ev.params
        srcs = [op for op in eng.operators.values()
                if isinstance(op, SourceOp)]

        def set_scale(s):
            for src in srcs:
                src.rate_scale = s

        sim.at(ev.at, set_scale, float(scale))
        sim.at(ev.at + dur, set_scale, 1.0)
    elif ev.kind == "hint_drop":
        p, dur = ev.params
        sim.at(ev.at, setattr, chaos, "drop_p", float(p))
        sim.at(ev.at + dur, setattr, chaos, "drop_p", 0.0)
    elif ev.kind == "hint_delay":
        extra, dur = ev.params
        sim.at(ev.at, setattr, chaos, "extra", float(extra))
        sim.at(ev.at + dur, setattr, chaos, "extra", 0.0)
    elif ev.kind == "corrupt":
        op = eng.operators["stateful"]
        # deterministic intentional violation: a key no session query
        # would ever write lands in the backend through the normal write
        # path (so delta checkpoints carry it like real state)
        sim.at(ev.at, op.backends[0].write,
               ("__corrupt__", round(ev.at, 6)), 999_999, 64)


def run_schedule(schedule: FaultSchedule, t_cut: float = 2.0,
                 mode: str = "prefetch", observe: bool = False,
                 timeline_interval: float = 0.1) -> RunResult:
    """Run the chaos workload under ``schedule`` until the source's
    LOGICAL clock reaches ``t_cut``, quiesce, then flush all windows
    with a final watermark pair and collect the oracle observables.

    The generator is cut on logical time, so a load shift or recovery
    replay changes when records arrive but never which records exist;
    the final watermark pair (``FINAL`` fires every session, ``FINAL +
    1e-7`` runs the purge sweep once all fires have applied) makes the
    purge set a pure event-time function of the workload.

    With ``observe``, the temporal plane (DESIGN.md §16) runs during the
    LIVE phase — timeline + health detectors on ``timeline_interval`` —
    and freezes before the drain (where throughput legitimately
    collapses to zero and stall/load-shift alerts would be artifacts of
    the harness, not the run).  The alerts land in
    ``RunResult.metrics["alerts"]`` for the alert oracle
    (``alert_oracle``).
    """
    eng = build_chaos_engine(schedule.seed, mode=mode)
    sim = eng.sim
    src: SourceOp = eng.operators["source"]
    op = eng.operators["stateful"]
    sessla = eng.operators["sess_lookahead"]
    sink = eng.operators["sink"]

    inner_gen = src.gen
    src.gen = lambda lt: None if lt >= t_cut else inner_gen(lt)

    emits: List[Tuple[Any, Any]] = []
    orig_process = sink.process
    sink.process = lambda sub, tup: (emits.append((tup.key, tup.payload)),
                                     orig_process(sub, tup))[1]

    coord = CheckpointCoordinator(eng, interval=0.3)
    coord.start()
    chaos = ChannelChaos(
        np.random.Generator(np.random.PCG64(schedule.chaos_seed)))
    for ch in sessla.out_hint:
        ch.chaos = chaos
    for ev in schedule.events:
        _install(eng, coord, chaos, ev)

    if observe:
        eng.enable_timeline(interval=timeline_interval)
        # the observed window is the LIVE phase: past t_cut the logical
        # stream is exhausted by construction and throughput falls to
        # zero — an artifact of the cut, not a health signal, so the
        # plane freezes there (oracle-gated events sit well inside)
        sim.at(t_cut, eng.stop_timeline)

    for o in eng.operators.values():
        if isinstance(o, SourceOp):
            o.start()
    sim.after(eng.marker_interval, eng._inject_marker)

    # phase 1: run until the logical stream is exhausted AND any replay /
    # recovery in flight has settled
    t, step, deadline = 0.0, 0.25, 10.0 * t_cut + 30.0
    while True:
        t += step
        sim.run_until(t)
        log_end = [src.log_base[s] + len(src.log[s])
                   for s in range(src.parallelism)]
        done = (all(lt >= t_cut for lt in src.logical_t)
                and all(src.replay_pos[s] >= log_end[s]
                        for s in range(src.parallelism))
                and not coord.in_recovery)
        if done:
            break
        if t > deadline:
            raise RuntimeError(f"chaos run failed to quiesce by t={t}")
    # phase 2: drain in-flight data, then fire + purge deterministically.
    # The temporal plane freezes here: the drain's zero-throughput tail
    # is a harness artifact, not run health
    eng.stop_timeline()
    t += 0.5
    sim.run_until(t)
    final_wm = t_cut + GAP + 0.05
    for wm in (final_wm, final_wm + 1e-7):
        for s in range(src.parallelism):
            src.wm[s] = wm                # freeze _wm_tick below this
            src.emit_watermark(s, wm)
        last = -1
        while len(emits) != last:         # fires may cascade merge settles
            last = len(emits)
            t += 0.3
            sim.run_until(t)
    src.stopped = True

    # ----- collect observables
    merged: Dict[Any, Any] = {}
    for sub in range(op.parallelism):
        for e in op.caches[sub].flush_dirty():
            op.backends[sub].write(e.key, e.state, op.state_size)
        merged.update(op.backends[sub].data)
    # prefetches materialize default (None) pane state in the backend;
    # whether a hint's fetch beat its pane's purge is timing, not state —
    # normalize the Nones away so only real values face the oracle
    merged = {k: v for k, v in merged.items() if v is not None}

    registry: Dict[Tuple, Tuple] = {}
    for sub in range(op.parallelism):
        for base, lst in op.sess[sub].items():
            for s in lst:
                registry[(base, s["wid"])] = (round(s["start"], 9),
                                              round(s["end"], 9))
    absorbed = frozenset(k for sub in range(op.parallelism)
                         for k in op.absorbed[sub])

    last_emit: Dict[Tuple, Any] = {}
    emit_counts: Dict[Tuple, int] = {}
    for _key, payload in emits:
        if isinstance(payload, tuple) and len(payload) == 4 \
                and payload[0] == "session":
            _, base, wid, count = payload
            last_emit[(base, wid)] = count
            emit_counts[(base, wid)] = emit_counts.get((base, wid), 0) + 1

    metrics = {
        "fires": op.fires, "fires_lost": op.fires_lost,
        "sessions_created": op.sessions_created,
        "sessions_merged": op.sessions_merged,
        "sessions_reopened": op.sessions_reopened,
        "late_dropped": op.late_dropped,
        "hints_dropped_by_chaos": chaos.dropped,
        "failures": coord.failures, "emits": len(emits),
        "rehints": sessla.rehints,
    }
    if observe and eng.health is not None:
        metrics["alerts"] = [a.as_dict() for a in eng.health.alerts]
        metrics["health"] = eng.health.block()
        metrics["timeline"] = eng.timeline.block()
    return RunResult(merged, registry, last_emit, emit_counts, absorbed,
                     metrics)


def compare(golden: RunResult, perturbed: RunResult) -> OracleReport:
    """The exactly-once state-effect oracle (module docstring).  Hard
    violations: final keyed state, final session registry, and the last
    emit of every non-merged pane must match the golden run.  Recorded
    deviations (at-least-once emission + fire/merge races): duplicate
    emissions and transient fires of panes later merged away."""
    v: List[str] = []
    if golden.final_state != perturbed.final_state:
        only_g = {k: golden.final_state[k]
                  for k in set(golden.final_state) - set(perturbed.final_state)}
        only_p = {k: perturbed.final_state[k]
                  for k in set(perturbed.final_state) - set(golden.final_state)}
        diff = {k: (golden.final_state[k], perturbed.final_state[k])
                for k in set(golden.final_state) & set(perturbed.final_state)
                if golden.final_state[k] != perturbed.final_state[k]}
        v.append(f"final keyed state diverged: only_golden={only_g!r} "
                 f"only_perturbed={only_p!r} value_diff={diff!r}")
    if golden.registry != perturbed.registry:
        d = set(golden.registry.items()) ^ set(perturbed.registry.items())
        v.append(f"session registry diverged: {sorted(d)[:6]!r}")
    merged_away = golden.absorbed | perturbed.absorbed
    hard_g = set(golden.last_emit) - merged_away
    hard_p = set(perturbed.last_emit) - merged_away
    if hard_g != hard_p:
        v.append(f"fired-pane set diverged: only_golden="
                 f"{sorted(hard_g - hard_p)[:6]!r} only_perturbed="
                 f"{sorted(hard_p - hard_g)[:6]!r}")
    for pane in hard_g & hard_p:
        if golden.last_emit[pane] != perturbed.last_emit[pane]:
            v.append(f"pane {pane!r} final emit diverged: "
                     f"golden={golden.last_emit[pane]!r} perturbed="
                     f"{perturbed.last_emit[pane]!r}")
    deviations = {
        "duplicate_emits": sum(c - 1 for c in
                               perturbed.emit_counts.values() if c > 1),
        "transient_pane_emits": sum(
            perturbed.emit_counts.get(p, 0)
            for p in set(perturbed.last_emit) & merged_away),
        "hints_dropped": perturbed.metrics.get("hints_dropped_by_chaos", 0),
    }
    return OracleReport(not v, v, deviations)


# ------------------------------------------------------- alert oracle (§16)
# fault kind -> the alert kind its detection must raise (health.py's
# ORACLE_KINDS, re-exported here so the harness is self-contained)
ALERT_FOR = {"failure": "recovery", "migrate": "migration",
             "load_shift": "load_shift"}


def effective_events(schedule: FaultSchedule
                     ) -> List[Tuple[FaultEvent, str]]:
    """The oracle-gated events a run will actually EXPRESS, with the
    alert kind each must raise.  Replays the shard-owner table in event
    order (initial owner = shard % PARALLELISM) because a migrate whose
    destination already owns the shard is a no-op at the engine
    (``StatefulOp.migrate_shard`` returns early) — ground truth must not
    demand an alert for a fault that physically cannot happen.  Same for
    a load shift at scale 1.0.  Assumes migrations execute in schedule
    order (checkpoint-deferral preserves relative order for the
    well-separated schedules the oracle benchmarks use)."""
    owner = [s % PARALLELISM for s in range(N_SHARDS)]
    out: List[Tuple[FaultEvent, str]] = []
    for ev in sorted(schedule.events, key=lambda e: e.at):
        if ev.kind == "failure":
            out.append((ev, ALERT_FOR[ev.kind]))
        elif ev.kind == "migrate":
            shard, dst = ev.params
            shard, dst = shard % N_SHARDS, dst % PARALLELISM
            if owner[shard] != dst:
                owner[shard] = dst
                out.append((ev, ALERT_FOR[ev.kind]))
        elif ev.kind == "load_shift":
            scale, dur = ev.params
            if scale != 1.0:
                out.append((ev, ALERT_FOR[ev.kind]))
    return out


def alert_oracle(schedule: FaultSchedule, perturbed: RunResult,
                 golden: RunResult, delay: float = 0.8) -> Dict[str, Any]:
    """Score the temporal plane against the seeded schedule as ground
    truth (both runs must have been produced with ``observe=True``):

      * recall — every effective failure/migrate/load_shift event must
        raise an alert of its mapped kind within ``delay`` logical
        seconds of the event's onset (windowed faults get their duration
        added: the shift exists for that long);
      * golden soundness — the unperturbed run of the same seed must
        raise ZERO stall alerts (and is reported on all kinds).

    Both are gated in BENCH_obs.json's ``alerts`` block."""
    galerts = golden.metrics.get("alerts", [])
    palerts = perturbed.metrics.get("alerts", [])
    per_event: List[Dict[str, Any]] = []
    matched = 0
    events = effective_events(schedule)
    for ev, want in events:
        horizon = ev.at + delay
        if ev.kind == "load_shift":
            horizon += ev.params[1]
        hit = [a for a in palerts
               if a["kind"] == want and ev.at <= a["t"] <= horizon]
        if hit:
            matched += 1
        per_event.append({"kind": ev.kind, "at": ev.at, "want": want,
                          "matched": bool(hit),
                          "alert_t": hit[0]["t"] if hit else None})
    by_kind: Dict[str, Dict[str, int]] = {}
    for e in per_event:
        b = by_kind.setdefault(e["kind"], {"injected": 0, "matched": 0})
        b["injected"] += 1
        b["matched"] += int(e["matched"])
    return {
        "injected": len(events),
        "matched": matched,
        "recall": matched / len(events) if events else 1.0,
        "per_kind": by_kind,
        "per_event": per_event,
        "golden_alerts": len(galerts),
        "golden_false_stall": sum(1 for a in galerts
                                  if a["kind"] == "stall"),
        "golden_by_kind": _count_kinds(galerts),
        "perturbed_by_kind": _count_kinds(palerts),
        "delay": delay,
    }


def _count_kinds(alerts: List[dict]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for a in alerts:
        out[a["kind"]] = out.get(a["kind"], 0) + 1
    return out


def check_schedule(schedule: FaultSchedule, t_cut: float = 2.0,
                   golden: Optional[RunResult] = None,
                   mode: str = "prefetch"):
    """Run golden (zero events, same seed) + perturbed and compare.
    Returns (report, golden, perturbed); pass ``golden`` to amortize it
    across schedules sharing a workload seed."""
    if golden is None:
        golden = run_schedule(schedule.with_events(()), t_cut, mode=mode)
    perturbed = run_schedule(schedule, t_cut, mode=mode)
    return compare(golden, perturbed), golden, perturbed


def minimize(schedule: FaultSchedule, t_cut: float = 2.0,
             golden: Optional[RunResult] = None) -> FaultSchedule:
    """Greedy schedule shrinking: repeatedly drop single events while
    the remainder still violates the oracle.  Deterministic (runs are
    replays), so the result is a stable minimal reproducer.  If the full
    schedule does not violate, it is returned unchanged."""
    if golden is None:
        golden = run_schedule(schedule.with_events(()), t_cut)

    def violates(events) -> bool:
        rep = compare(golden,
                      run_schedule(schedule.with_events(events), t_cut))
        return not rep.ok

    events = list(schedule.events)
    if not violates(events):
        return schedule
    shrunk = True
    while shrunk and len(events) > 1:
        shrunk = False
        for i in range(len(events)):
            cand = events[:i] + events[i + 1:]
            if violates(cand):
                events = cand
                shrunk = True
                break
    return schedule.with_events(events)


def save_artifact(schedule: FaultSchedule, report: OracleReport,
                  out_dir: str = "chaos_artifacts") -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"repro_seed{schedule.seed}.pkl")
    with open(path, "wb") as f:
        pickle.dump({"schedule": schedule,
                     "violations": report.violations,
                     "deviations": report.deviations}, f)
    return path
