"""Synthetic query for the dynamic-lookahead experiment (paper Fig 10).

source -> udf0 -> udf1 -> udf2 -> static join (controllable access latency).
All three UDFs are candidate lookaheads.  At ``t_mismatch`` udf1 starts
remapping the state-access key (hints from udf0 become wrong -> mismatch
switch); at ``t_latency_drop`` the backend gets faster (timing switch to the
latest candidate with sufficient slack).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.streaming.backend import BackendModel, StateBackend
from repro.streaming.engine import (Engine, MapOp, SinkOp, SourceOp,
                                    StatefulOp)
from repro.streaming.events import Tuple_

SLOW = BackendModel("remote-slow", 3e-3, 1.0e9, parallelism=32)
FAST = BackendModel("remote-fast", 0.4e-3, 1.2e9, parallelism=32)


@dataclass
class SyntheticConfig:
    rate: float = 15_000.0
    n_keys: int = 20_000
    t_mismatch: float = 10.0
    t_latency_drop: float = 20.0
    seed: int = 3
    # bounded out-of-orderness (DESIGN.md §10): event ts trails arrival by
    # U(0, oo_bound); with watermark_interval > 0 the source also emits
    # Watermark(max ts - oo_bound) so downstream event-time operators
    # (windows.py) can run on the synthetic plan
    oo_bound: float = 0.0
    watermark_interval: float = 0.0


def build_synthetic(cfg: SyntheticConfig, policy: str = "tac",
                    mode: str = "prefetch", cache_entries: int = 4096,
                    parallelism: int = 2, gamma: float = 0.3e-3,
                    lookaheads=("udf0", "udf1", "udf2")) -> Engine:
    eng = Engine()
    # counter-based generator: the workload replays bit-exactly from its
    # seed (the chaos oracle's determinism contract, DESIGN.md §15)
    rng = np.random.Generator(np.random.PCG64(cfg.seed))

    def gen(now: float):
        k = int(rng.integers(cfg.n_keys))
        if cfg.oo_bound > 0:
            return (k, {"k": k}, 150,
                    max(0.0, now - cfg.oo_bound * rng.random()))
        return (k, {"k": k}, 150)

    def key_of(tup: Tuple_):
        return tup.key

    remap = {"active": False}

    def udf1_fn(tup: Tuple_):
        if remap["active"]:
            tup.key = tup.key + 10_000_000      # new key space downstream
        return tup

    def apply_fn(tup, state):
        return state, [Tuple_(tup.ts, tup.key, state, 170, tup.ingest_t)]

    src = eng.add(SourceOp(eng, "source", 1, cfg.rate, gen,
                           watermark_interval=cfg.watermark_interval,
                           oo_bound=cfg.oo_bound))
    udf0 = eng.add(MapOp(eng, "udf0", parallelism, fn=None,
                         service_time=12e-6, key_of=key_of))
    udf1 = eng.add(MapOp(eng, "udf1", parallelism, fn=udf1_fn,
                         service_time=12e-6, key_of=key_of))
    udf2 = eng.add(MapOp(eng, "udf2", parallelism, fn=None,
                         service_time=12e-6, key_of=key_of))
    join = eng.add(StatefulOp(
        eng, "stateful", parallelism, apply_fn, SLOW,
        cache_entries * 150, policy=policy, mode=mode, io_workers=24,
        state_size=150, read_only=True,
        default_state=lambda k: {"row": k}, gamma=gamma))
    sink = eng.add(SinkOp(eng, "sink", 1))
    eng.connect(src, udf0)
    eng.connect(udf0, udf1)
    eng.connect(udf1, udf2)
    eng.connect(udf2, join)
    eng.connect(join, sink, partition=lambda k, n: 0)
    if mode == "prefetch":
        by_name = {"udf0": udf0, "udf1": udf1, "udf2": udf2}
        eng.register_prefetching(join, [by_name[n] for n in lookaheads])

    def start_mismatch():
        remap["active"] = True

    def drop_latency():
        for be in join.backends:
            be.model = FAST

    eng.sim.at(cfg.t_mismatch, start_mismatch)
    eng.sim.at(cfg.t_latency_drop, drop_latency)
    return eng
