"""Session windows: data-driven gaps, pane MERGING, moving-deadline hints
(DESIGN.md §15).

Tumbling/sliding windows (windows.py) know their fire time the moment a
tuple is assigned; a SESSION window does not — every tuple extends its
session's end to ``ts + gap``, and a tuple landing between two sessions
MERGES them into one.  That makes sessions the honest adversary for the
paper's deadline-aware TAC: the fire deadline a hint promised keeps
moving, so the lookahead must RE-HINT on every extension/merge and the
cache must ``renew`` the pane's timestamp rather than trust the first
deadline it saw (core/tac.py).

Three pieces:

  * ``SessionWindowAssigner`` — per-key dynamic session registry logic.
    ``fold`` is the one canonical merge rule, shared verbatim by the
    stateful operator and the lookahead so both mirror the same session
    structure (lockstep hints).  Session ids are CANONICAL: the surviving
    ``wid`` is always derived from the earliest event timestamp in the
    session, so the final registry is independent of per-key arrival
    order — the property the chaos oracle (streaming/chaos.py) and the
    Hypothesis merge tests (tests/test_sessions.py) rely on.
  * ``SessionWindowedOp`` — pane state keyed ``WindowKey(key, wid)`` on
    the inherited keyed machinery.  A merge runs as a two-step protocol
    THROUGH that machinery (so pane reads park/prefetch exactly like any
    keyed access): the absorbed pane receives a ``_MergeDrain`` message
    that takes its accumulator and purges it, then self-delivers a
    ``_MergeAbsorb`` carrying the state into the surviving pane, where
    ``merge_fn`` combines the two accumulators.  A bridging tuple
    therefore never loses either side's state, even when one side is
    parked on a backend fetch mid-merge.  Sessions with absorbs still in
    flight (``pending > 0``) never fire; the settle re-arms the fire.
  * ``SessionLookaheadOp`` — mirrors the registry per key and emits
    deadline hints carrying the session's CURRENT end; on extension or
    merge it re-hints unconditionally (bypassing admission, like the
    fire burst) so a resident pane's TAC deadline is renewed in place.

Late tuples follow the windows.py policies: beyond the lateness horizon
they drop; inside it, ``update`` re-opens the fired session (Aion-style
late-side update) and the re-fired emit carries the refreshed
accumulator, while ``drop`` discards them.
"""
from __future__ import annotations

import heapq
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.streaming.engine import HINT_COST, StatefulOp, _IOReq
from repro.streaming.events import Hint, Tuple_, WindowKey
from repro.streaming.windows import (FIRE, WindowedLookaheadOp,
                                     WindowedStatefulOp)

# session ids quantize the creating event timestamp to microseconds: two
# distinct sessions of one key are separated by > gap >> 1µs, so ids
# never collide, and ``start_of`` inverts the id for horizon checks
_WID_SCALE = 1e6


class _MergeDrain:
    """Self-addressed message to an ABSORBED pane: take its accumulator,
    purge the pane, and forward the state to the surviving pane."""
    __slots__ = ("surv",)

    def __init__(self, surv: int):
        self.surv = surv

    def __repr__(self):
        return f"<DRAIN->{self.surv}>"


class _MergeAbsorb:
    """Self-addressed message to a SURVIVING pane: combine the absorbed
    pane's accumulator into its own via ``merge_fn``."""
    __slots__ = ("state",)

    def __init__(self, state: Any):
        self.state = state

    def __repr__(self):
        return f"<ABSORB {self.state!r}>"


def _new_session(ts: float, wid: int, gap: float) -> dict:
    return {"start": ts, "end": ts + gap, "wid": wid, "fired": False,
            "pending": 0, "fire_due": False}


class SessionWindowAssigner:
    """Data-driven session membership with a fixed inactivity ``gap``.

    A tuple at event time ``ts`` spans ``[ts, ts + gap)``; it joins every
    session that interval overlaps, merging them when it bridges more
    than one.  ``wid_of(ts)`` derives the session id from the earliest
    event timestamp, and ``fold`` keeps that canonical: when a tuple
    extends a session's start backwards, the EARLIER timestamp's id wins
    and the old pane is absorbed — so the final id of any session equals
    ``wid_of(min ts in the session)`` regardless of arrival order.
    """

    def __init__(self, gap: float):
        if gap <= 0:
            raise ValueError(f"need gap > 0, got {gap}")
        self.gap = gap

    def wid_of(self, ts: float) -> int:
        return int(math.floor(ts * _WID_SCALE + 0.5))

    def start_of(self, wid: int) -> float:
        return wid / _WID_SCALE

    def end(self, wid: int) -> float:
        """Minimal possible fire deadline of a session created at this
        id's timestamp (extensions only move the true end later).  Kept
        for WindowedStatefulOp API compatibility; the session registry
        holds the live end."""
        return self.start_of(wid) + self.gap

    def overlapping(self, sessions: List[dict], ts: float) -> List[dict]:
        hi = ts + self.gap
        return [s for s in sessions if ts < s["end"] and hi > s["start"]]

    def fold(self, sessions: List[dict], ts: float):
        """Fold one tuple into a key's session list (mutating it).

        Returns ``(sess, absorbed, extended, created)``: the surviving
        session, the sessions merged into it (removed from the list),
        whether the surviving end moved, and whether the survivor is a
        brand-new session dict.
        """
        ov = self.overlapping(sessions, ts)
        if not ov:
            s = _new_session(ts, self.wid_of(ts), self.gap)
            sessions.append(s)
            return s, [], True, True
        ov.sort(key=lambda s: (s["start"], s["wid"]))
        if ts < ov[0]["start"]:
            # the tuple PREDATES every overlapping session: the canonical
            # id belongs to it — a fresh session absorbs the rest
            surv = _new_session(ts, self.wid_of(ts), self.gap)
            sessions.append(surv)
            absorbed, created = ov, True
        else:
            surv, absorbed, created = ov[0], ov[1:], False
        old_end = surv["end"]
        surv["start"] = min(surv["start"], ts)
        surv["end"] = max([surv["end"], ts + self.gap]
                          + [a["end"] for a in absorbed])
        for a in absorbed:
            sessions.remove(a)
        return surv, absorbed, created or surv["end"] > old_end, created


class SessionWindowedOp(WindowedStatefulOp):
    """Keyed session-window aggregation with pane merging (DESIGN.md §15).

    ``merge_fn(a, b)`` combines two pane accumulators (either may be
    ``None``); it must be commutative/associative so the merged result is
    independent of merge order — the session-structure canonicalization
    (``SessionWindowAssigner.fold``) guarantees the same for ids.

    Fires are driven by a lazy per-subtask heap of ``(end, base, wid)``
    candidates pushed on every extension; stale entries (extended,
    absorbed, or already fired since) are skipped on pop, and a session
    with merge absorbs still in flight defers its fire until they settle.
    """

    def __init__(self, engine, name, parallelism,
                 assigner: SessionWindowAssigner,
                 agg_fn: Callable[[Tuple_, Any], Any],
                 emit_fn: Callable[[Any, int, float, Any], Any],
                 backend_model, cache_capacity: int,
                 merge_fn: Optional[Callable[[Any, Any], Any]] = None,
                 **kw):
        super().__init__(engine, name, parallelism, assigner, agg_fn,
                         emit_fn, backend_model, cache_capacity, **kw)
        if self.fused_spec is not None:
            raise ValueError("session windows have no fused plane: merge "
                             "re-keys panes mid-stream (DESIGN.md §15)")
        self.merge_fn = merge_fn or (lambda a, b: b if a is None else a)
        # base -> [session dict], per subtask (durable: rides snapshots)
        self.sess: List[Dict[Any, List[dict]]] = \
            [dict() for _ in range(parallelism)]
        # (base, absorbed wid) -> {"surv": wid, "drained": bool} — the
        # redirect map for in-flight pane traffic addressed to a merged-
        # away session (chain-resolved; entries are a few bytes each and
        # kept for the run — see _resolve)
        self.absorbed: List[Dict[Tuple[Any, int], dict]] = \
            [dict() for _ in range(parallelism)]
        self.fire_heap: List[List] = [[] for _ in range(parallelism)]
        self.purge_heap: List[List] = [[] for _ in range(parallelism)]
        self.sessions_created = 0
        self.sessions_merged = 0
        self.sessions_reopened = 0
        self.fires_superseded = 0
        self.fires_absorbed = 0
        self.merge_drains = 0
        self.merge_absorbs = 0

    # ----------------------------------------------------------- registry
    def _find(self, sub: int, base: Any, wid: int) -> Optional[dict]:
        for s in self.sess[sub].get(base, ()):
            if s["wid"] == wid:
                return s
        return None

    def _resolve(self, sub: int, wk: WindowKey) -> WindowKey:
        """Chain-resolve a pane key through the absorbed-redirect map so
        stale in-flight traffic (parked resumes, migration/recovery
        replays) lands on the surviving pane."""
        amap = self.absorbed[sub]
        wid = wk.wid
        seen = 0
        while (wk.base, wid) in amap:
            wid = amap[(wk.base, wid)]["surv"]
            seen += 1
            if seen > 64:                 # defensive: merges form a DAG
                break
        return wk if wid == wk.wid else WindowKey(wk.base, wid)

    def _arm_fire(self, sub: int, sess: dict, base: Any) -> None:
        heapq.heappush(self.fire_heap[sub],
                       (sess["end"], base, sess["wid"]))

    # ------------------------------------------------------------ data path
    def _on_data(self, sub: int, tup: Tuple_) -> float:
        if isinstance(tup.key, WindowKey):
            # pane-addressed traffic: merge protocol messages go straight
            # through; data/absorbs redirect if their pane was merged away
            if not isinstance(tup.payload, _MergeDrain) \
                    and tup.payload is not FIRE:
                wk = self._resolve(sub, tup.key)
                if wk is not tup.key:
                    tup = Tuple_(tup.ts, wk, tup.payload, tup.size,
                                 tup.ingest_t, trace=tup.trace)
            return StatefulOp._on_data(self, sub, tup)
        wm = self.wm[sub]
        base, ts = tup.key, tup.ts
        gap = self.assigner.gap
        sessions = self.sess[sub].setdefault(base, [])
        ov = self.assigner.overlapping(sessions, ts)
        if not ov and ts + gap + self.allowed_lateness < wm:
            self.late_dropped += 1        # beyond any horizon: unjoinable
            self._trace_absorbed(tup.trace)
            return 5e-7
        if self.late_policy == "drop" and any(s["fired"] for s in ov):
            self.late_dropped += 1        # would touch a fired session
            self._trace_absorbed(tup.trace)
            return 5e-7
        sess, absorbed, extended, created = self.assigner.fold(sessions, ts)
        if created:
            self.sessions_created += 1
        reopen = sess["fired"] or any(a["fired"] for a in absorbed)
        if reopen:
            # Aion-style late-side re-open: the refreshed session
            # re-fires at its (possibly extended) end
            sess["fired"] = False
            self.sessions_reopened += 1
        svc = 0.0
        for a in absorbed:
            self.sessions_merged += 1
            self.merge_drains += 1
            sess["pending"] += 1
            self.absorbed[sub][(base, a["wid"])] = {"surv": sess["wid"],
                                                    "drained": False}
            # two-step merge through the keyed machinery: drain the
            # absorbed pane (its read parks/prefetches like any access)
            self.deliver_batch(sub, [Tuple_(
                ts, WindowKey(base, a["wid"]), _MergeDrain(sess["wid"]),
                32, tup.ingest_t)])
        if extended or reopen:
            self._arm_fire(sub, sess, base)
        svc += StatefulOp._on_data(self, sub, Tuple_(
            ts, WindowKey(base, sess["wid"]), tup.payload, tup.size,
            tup.ingest_t, trace=tup.trace))
        return svc

    def _apply(self, sub: int, tup: Tuple_, state: Any) -> float:
        wk: WindowKey = tup.key
        base, wid = wk.base, wk.wid
        if isinstance(tup.payload, _MergeDrain):
            # absorbed pane: lift its accumulator, purge it, forward
            entry = self.absorbed[sub].get((base, wid))
            if entry is not None:
                entry["drained"] = True
            self.caches[sub].drop(wk)
            self.backends[sub].delete(wk)
            self.panes_purged += 1
            self.deliver_batch(sub, [Tuple_(
                tup.ts, WindowKey(base, tup.payload.surv),
                _MergeAbsorb(state), 32, tup.ingest_t)])
            return self.service_time
        if isinstance(tup.payload, _MergeAbsorb):
            self.merge_absorbs += 1
            acc = self.merge_fn(state, tup.payload.state)
            if acc is not state:
                self.caches[sub].write(wk, acc, tup.ts,
                                       size=self.state_size)
                self._io_kick(sub)
            sess = self._find(sub, base, wid)
            if sess is not None:
                sess["pending"] = max(0, sess["pending"] - 1)
                if sess["pending"] == 0 and not sess["fired"] \
                        and (sess["fire_due"] or sess["end"] <= self.wm[sub]):
                    # the fire this merge was holding back (the final
                    # flush watermark may already be behind us)
                    sess["fire_due"] = False
                    sess["fired"] = True
                    self.deliver_batch(sub, [Tuple_(
                        sess["end"], wk, FIRE, 32, self.sim.t)])
            return self.service_time
        if tup.payload is FIRE:
            sess = self._find(sub, base, wid)
            if sess is None:
                # merged away (or purged) after this FIRE was queued: the
                # surviving session carries the state and its own fire
                self.fires_absorbed += 1
                self._trace_absorbed(tup.trace)
                return self.service_time
            if sess["end"] > tup.ts or not sess["fired"]:
                # extended or re-opened since: a fresher heap entry fires
                self.fires_superseded += 1
                self._trace_absorbed(tup.trace)
                return self.service_time
            payload = self.emit_fn(base, wid, sess["end"], state)
            self.fires += 1
            if self.engine.record_events:
                self.engine.log_event("fire", op=self.name, wid=wid)
            if payload is not None:
                self.outputs += 1
                self.emit(sub, Tuple_(sess["end"], base, payload,
                                      self.out_size, tup.ingest_t,
                                      trace=tup.trace))
            if self.allowed_lateness == 0:
                self._purge_session(sub, base, sess)
            else:
                heapq.heappush(self.purge_heap[sub],
                               (sess["end"] + self.allowed_lateness,
                                base, wid))
            return self.service_time
        # plain pane data (possibly a redirected straggler)
        sess = self._find(sub, base, wid)
        if sess is None:
            wk2 = self._resolve(sub, wk)
            if wk2 is not wk:
                # the pane was merged away while this tuple sat queued or
                # parked (a fold removes the session synchronously): its
                # contribution belongs to the surviving pane — re-deliver
                # there instead of dropping it, or the count would depend
                # on I/O timing (the chaos oracle's nightmare)
                self.deliver_batch(sub, [Tuple_(
                    tup.ts, wk2, tup.payload, tup.size, tup.ingest_t,
                    trace=tup.trace)])
                return self.service_time
            # unregistered and not redirectable: the pane purged —
            # writing would resurrect dead state
            self.late_dropped += 1
            self._trace_absorbed(tup.trace)
            return self.service_time
        acc = self.agg_fn(tup, state)
        if acc is not state:
            self.caches[sub].write(wk, acc, tup.ts, size=self.state_size)
            self._io_kick(sub)
        self._trace_absorbed(tup.trace)   # folded into the pane
        return self.service_time

    # ---------------------------------------------------------------- firing
    def on_watermark(self, sub: int, wm: float) -> None:
        set_clock = getattr(self.caches[sub], "set_clock", None)
        if set_clock is not None:
            set_clock(wm)
        fire_batch = []
        just_fired = set()
        now = self.sim.t
        heap = self.fire_heap[sub]
        while heap and heap[0][0] <= wm:
            end, base, wid = heapq.heappop(heap)
            sess = self._find(sub, base, wid)
            if sess is None or sess["fired"] or sess["end"] != end:
                continue                  # stale candidate
            if sess["pending"]:
                sess["fire_due"] = True   # absorbs in flight: settle fires
                continue
            sess["fired"] = True
            just_fired.add((base, wid))
            fire_batch.append(Tuple_(end, WindowKey(base, wid), FIRE, 32,
                                     now))
        if fire_batch:
            self.deliver_batch(sub, fire_batch)
        pheap = self.purge_heap[sub]
        requeue = []
        while pheap and pheap[0][0] <= wm:
            due, base, wid = heapq.heappop(pheap)
            if (base, wid) in just_fired:
                # this pane's (re)fire was scheduled by THIS advance and
                # hasn't applied yet: purging now would drop the emit —
                # hold the entry for the next advance (windows.py keeps
                # its horizon purge one advance behind for the same race)
                requeue.append((due, base, wid))
                continue
            sess = self._find(sub, base, wid)
            if sess is not None and sess["fired"] \
                    and sess["end"] + self.allowed_lateness <= wm:
                self._purge_session(sub, base, sess)
        for item in requeue:
            heapq.heappush(pheap, item)

    def _purge_session(self, sub: int, base: Any, sess: dict) -> None:
        wk = WindowKey(base, sess["wid"])
        self.caches[sub].drop(wk)
        self.backends[sub].delete(wk)
        self.panes_purged += 1
        lst = self.sess[sub].get(base)
        if lst is not None:
            try:
                lst.remove(sess)
            except ValueError:
                pass
            if not lst:
                del self.sess[sub][base]

    # ----------------------------------------------------- purge/I-O races
    def _completion_dead(self, sub: int, req: _IOReq) -> bool:
        wk = req.key
        if not isinstance(wk, WindowKey):
            return False
        entry = self.absorbed[sub].get((wk.base, wk.wid))
        if entry is not None:
            # absorbed pane: completions stay LIVE until the drain took
            # its state (the drain may be parked on this very fetch);
            # after that the pane is purged and completions are dead
            return entry["drained"]
        if self._find(sub, wk.base, wk.wid) is not None:
            return False                  # registered and live
        # unregistered: a hint legitimately runs ahead of the first data
        # tuple, so only count the pane dead once even the EARLIEST
        # possible fire deadline of its creating timestamp is past the
        # lateness horizon
        return self.assigner.start_of(wk.wid) + self.assigner.gap \
            + self.allowed_lateness < self.wm[sub]

    # ------------------------------------------------------------- migration
    def migrate_shard(self, shard: int, dst_sub: int) -> None:
        plane = self.shards
        src = plane.owner[shard] if plane is not None else None
        super().migrate_shard(shard, dst_sub)
        if plane is None or src is None or src == dst_sub:
            return
        moving = [b for b in self.sess[src]
                  if plane.shard_of(b) == shard]
        for base in moving:
            sessions = self.sess[src].pop(base)
            self.sess[dst_sub].setdefault(base, []).extend(sessions)
            for s in sessions:
                # re-arm firing/purging at the new owner (the old owner's
                # heap entries go stale and skip on pop)
                if s["fired"]:
                    if self.allowed_lateness > 0:
                        heapq.heappush(
                            self.purge_heap[dst_sub],
                            (s["end"] + self.allowed_lateness, base,
                             s["wid"]))
                else:
                    heapq.heappush(self.fire_heap[dst_sub],
                                   (s["end"], base, s["wid"]))
        amap = self.absorbed[src]
        for k in [k for k in amap if plane.shard_of(k[0]) == shard]:
            self.absorbed[dst_sub][k] = amap.pop(k)

    # ---------------------------------------------------- snapshot / restore
    def snapshot_extra(self, sub: int) -> Dict[str, Any]:
        import copy
        out = super().snapshot_extra(sub) or {}
        out["sessions"] = copy.deepcopy(self.sess[sub])
        out["absorbed"] = copy.deepcopy(self.absorbed[sub])
        return out

    def restore_extra(self, sub: int, extra: Optional[dict]) -> None:
        super().restore_extra(sub, extra)
        if not extra or "sessions" not in extra:
            return
        self.sess[sub] = extra["sessions"]
        self.absorbed[sub] = extra.get("absorbed", {})
        # heaps are derived state: rebuild from the restored registry.
        # ``pending`` counts survive as snapshotted: each in-flight
        # drain/absorb rides the inflight capture exactly once (an
        # applied drain leaves the queue before its absorb enters), so
        # re-delivery decrements them back to zero.
        self.fire_heap[sub] = []
        self.purge_heap[sub] = []
        for base, sessions in self.sess[sub].items():
            for s in sessions:
                if s["fired"]:
                    if self.allowed_lateness > 0:
                        heapq.heappush(
                            self.purge_heap[sub],
                            (s["end"] + self.allowed_lateness, base,
                             s["wid"]))
                else:
                    heapq.heappush(self.fire_heap[sub],
                                   (s["end"], base, s["wid"]))

    def _snapshot_inflight(self, sub: int) -> List[Any]:
        out = StatefulOp._snapshot_inflight(self, sub)
        out.extend(t for t in self.queues[sub]
                   if isinstance(t, Tuple_)
                   and (t.payload is FIRE
                        or isinstance(t.payload, (_MergeDrain,
                                                  _MergeAbsorb))))
        return out

    def reset_volatile(self) -> None:
        super().reset_volatile()
        p = self.parallelism
        self.sess = [dict() for _ in range(p)]
        self.absorbed = [dict() for _ in range(p)]
        self.fire_heap = [[] for _ in range(p)]
        self.purge_heap = [[] for _ in range(p)]

    # --------------------------------------------------------------- metrics
    def extra_metrics(self) -> Dict[str, Any]:
        out = super().extra_metrics()
        out.update({
            "sessions_created": self.sessions_created,
            "sessions_merged": self.sessions_merged,
            "sessions_reopened": self.sessions_reopened,
            "fires_superseded": self.fires_superseded,
            "fires_absorbed": self.fires_absorbed,
            "merge_drains": self.merge_drains,
            "merge_absorbs": self.merge_absorbs,
            "live_sessions": sum(len(lst) for sub in self.sess
                                 for lst in sub.values()),
        })
        return out


class SessionLookaheadOp(WindowedLookaheadOp):
    """Session-window Hint Extractor with MOVING deadlines (DESIGN.md
    §15).

    Mirrors the downstream session registry per key via the SAME
    ``SessionWindowAssigner.fold`` (the edge into this operator and the
    edge out of it both partition by the session key, so both sides see
    each key's tuples in one FIFO order — lockstep).  Per tuple it emits
    a deadline hint for the surviving pane; when the tuple EXTENDS or
    MERGES the session, the hint bypasses admission/dedup entirely
    (``rehints``) so ``PrefetchingManager.on_hint`` renews the resident
    pane's TAC timestamp to the new deadline — the moving-deadline path.
    Near-fire sessions burst exactly like fixed windows.
    """

    def __init__(self, engine, name, parallelism,
                 assigner: SessionWindowAssigner, key_of: Callable,
                 fn=None, hint_ts_mode: str = "deadline",
                 burst_ahead: float = 0.0, allowed_lateness: float = 0.0,
                 service_time: float = 10e-6,
                 cms_conf: Optional[dict] = None,
                 filter_conf: Optional[dict] = None):
        super().__init__(engine, name, parallelism, assigner, key_of,
                         fn=fn, hint_ts_mode=hint_ts_mode,
                         burst_ahead=burst_ahead,
                         allowed_lateness=allowed_lateness,
                         service_time=service_time, cms_conf=cms_conf,
                         filter_conf=filter_conf)
        self.sess: List[Dict[Any, List[dict]]] = \
            [dict() for _ in range(parallelism)]
        self.rehints = 0

    def _emit_hints_for(self, sub: int, o: Tuple_) -> float:
        base = self.key_of(o)
        if base is None:
            return 0.0
        ts = o.ts
        wm = self.wm[sub]
        gap = self.assigner.gap
        if ts + gap + self.allowed_lateness < wm \
                and not self.assigner.overlapping(
                    self.sess[sub].get(base, ()), ts):
            return 0.0                    # dropped downstream anyway
        sessions = self.sess[sub].setdefault(base, [])
        sess, absorbed, extended, created = self.assigner.fold(sessions, ts)
        if extended and not created:
            sess["burst"] = False         # deadline moved: burst re-arms
        wk = WindowKey(base, sess["wid"])
        deadline = self.hint_ts_mode == "deadline"
        hint_ts = sess["end"] if deadline else ts
        svc = HINT_COST
        if created:
            if self._admit(sub, wk, freq_key=base):
                self.emit_hint(sub, Hint(wk, hint_ts, origin=self.name))
        elif extended or absorbed:
            # the deadline MOVED: re-hint unconditionally so a resident
            # pane is renewed in place (admission dedup would swallow it)
            self.rehints += 1
            self.emit_hint(sub, Hint(wk, hint_ts, origin=self.name))
        elif self._admit(sub, wk, freq_key=base):
            self.emit_hint(sub, Hint(wk, hint_ts, origin=self.name))
        return svc

    def on_watermark(self, sub: int, wm: float) -> None:
        if self.hint_ts_mode != "deadline":
            return
        horizon = wm + self.burst_ahead
        registry = self.sess[sub]
        for base in list(registry):
            sessions = registry[base]
            for s in list(sessions):
                if s["end"] + self.allowed_lateness < wm:
                    sessions.remove(s)    # closed downstream: forget it
                elif s["end"] <= horizon and not s.get("burst") \
                        and self.hint_active:
                    s["burst"] = True
                    self.burst_hints += 1
                    self.emit_hint(sub, Hint(WindowKey(base, s["wid"]),
                                             s["end"], origin=self.name))
            if not sessions:
                del registry[base]

    def reset_volatile(self) -> None:
        super().reset_volatile()
        self.sess = [dict() for _ in range(self.parallelism)]

    def extra_metrics(self) -> Dict[str, Any]:
        out = super().extra_metrics()
        out["rehints"] = self.rehints
        out["tracked_sessions"] = sum(len(lst) for sub in self.sess
                                      for lst in sub.values())
        return out
