"""Fault-tolerance plane: barrier-aligned checkpoints of the keyed-state
plane with prefetch-warmed recovery (DESIGN.md §7).

The paper targets applications that "run forever"; this module makes the
engine survive them.  Three pieces:

  * ``CheckpointCoordinator`` — injects epoch-numbered barriers at every
    source subtask on an interval; operators ALIGN the barrier copies
    across their inputs (buffering post-barrier traffic, metering the
    alignment stall — ``engine.py``), snapshot their keyed state at the
    aligned cut (TAC dirty drain → backend delta, window/join registries,
    HintsBuffer contents, in-flight parked tuples), and the coordinator
    completes the epoch once every (operator, subtask) acked and the
    write landed.  Migrations serialize with epochs (§9 ∩ §7) so no cut
    ever straddles an ownership flip.

  * ``SnapshotStore`` — composes the per-epoch incremental deltas into
    materialized per-partition state (RocksDB-style incremental
    checkpoints), optionally persisting each epoch's delta through the
    same async atomic writer the training checkpoints use
    (``checkpoint/manager.py``).  Only COMPLETED epochs are restorable:
    a failure between alignment and persist rolls the epoch back.

  * failure injection + recovery — ``inject_failure_at`` kills the job
    mid-run (volatile state dropped, pending callbacks purged, in-flight
    network lost); recovery restores the last completed epoch at backend
    speed (no free bulk reads), rewinds the replayable sources to the
    snapshotted offsets, and replays.  The headline is the RECOVERY
    WARMUP (``mode="warmed"``): the cache comes back cold, and the first
    seconds of replay would pay on-demand backend latency — exactly the
    paper's baseline p99 spike.  Warmed recovery re-issues the logged
    hint stream for the replay horizon (the hint WAL + the snapshotted
    HintsBuffer) through the existing ``PrefetchingManager`` BEFORE the
    replayed data path resumes, staging the hot set off the tuple path —
    the same latency-conscious state movement Megaphone applies to
    migration, applied to restarts.

Recorded deviations (§7): emit-side effects are at-least-once (a window
that fired between the cut and the failure re-fires after recovery —
state effects stay exactly-once, duplicates appear only on the emit
path); lookahead soft state (CMS counters) and operator adaptation
statistics are not snapshotted (the controller is coordinator-side and
survives; CMS re-learns).
"""
from __future__ import annotations

import copy
import itertools
import pickle
from typing import Any, Dict, List, Optional, Tuple

from repro.streaming.engine import (Channel, Engine, Operator, SourceOp,
                                    StatefulOp, _IOReq)

# calibrated snapshot-plane constants (DESIGN.md §8): one RTT to the
# durable store per epoch plus the delta at backbone bandwidth (same
# class as the migration bulk path)
SNAPSHOT_RTT = 1e-3
SNAPSHOT_BANDWIDTH = 1.2e9
# warmup replay budget, in multiples of the cache's entry capacity: the
# data replay consumes staged entries while later prefetches issue, so
# modest oversubscription raises coverage — but an UNBOUNDED replay
# (e.g. a long hint WAL over a uniform key tail) thrashes the cache and
# stretches the warmup lead for keys that evict before use
WARMUP_BUDGET_SLACK = 1.5


class SnapshotStore:
    """Durable store for epoch snapshots (DESIGN.md §7).

    Holds per-epoch records (offsets, per-(op, subtask) payloads) and the
    MATERIALIZED per-partition backend state composed from the
    incremental deltas — persisting a delta applies its writes and
    tombstones over the previous epoch's view, so restore hands back full
    state without replaying every epoch.  With ``directory`` set, each
    completed epoch's delta record is additionally pickled to disk
    through ``checkpoint.manager.AsyncAtomicWriter`` (same single-writer
    + atomic-rename discipline as training checkpoints); the in-memory
    view stays authoritative for the simulated restore path.
    """

    def __init__(self, directory: Optional[str] = None, keep: int = 3):
        self.records: Dict[int, dict] = {}
        self.materialized: Dict[Tuple[str, int], Dict[Any, Any]] = {}
        self.last_epoch: Optional[int] = None
        self.keep = keep
        self.persisted_bytes = 0
        self._writer = None
        if directory is not None:
            from repro.checkpoint.manager import AsyncAtomicWriter
            self._writer = AsyncAtomicWriter(directory)

    def persist(self, epoch: int, record: dict) -> None:
        """Publish a completed epoch: apply its deltas to the
        materialized view, retain the record, GC old records."""
        for op_sub, payload in record["ops"].items():
            if not payload:
                continue
            base = self.materialized.setdefault(op_sub, {})
            for k in payload.get("deleted", ()):
                base.pop(k, None)
            base.update(payload.get("delta", {}))
        self.records[epoch] = record
        self.last_epoch = epoch
        self.persisted_bytes += record.get("bytes", 0)
        for e in sorted(self.records)[:-self.keep]:
            del self.records[e]
        if self._writer is not None:
            blob = pickle.dumps({"epoch": epoch, "record": record})

            def _write(tmp):
                with open(f"{tmp}/record.pkl", "wb") as f:
                    f.write(blob)

            self._writer.submit(f"epoch_{epoch:08d}", _write)

    def latest(self) -> Optional[Tuple[int, dict]]:
        if self.last_epoch is None:
            return None
        return self.last_epoch, self.records[self.last_epoch]

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.wait()


class CheckpointCoordinator:
    """JobManager-side checkpoint driver (DESIGN.md §7).

    One epoch in flight at a time: ``trigger`` records the replayable
    sources' offsets, then injects the epoch's barriers at every source
    subtask; downstream alignment and snapshots report back through
    ``Engine.on_snapshot``; once every (operator, subtask) acked, the
    epoch completes after the modelled store write
    (``SNAPSHOT_RTT + bytes / SNAPSHOT_BANDWIDTH``).  A trigger landing
    while shards are migrating is deferred (and vice versa — see
    ``Engine.migrate_shard``): the epoch cut and the ownership flip are
    never concurrent, which is also what keeps shard-forwarding off the
    alignment window.
    """

    def __init__(self, engine: Engine, interval: float = 0.5,
                 store: Optional[SnapshotStore] = None,
                 defer_delay: float = 0.02):
        self.engine = engine
        self.sim = engine.sim
        self.interval = interval
        self.defer_delay = defer_delay
        self.store = store if store is not None else SnapshotStore()
        engine.coordinator = self
        # delta tracking must start BEFORE data flows, or the first
        # epoch's incremental delta misses pre-attach state (backends
        # keep it off otherwise — see StateBackend.track_deltas)
        for op in engine.operators.values():
            if isinstance(op, StatefulOp):
                for bk in op.backends:
                    bk.track_deltas = True
        self._epochs = itertools.count(1)
        self.pending: Optional[dict] = None
        self._queued_migrations: List[Tuple[str, int, int]] = []
        self.in_recovery = False
        # counters (surfaced via Engine.metrics "checkpoint"/"recovery")
        self.epochs_completed = 0
        self.skipped_triggers = 0
        self.deferred_triggers = 0
        self.rolled_back = 0
        self.stale_acks = 0
        self.snapshot_bytes_total = 0
        self.failures = 0
        self.warmup_hints = 0
        self.recoveries: List[dict] = []

    # ------------------------------------------------------------- triggering
    def start(self) -> None:
        self.sim.after(self.interval, self._tick)

    def _tick(self) -> None:
        self.trigger()
        self.sim.after(self.interval, self._tick)

    def _migrating(self) -> bool:
        """True while any shard is in transit OR within the post-landing
        QUIESCE window: tuples partitioned under the old owner table can
        sit in channel buffers for up to the flush timeout and then take
        the one-hop forward (which carries no channel origin, bypassing
        alignment) — a barrier cut inside that tail could process a
        pre-barrier tuple after the snapshot and lose its effects.
        Deferring the trigger until the tail drains closes the window
        (DESIGN.md §7 ∩ §9)."""
        quiesce = 0.0
        for op in self.engine.operators.values():
            for ch in op.out_data:
                quiesce = max(quiesce, ch.timeout)
        from repro.streaming.engine import NET_LATENCY
        quiesce += 3 * NET_LATENCY
        now = self.sim.t
        for op in self.engine.operators.values():
            if isinstance(op, StatefulOp) and op.shards is not None:
                if op.shards.migrating:
                    return True
                if now - op.shards.last_finish_t < quiesce:
                    return True
        return False

    def trigger(self) -> None:
        if self.pending is not None or self.in_recovery:
            self.skipped_triggers += 1
            return
        if self._migrating():
            # serialize with the in-flight migration (§9 ∩ §7)
            self.deferred_triggers += 1
            self.sim.after(self.defer_delay, self.trigger)
            return
        epoch = next(self._epochs)
        offsets = {}
        expected = set()
        for name, op in self.engine.operators.items():
            if isinstance(op, SourceOp):
                if op.replayable:
                    offsets[name] = [op.offset(s)
                                     for s in range(op.parallelism)]
            else:
                expected.update((name, s) for s in range(op.parallelism))
        self.pending = {"epoch": epoch, "t0": self.sim.t,
                        "offsets": offsets, "acks": {},
                        "expected": expected, "bytes": 0}
        self.engine.log_event("epoch_trigger", id=epoch)
        self.engine.trigger_checkpoint(epoch)

    def defer_migration(self, op_name: str, shard: int,
                        dst_sub: int) -> None:
        """Called by ``Engine._do_migrate`` when an epoch is in flight."""
        self._queued_migrations.append((op_name, shard, dst_sub))

    # --------------------------------------------------------------- epoching
    def on_operator_snapshot(self, epoch: int, op: str, sub: int,
                             payload: Optional[dict], stall: float,
                             buffered: int) -> None:
        p = self.pending
        if p is None or p["epoch"] != epoch:
            self.stale_acks += 1
            return
        p["acks"][(op, sub)] = payload
        if set(p["acks"]) >= p["expected"]:
            p["bytes"] = sum(pl.get("bytes", 0)
                             for pl in p["acks"].values() if pl)
            delay = SNAPSHOT_RTT + p["bytes"] / SNAPSHOT_BANDWIDTH
            self.sim.after(delay, self._complete, epoch)

    def _complete(self, epoch: int) -> None:
        p = self.pending
        if p is None or p["epoch"] != epoch:
            return                        # a failure rolled this epoch back
        self.store.persist(epoch, {
            "epoch": epoch, "t0": p["t0"], "offsets": p["offsets"],
            "ops": p["acks"], "bytes": p["bytes"]})
        self.epochs_completed += 1
        self.snapshot_bytes_total += p["bytes"]
        self.engine.log_event("epoch_complete", id=epoch,
                              bytes=p["bytes"])
        self.pending = None
        # reclaim logs no restore can need any more
        for name, offs in p["offsets"].items():
            src = self.engine.operators[name]
            for s, off in enumerate(offs):
                src.trim_log(s, off)
        for op in self.engine.operators.values():
            if isinstance(op, StatefulOp):
                for s in range(op.parallelism):
                    op.hint_log[s] = [h for h in op.hint_log[s]
                                      if h[0] >= p["t0"]]
        # run migrations that waited for the epoch (§9 ∩ §7)
        queued, self._queued_migrations = self._queued_migrations, []
        for op_name, shard, dst_sub in queued:
            self.engine._do_migrate(op_name, shard, dst_sub)

    def metrics_block(self) -> Dict[str, Any]:
        return {
            "epochs_completed": self.epochs_completed,
            "last_completed_epoch": self.store.last_epoch,
            "snapshot_bytes_total": self.snapshot_bytes_total,
            "skipped_triggers": self.skipped_triggers,
            "deferred_triggers": self.deferred_triggers,
            "rolled_back": self.rolled_back,
            "interval": self.interval,
        }

    def registry_sync(self, registry) -> None:
        """Mirror the checkpoint/recovery-plane counters into the
        metrics registry (``checkpoint.*`` / ``recovery.*``, DESIGN.md
        §12); called by ``Engine._sync_registry``."""
        registry.counter("checkpoint.completed").set(self.epochs_completed)
        registry.counter("checkpoint.bytes").set(self.snapshot_bytes_total)
        if self.recoveries:
            rb = self.recovery_block()
            registry.counter("recovery.count").set(rb["failures"])
            registry.counter("recovery.warmup_hints").set(
                rb["warmup_hints"])
            registry.gauge("recovery.restore_s").set(
                rb.get("last_downtime", 0.0))

    # ----------------------------------------------------- failure / recovery
    def fail(self, mode: str = "warmed", down_time: float = 0.05,
             replay_speedup: float = 4.0,
             warmup_lead: Optional[float] = None) -> None:
        """Kill the job NOW and recover from the last completed epoch.

        ``mode``: ``"warmed"`` replays the hint WAL through the
        PrefetchingManagers before the data path resumes; ``"cold"``
        restores state only (the paper's on-demand baseline after
        restore).  ``down_time`` models detection + reschedule;
        ``replay_speedup`` is the catch-up rate multiple.
        """
        if mode not in ("warmed", "cold"):
            raise ValueError(f"mode {mode!r}")
        if self.in_recovery:
            # a second failure landing inside the first recovery's
            # restore/warmup window would interleave two incarnations'
            # resume callbacks (double-scheduled source ticks, doubled
            # replay); overlapping failures are out of scope — fail loud
            raise RuntimeError("failure injected while a recovery is "
                               "already in flight")
        eng = self.engine
        now = self.sim.t
        self.failures += 1
        if self.pending is not None:
            # epoch aligned-but-not-persisted: roll back (DESIGN.md §7)
            self.rolled_back += 1
            self.pending = None
        # migrations deferred behind the rolled-back epoch stay queued:
        # the rebalance request survives the crash (it is control-plane
        # intent, not task state) and replays after restore, exactly
        # like migrations requested during the outage
        self.in_recovery = True
        # the dead incarnation: pending service/I-O completions, source
        # ticks, and in-flight network buffers all die with the process
        purged = self.sim.purge(
            lambda ev: isinstance(getattr(ev[2], "__self__", None),
                                  (Operator, Channel)))
        for op in eng.operators.values():
            for ch in op.out_data + op.out_hint:
                ch.bufs.clear()
                ch.buf_bytes.clear()
                ch.flush_scheduled.clear()
            if isinstance(op, SourceOp):
                op.stopped = True
            op.reset_volatile()
        rec = self.store.latest()
        entry = {"t_fail": now, "mode": mode, "purged_events": purged,
                 "epoch": rec[0] if rec else None, "down_time": down_time,
                 "fid": self.failures}
        self.recoveries.append(entry)
        eng.log_event("failure", id=self.failures, mode=mode)
        self.sim.after(down_time, self._restore, rec, entry, mode,
                       replay_speedup, warmup_lead)

    def _restore(self, rec, entry: dict, mode: str, replay_speedup: float,
                 warmup_lead: Optional[float]) -> None:
        """Re-import the last completed epoch at backend speed, then (for
        ``warmed``) replay the hint WAL, then resume the sources."""
        eng = self.engine
        restore_bytes = 0
        max_delay = 0.0
        record = rec[1] if rec else None
        if record is not None:
            for (op_name, sub), snap in record["ops"].items():
                op = eng.operators[op_name]
                if not isinstance(op, StatefulOp):
                    continue
                items = self.store.materialized.get((op_name, sub), {})
                n = op.backends[sub].restore_snapshot(copy.deepcopy(items))
                b = n * op.state_size
                restore_bytes += b
                # the bulk re-import is a charged backend read: partition
                # restore runs at backend speed, in parallel across subs
                max_delay = max(max_delay, op.backends[sub].latency(b))
                op.restore_extra(sub, copy.deepcopy(snap.get("extra"))
                                 if snap else None)
        t_ready = self.sim.t + max_delay
        entry["restore_bytes"] = restore_bytes
        entry["restore_delay"] = max_delay
        if mode == "warmed" and record is not None:
            plan, n_hints = self._plan_warmup(record)
            self.sim.at(t_ready, self._warmup, plan)
            if warmup_lead is None:
                # enough lead for the I/O lanes to drain the hint replay
                io = sum(op.io_workers * op.parallelism
                         for op in eng.operators.values()
                         if isinstance(op, StatefulOp)) or 1
                lat = max((op.backends[0].latency(op.state_size)
                           for op in eng.operators.values()
                           if isinstance(op, StatefulOp)), default=0.0)
                warmup_lead = min(0.5, 1.2 * lat * n_hints / io)
        else:
            warmup_lead = 0.0
        entry["warmup_lead"] = warmup_lead
        t_resume = t_ready + warmup_lead
        entry["t_resume"] = t_resume
        entry["downtime"] = t_resume - entry["t_fail"]
        self.sim.at(t_resume, self._resume, record, entry, replay_speedup)

    def _plan_warmup(self, record: dict):
        """Build the capped per-(op, subtask) warmup replay (DESIGN.md
        §7): the cache MANIFEST first (resident at the cut = proven
        hot), then the snapshotted HintsBuffer, then the hint WAL newest
        first — deduped and CAPPED at the cache's entry capacity.  A
        replay longer than the cache thrashes: later prefetches evict
        earlier ones, the lead grows, and the warmup stages churn
        instead of the hot set."""
        plan = {}
        total = 0
        for (op_name, sub), snap in record["ops"].items():
            op = self.engine.operators[op_name]
            if not isinstance(op, StatefulOp) or not snap:
                continue
            budget = int(WARMUP_BUDGET_SLACK
                         * max(1, op.cache_capacity
                               // max(1, op.state_size)))
            replay = list(snap.get("manifest", ()))
            replay += list(snap.get("hints", {}).items())
            wal = [(k, ts) for (t, k, ts) in op.hint_log[sub]
                   if t >= record["t0"]]
            replay += reversed(wal)
            seen = set()
            capped = []
            for key, ts in replay:
                if key in seen:
                    continue
                seen.add(key)
                capped.append((key, ts))
                if len(capped) >= budget:
                    break
            plan[(op_name, sub)] = capped
            total += len(capped)
        return plan, total

    def _warmup(self, plan: dict) -> None:
        """Recovery warmup (the headline, DESIGN.md §7): re-issue the
        planned hint replay through the ordinary prefetch path
        (admission, dedup, charged ``peek_latency`` I/O), so the hot set
        stages while the data path is still down."""
        for (op_name, sub), replay in plan.items():
            op = self.engine.operators[op_name]
            for key, ts in replay:
                # logged at the subtask that received it, re-routed by the
                # RESTORED ownership (a post-epoch migration rolled back)
                tgt = op.shards.owner_of(key) if op.shards is not None \
                    else sub
                mgr = op.managers[tgt]
                if mgr.on_hint(key, ts, op.caches[tgt],
                               watermark=op.wm[tgt],
                               lateness=op.hint_lateness):
                    mgr.hints.take(key)
                    op._io_enqueue(tgt, _IOReq("prefetch", key, ts,
                                               origin="recovery"))
                    self.warmup_hints += 1

    def _resume(self, record: Optional[dict], entry: dict,
                replay_speedup: float) -> None:
        eng = self.engine
        offsets = record["offsets"] if record else {}
        for name, op in eng.operators.items():
            if not isinstance(op, SourceOp):
                continue
            if op.replayable:
                offs = offsets.get(name)
                for s in range(op.parallelism):
                    op.rewind(s, offs[s] if offs else op.log_base[s])
                op.resume(replay_speedup=replay_speedup)
            else:
                # non-replayable source: restart live (records during the
                # outage are lost — why the benchmarks run replayable)
                op.stopped = False
                op.start()
        if record is not None:
            # tuples whose effects were NOT in the cut and that no source
            # will replay: parked fetches, mid-migration parks, pending
            # FIREs — re-delivered for exactly-once state effects
            for (op_name, sub), snap in record["ops"].items():
                if snap and snap.get("inflight"):
                    eng.operators[op_name].deliver_batch(
                        sub, copy.deepcopy(snap["inflight"]))
        entry["warmup_hints"] = self.warmup_hints
        self.in_recovery = False
        eng.log_event("recovered", id=entry.get("fid"),
                      warmup_hints=self.warmup_hints)
        # migrations requested during the outage waited for the restore
        queued, self._queued_migrations = self._queued_migrations, []
        for op_name, shard, dst_sub in queued:
            eng._do_migrate(op_name, shard, dst_sub)

    def recovery_block(self) -> Dict[str, Any]:
        last = dict(self.recoveries[-1]) if self.recoveries else {}
        last.pop("purged_events", None)
        replayed = sum(op.replayed for op in self.engine.operators.values()
                       if isinstance(op, SourceOp))
        return {"failures": self.failures, "warmup_hints": self.warmup_hints,
                "replayed": replayed, **{f"last_{k}": v
                                         for k, v in last.items()}}


def inject_failure_at(engine: Engine, at: float, mode: str = "warmed",
                      down_time: float = 0.05,
                      replay_speedup: float = 4.0,
                      warmup_lead: Optional[float] = None) -> None:
    """Schedule a whole-job failure at sim time ``at`` (the streaming
    analogue of ``runtime.supervisor.inject_failure_at``): the attached
    ``CheckpointCoordinator`` kills volatile state and recovers from the
    last completed epoch in ``mode`` ("warmed" | "cold")."""
    coord = engine.coordinator
    if not isinstance(coord, CheckpointCoordinator):
        raise RuntimeError("attach a CheckpointCoordinator before "
                           "injecting failures")
    engine.sim.at(at, coord.fail, mode, down_time, replay_speedup,
                  warmup_lead)
