"""Message types flowing through the dataflow engine."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional


@dataclass
class Tuple_:
    ts: float                 # event time (set at the source)
    key: Any                  # partitioning / state-access key (may be None)
    payload: Any = None
    size: int = 200           # serialized bytes (network accounting)
    ingest_t: float = 0.0     # processing time entering the pipeline
    trace: Any = None         # sampled critical-path span (obs.trace), or
    #                           None on the unsampled fast path — not
    #                           serialized, never crosses a checkpoint


class WindowKey(NamedTuple):
    """State-access key of one window pane: ``(base key, window id)``.

    Routing (``hash_partition``, ``ShardPlane.shard_of``) unwraps ``base``
    so every pane of a key — and every hint for it — lands on the subtask
    that owns the key itself (DESIGN.md §10).
    """
    base: Any
    wid: int


@dataclass
class Hint:
    """Keyed-prefetching hint (DESIGN.md §3, §10).

    ``ts`` is the PREDICTED ACCESS TIMESTAMP of ``key`` — it must be in
    the same clock domain the consuming cache orders entries by, and that
    domain differs per plane:

      * streaming engine: EVENT time.  Per-tuple lookaheads use the
        tuple's event timestamp (the access happens when the tuple
        reaches the stateful operator); windowed lookaheads use the
        WINDOW-FIRE DEADLINE (window end), the exact event time at which
        the pane is read on watermark advance.
      * serving scheduler: PROCESSING (wall/sim) time — the predicted
        decode-start time of the session (DESIGN.md §6).

    The two domains never mix inside one TAC: each stateful operator /
    arena orders by exactly one clock.  ``PrefetchingManager.on_hint``
    names the parameter ``access_ts`` for this reason.
    """
    key: Any
    ts: float                 # predicted access timestamp (see above)
    origin: str = ""          # lookahead operator that emitted the hint
    size: int = 24            # key + timestamp on the wire
    emit_t: float = 0.0       # processing time the lookahead emitted it
    #                           (hint-channel delay telemetry, DESIGN.md §12)


@dataclass
class Marker:
    marker_id: int
    origin: str = "controller"
    lookahead_id: Optional[str] = None
    size: int = 16


@dataclass
class Watermark:
    """Event-time watermark: a promise that no tuple with ``ts`` below
    this will follow on the same input (modulo allowed lateness).
    ``origin`` identifies the (channel, src subtask) pair so operators can
    take the min across ALL their inputs (DESIGN.md §10)."""
    ts: float
    origin: Any = None
    size: int = 16


@dataclass
class CheckpointBarrier:
    """Epoch-numbered checkpoint barrier (DESIGN.md §7).

    Injected at sources by the ``CheckpointCoordinator``
    (``streaming/recovery.py``) and broadcast downstream on every data
    edge.  Like watermarks, each copy is tagged with the (channel, src
    subtask) input it travelled on so a multi-input operator can ALIGN:
    it buffers post-barrier traffic from inputs whose barrier already
    arrived and snapshots only once every input reported (Chandy-Lamport
    via Flink-style aligned barriers)."""
    checkpoint_id: int        # epoch number
    origin: Any = None        # (channel id, src subtask) — set per copy
    size: int = 16
