"""Message types flowing through the dataflow engine."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass
class Tuple_:
    ts: float                 # event time (set at the source)
    key: Any                  # partitioning / state-access key (may be None)
    payload: Any = None
    size: int = 200           # serialized bytes (network accounting)
    ingest_t: float = 0.0     # processing time entering the pipeline


@dataclass
class Hint:
    key: Any
    ts: float                 # event time at which the key will be accessed
    origin: str = ""          # lookahead operator that emitted the hint
    size: int = 24            # key + timestamp on the wire


@dataclass
class Marker:
    marker_id: int
    origin: str = "controller"
    lookahead_id: Optional[str] = None
    size: int = 16


@dataclass
class Watermark:
    ts: float
    size: int = 16


@dataclass
class CheckpointBarrier:
    checkpoint_id: int
    size: int = 16
