"""Yahoo Streaming Benchmark (paper §VI): ad-analytics enrichment against a
DISAGGREGATED key-value store (the paper uses remote Redis).  Events are
114 B; ad ids follow Zipf(alpha=1); the join key is ad_id -> campaign."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.streaming.backend import DISAGGREGATED
from repro.streaming.engine import (Engine, MapOp, SinkOp, SourceOp,
                                    StatefulOp)
from repro.streaming.events import Tuple_


@dataclass
class YSBConfig:
    rate: float = 50_000.0
    n_ads: int = 100_000
    zipf_alpha: float = 1.0
    seed: int = 11


class YSBGen:
    def __init__(self, cfg: YSBConfig):
        self.cfg = cfg
        # counter-based generator: replays bit-exactly from the seed
        # (chaos-oracle determinism contract, DESIGN.md §15)
        self.rng = np.random.Generator(np.random.PCG64(cfg.seed))
        # Zipf(alpha=1) over n_ads via inverse-CDF table
        ranks = np.arange(1, cfg.n_ads + 1, dtype=np.float64)
        w = 1.0 / ranks ** cfg.zipf_alpha
        self.cdf = np.cumsum(w) / w.sum()

    def __call__(self, now: float):
        u = self.rng.random()
        ad = int(np.searchsorted(self.cdf, u))
        etype = self.rng.random()
        return (ad, {"ad": ad, "etype": "view" if etype < 0.33 else "other"},
                114)


def build_ysb(policy: str, mode: str, cfg: YSBConfig,
              cache_entries: int = 4096, parallelism: int = 3,
              source_parallelism: int = 2, io_workers: int = 8,
              cms_conf=None, replayable: bool = False,
              fused: bool = False, fused_batch: int = 64) -> Engine:
    """``replayable=True`` runs the source against a durable log so the
    failure/recovery scenarios (DESIGN.md §7) can rewind and replay it.

    ``fused=True`` runs the enrichment join's hot path on the device
    plane (DESIGN.md §14): the campaign record is a 1-wide read-only row
    and each batch probes + gathers + emits in one jitted program."""
    eng = Engine()
    gen = YSBGen(cfg)
    state_size = 64                        # campaign metadata

    def key_of(tup: Tuple_):
        return tup.payload["ad"]

    def vfilter(tup: Tuple_):
        return tup if tup.payload["etype"] == "view" else None

    def project(tup: Tuple_):
        return tup

    def apply_fn(tup, state):
        return state, [Tuple_(tup.ts, tup.key, (tup.payload, state), 130,
                              tup.ingest_t)]

    fused_kw = {}
    if fused:
        from repro.streaming.fused import FusedSpec
        spec = FusedSpec(
            kind="read", width=1,
            encode=lambda s: [float(s["campaign"])],
            decode=lambda v: {"campaign": int(round(float(v[0])))},
            emit_of=lambda tup, state: [
                Tuple_(tup.ts, tup.key, (tup.payload, state), 130,
                       tup.ingest_t)])
        fused_kw = dict(fused=spec, fused_batch=fused_batch)

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate, gen,
                           replayable=replayable))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=vfilter,
                          service_time=20e-6, key_of=key_of,
                          cms_conf=cms_conf))
    proj = eng.add(MapOp(eng, "project", parallelism, fn=project,
                         service_time=8e-6, key_of=key_of,
                         cms_conf=cms_conf))
    join = eng.add(StatefulOp(
        eng, "stateful", parallelism, apply_fn, DISAGGREGATED,
        cache_entries * state_size, policy=policy, mode=mode,
        io_workers=io_workers, state_size=state_size, read_only=True,
        default_state=lambda k: {"campaign": k % 1000},
        dense_backend=True, **fused_kw))
    sink = eng.add(SinkOp(eng, "sink", 1))
    eng.connect(src, parse)
    eng.connect(parse, proj)
    eng.connect(proj, join)
    eng.connect(join, sink, partition=lambda k, n: 0)
    if mode == "prefetch":
        eng.register_prefetching(join, [parse, proj])
    return eng
