"""Stream-stream joins with two-sided keyed prefetching (DESIGN.md §11).

Joins are where the paper's claim — future access keys are "frequently
known earlier in the query plan" — is strongest: a tuple on either input
names exactly the join key whose state the join operator will fetch, so
BOTH inputs can emit hints for the other side's keyed state long before
the tuple reaches the join.  Three pieces:

  * ``IntervalJoinOp`` — per-key DUAL state buffers (left/right entry
    lists) with event-time retention bounds.  A left entry at ``t`` can
    only match right tuples with ``ts ∈ [t + lo, t + hi]``, so its
    retention deadline is ``t + hi`` (symmetrically ``t − lo`` on the
    right); once the watermark passes a key's maximum live deadline the
    whole key expires — cache ``drop`` + backend ``delete``, never a
    write-back (Belady on interval ends, mirroring the window purge of
    §10).
  * ``WindowedJoinOp`` — co-grouped join panes keyed by ``WindowKey``:
    both sides accumulate into one pane per (key, window) and the join
    fires on watermark advance exactly like ``WindowedStatefulOp``
    (whose firing, late-data, purge, and migration machinery it inherits
    unchanged).
  * ``JoinLookaheadOp`` — the two-sided Hint Extractor: left tuples hint
    the state a future right probe will read and vice versa, carrying
    RETENTION-DEADLINE timestamps (interval joins) or window-fire
    deadlines (windowed joins, inherited from ``WindowedLookaheadOp``
    together with the fire-time burst prefetch).

All three run through the existing sync/async/prefetch/shard machinery:
hints route by shard ownership, misrouted messages forward one hop,
mid-migration traffic parks and replays, and the retention registry
migrates with its shard (§9).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.streaming.engine import HINT_COST, StatefulOp, _IOReq
from repro.streaming.events import Hint, Tuple_
from repro.streaming.windows import (WindowAssigner, WindowedLookaheadOp,
                                     WindowedStatefulOp)

LEFT, RIGHT = "L", "R"


class IntervalJoinOp(StatefulOp):
    """Event-time interval join on the keyed stateful machinery
    (DESIGN.md §11).

    Both inputs arrive on the ONE data edge as a tagged union (the shape
    a physical join takes after the keyed exchange merges its inputs);
    ``side_of(payload)`` recovers the side.  State per join key is a pair
    of entry buffers ``{"L": [(ts, payload), ...], "R": [...]}`` flowing
    through the inherited cache/backend paths, so a join-state read
    parks, prefetches, and migrates exactly like any keyed access.

    Matching: a left entry at ``t_l`` joins a right entry at ``t_r`` iff
    ``lo <= t_r - t_l <= hi`` (Flink interval-join semantics).  Each
    arriving tuple probes the OPPOSITE buffer, emits one output per match
    via ``join_fn(key, left_payload, right_payload)`` (None = no output),
    then appends its own entry — ``keep_fn(side, payload)`` can decline
    the append for pre-filtered build sides.

    Retention and expiry: a left entry is matchable until the watermark
    passes ``t_l + hi``, a right entry until ``t_r - lo``; the per-key
    registry tracks the MAXIMUM live deadline and ``on_watermark`` purges
    keys whose registry deadline (plus ``allowed_lateness``) fell behind
    — ``cache.drop`` + ``backend.delete``, no write-back (expired join
    state is dead, exactly like a fired pane, §10).  Entries inside a
    still-live key prune lazily at the next access.  Tuples whose OWN
    retention deadline is already behind the horizon drop as late;
    within the horizon they still match retained entries (late joins).

    Purge/I-O races: a purge while a fetch for the key is in flight
    marks the key in ``_purged``; the completion is then dropped and
    tuples parked on it count late (``_completion_dead`` /
    ``_on_dead_parked`` hooks).  A write-back already ISSUED at purge
    time may still land in the backend; the landed state is inert — the
    registry entry is gone and a reborn key prunes expired entries at
    first access (recorded deviation, §11).
    """

    def __init__(self, engine, name, parallelism,
                 side_of: Callable[[Any], Optional[str]],
                 join_fn: Callable[[Any, Any, Any], Any],
                 bounds: Tuple[float, float],
                 backend_model, cache_capacity: int,
                 allowed_lateness: float = 0.0,
                 keep_fn: Optional[Callable[[str, Any], bool]] = None,
                 out_size: int = 300, **kw):
        lo, hi = bounds
        if lo > hi:
            raise ValueError(f"need lo ({lo}) <= hi ({hi})")
        # a real (empty) dual-buffer default: a first-touch key's parked
        # resume must read as a hit, not as a second miss
        kw.setdefault("default_state", lambda k: {LEFT: [], RIGHT: []})
        super().__init__(engine, name, parallelism, None, backend_model,
                         cache_capacity, **kw)
        self.side_of = side_of
        self.join_fn = join_fn
        self.lo, self.hi = float(lo), float(hi)
        self.allowed_lateness = float(allowed_lateness)
        # hints behind watermark - lateness target droppable tuples'
        # state (StatefulOp._on_hint admission horizon)
        self.hint_lateness = float(allowed_lateness) + max(
            0.0, -self.lo) + max(0.0, self.hi)
        self.keep_fn = keep_fn
        self.out_size = out_size
        # key -> max live retention deadline, per subtask (purge index)
        self.retention: List[Dict[Any, float]] = \
            [dict() for _ in range(parallelism)]
        # keys purged with I/O possibly in flight: completions must not
        # resurrect them (cleared on key rebirth)
        self._purged: List[Set[Any]] = [set() for _ in range(parallelism)]
        self.joined = 0
        self.late_dropped = 0
        self.late_joins = 0
        self.keys_expired = 0
        self.entries_pruned = 0

    # ------------------------------------------------------------ retention
    def _entry_deadline(self, side: str, ts: float) -> float:
        """Last event time at which an entry on ``side`` can still match
        an on-time arrival on the other side (its interval end)."""
        return ts + self.hi if side == LEFT else ts - self.lo

    # ------------------------------------------------------------- data path
    def _on_data(self, sub: int, tup: Tuple_) -> float:
        side = self.side_of(tup.payload)
        if side not in (LEFT, RIGHT):
            return 5e-7                      # foreign record: ignore
        wm = self.wm[sub]
        if self._entry_deadline(side, tup.ts) + self.allowed_lateness < wm:
            self.late_dropped += 1           # beyond the lateness horizon
            return 5e-7
        self._purged[sub].discard(tup.key)   # key reborn: I/O valid again
        return super()._on_data(sub, tup)

    def _apply(self, sub: int, tup: Tuple_, state: Any) -> float:
        side = self.side_of(tup.payload)
        wm = self.wm[sub]
        d_own = self._entry_deadline(side, tup.ts)
        if d_own + self.allowed_lateness < wm:
            # parked across the horizon while its fetch was in flight:
            # its interval is closed, the match set unrecoverable
            self.late_dropped += 1
            self._trace_absorbed(tup.trace)
            return self.service_time
        horizon = wm - self.allowed_lateness
        # the state dict is owned exclusively by this subtask's cache/
        # backend pair, so it is mutated IN PLACE and re-marked dirty —
        # copy-on-write would rebuild the hot key's buffers per tuple
        st = state if state else {LEFT: [], RIGHT: []}
        # amortized in-key expiry: entries append in arrival order, so
        # the expired run is a prefix up to the out-of-orderness spread;
        # deeper stragglers are skipped at probe time and reclaimed when
        # the prefix reaches them
        for s in (LEFT, RIGHT):
            buf = st[s]
            i = 0
            while i < len(buf) and \
                    self._entry_deadline(s, buf[i][0]) < horizon:
                i += 1
            if i:
                del buf[:i]
                self.entries_pruned += i
        other = RIGHT if side == LEFT else LEFT
        late = tup.ts < wm                   # joining behind the watermark
        emitted = False
        for ts2, p2 in st[other]:
            if self._entry_deadline(other, ts2) < horizon:
                continue                     # straggler awaiting reclaim
            delta = (ts2 - tup.ts) if side == LEFT else (tup.ts - ts2)
            if self.lo <= delta <= self.hi:
                l, r = (tup.payload, p2) if side == LEFT else (p2,
                                                               tup.payload)
                payload = self.join_fn(tup.key, l, r)
                if payload is not None:
                    self.joined += 1
                    if late:
                        self.late_joins += 1
                    self.outputs += 1
                    emitted = True
                    self.emit(sub, Tuple_(tup.ts, tup.key, payload,
                                          self.out_size, tup.ingest_t,
                                          trace=tup.trace))
        if not emitted:
            self._trace_absorbed(tup.trace)  # probe matched nothing (yet)
        if self.keep_fn is None or self.keep_fn(side, tup.payload):
            st[side].append((tup.ts, tup.payload))
        # the registry learns the key even when keep_fn declines the
        # append: the read materialized (empty) state in cache/backend,
        # and only registered keys are ever purged
        reg = self.retention[sub]
        if d_own > reg.get(tup.key, float("-inf")):
            reg[tup.key] = d_own
        self._purged[sub].discard(tup.key)
        self.caches[sub].write(tup.key, st, tup.ts, size=self.state_size)
        self._io_kick(sub)                   # opportunistic write-back
        return self.service_time

    # --------------------------------------------------------------- expiry
    def on_watermark(self, sub: int, wm: float) -> None:
        set_clock = getattr(self.caches[sub], "set_clock", None)
        if set_clock is not None:
            set_clock(wm)
        horizon = wm - self.allowed_lateness
        reg = self.retention[sub]
        for key in [k for k, d in reg.items() if d < horizon]:
            del reg[key]
            self._purge_key(sub, key)

    def _purge_key(self, sub: int, key: Any) -> None:
        """Expire one join key outright: no write-back, no backend
        tombstone cost — the state can never be matched again (§11)."""
        self.caches[sub].drop(key)
        self.backends[sub].delete(key)
        self.keys_expired += 1
        self._purged[sub].add(key)

    # ------------------------------------------------------ purge/I-O races
    def _completion_dead(self, sub: int, req: _IOReq) -> bool:
        """A fetch (or write-back) completing for a key that expired while
        the I/O was in flight must be dropped, not resurrect dead join
        state.  Rebirth (``_on_data``/``_apply``) clears the mark first,
        so a re-opened key's I/O stays valid."""
        return req.key in self._purged[sub]

    def _on_dead_parked(self, sub: int, tup: Tuple_) -> None:
        self.late_dropped += 1

    # ------------------------------------------------------------- migration
    def migrate_shard(self, shard: int, dst_sub: int) -> None:
        """The retention registry and purge marks move with their shard
        (§9), so expiry keeps firing at the new owner and dead keys stay
        dead across the move."""
        plane = self.shards
        src = plane.owner[shard] if plane is not None else None
        super().migrate_shard(shard, dst_sub)
        if plane is None or src is None or src == dst_sub:
            return
        in_shard = lambda k: plane.shard_of(k) == shard
        reg, dreg = self.retention[src], self.retention[dst_sub]
        for key in [k for k in reg if in_shard(k)]:
            d = reg.pop(key)
            if d > dreg.get(key, float("-inf")):
                dreg[key] = d
        moving = {k for k in self._purged[src] if in_shard(k)}
        self._purged[src] -= moving
        self._purged[dst_sub] |= moving

    # ---------------------------------------------------- snapshot / restore
    def snapshot_extra(self, sub: int) -> Dict[str, Any]:
        """The retention registry and purge marks ride the snapshot
        (DESIGN.md §7): restored keys must keep their expiry deadlines
        (watermark purges resume where they left off) and dead keys must
        stay dead across a restore (§11)."""
        import copy
        out = super().snapshot_extra(sub) or {}
        out["retention"] = copy.deepcopy(self.retention[sub])
        out["purged"] = set(self._purged[sub])
        return out

    def restore_extra(self, sub: int, extra: Optional[dict]) -> None:
        super().restore_extra(sub, extra)
        if extra and "retention" in extra:
            self.retention[sub] = extra["retention"]
            self._purged[sub] = set(extra.get("purged", ()))

    def reset_volatile(self) -> None:
        super().reset_volatile()
        self.retention = [dict() for _ in range(self.parallelism)]
        self._purged = [set() for _ in range(self.parallelism)]

    # --------------------------------------------------------------- metrics
    def extra_metrics(self) -> Dict[str, Any]:
        return {"joined": self.joined, "late_dropped": self.late_dropped,
                "late_joins": self.late_joins,
                "keys_expired": self.keys_expired,
                "entries_pruned": self.entries_pruned,
                "live_keys": sum(len(r) for r in self.retention)}


class WindowedJoinOp(WindowedStatefulOp):
    """Co-grouped windowed join (DESIGN.md §11).

    Both sides of the join accumulate into ONE pane per (key, window) —
    ``{"L": [payloads], "R": [payloads]}`` keyed ``WindowKey(key, wid)``
    — and the join result is produced at window fire, when both sides
    are complete.  Everything else is inherited from
    ``WindowedStatefulOp`` (§10) unchanged: watermark-driven FIRE
    messages, allowed-lateness drop/update policies, fire-time purge
    with no write-back, shard migration of live-window registrations.

    ``join_fn(key, left_payloads, right_payloads)`` maps a fired pane to
    the output payload (None = no output, e.g. when a side is empty);
    one-sided panes are counted per side at fire time.
    """

    def __init__(self, engine, name, parallelism, assigner: WindowAssigner,
                 side_of: Callable[[Any], Optional[str]],
                 join_fn: Callable[[Any, List, List], Any],
                 backend_model, cache_capacity: int, **kw):
        self.side_of = side_of
        self.join_fn = join_fn
        self.joined = 0
        self.unmatched = {LEFT: 0, RIGHT: 0}
        super().__init__(engine, name, parallelism, assigner,
                         self._co_group, self._fire_join, backend_model,
                         cache_capacity, **kw)

    def _co_group(self, tup: Tuple_, acc: Any) -> Any:
        side = self.side_of(tup.payload)
        if side not in (LEFT, RIGHT):
            return acc
        # copy-on-write: WindowedStatefulOp only persists a NEW object
        new = {LEFT: list(acc[LEFT]), RIGHT: list(acc[RIGHT])} \
            if acc else {LEFT: [], RIGHT: []}
        new[side].append(tup.payload)
        return new

    def _fire_join(self, key: Any, wid: int, end: float, acc: Any) -> Any:
        if not acc:
            return None
        if not acc[LEFT] or not acc[RIGHT]:
            self.unmatched[RIGHT if acc[LEFT] else LEFT] += 1
            return None
        out = self.join_fn(key, acc[LEFT], acc[RIGHT])
        if out is not None:
            self.joined += 1
        return out

    def extra_metrics(self) -> Dict[str, Any]:
        out = super().extra_metrics()
        out.update({"joined": self.joined,
                    "unmatched_left": self.unmatched[LEFT],
                    "unmatched_right": self.unmatched[RIGHT]})
        return out


class JoinLookaheadOp(WindowedLookaheadOp):
    """Two-sided join Hint Extractor (DESIGN.md §11).

    Either input side names the join key the operator will access, so
    hints cross sides: a LEFT tuple pre-stages the state a future RIGHT
    probe will read and vice versa.  ``hint_sides`` restricts which
    input sides emit (the one-sided ablation: only the probe side
    hints); ``side_of``/``key_of`` recover side and join key per tuple.

    Timestamp semantics per join kind (``hint_ts_mode="deadline"``):

      * windowed (``assigner`` set) — per-pane WINDOW-FIRE deadline
        hints plus the fire-time burst prefetch, inherited from
        ``WindowedLookaheadOp`` (§10);
      * interval (``bounds`` set) — the entry's RETENTION DEADLINE
        (``ts + hi`` left, ``ts − lo`` right, §11) CAPPED at
        ``ts + probe_ahead``, the predicted FIRST cross-side probe time.
        The cap matters: ``Hint.ts`` is a predicted access timestamp,
        and an interval entry's retention deadline bounds its LAST
        possible access, not its next one — hinting the full retention
        would pin every build-side key for its whole matchable life and
        invert eviction priorities whenever the live key population
        exceeds capacity (§11).  Capped, a build-side hint stages the
        key's state just ahead of its first probes and protects it
        across the out-of-orderness slack; renewal by continuing
        probe-side hints keeps hot keys resident after that.

    ``hint_ts_mode="arrival"`` keeps the tuple's event timestamp on both
    sides (the timing ablation: accurate key, but a build-side hint ages
    out immediately under min-ts eviction instead of surviving until its
    first probe).
    """

    def __init__(self, engine, name, parallelism,
                 side_of: Callable[[Any], Optional[str]],
                 key_of: Callable, hint_sides=(LEFT, RIGHT),
                 assigner: Optional[WindowAssigner] = None,
                 bounds: Optional[Tuple[float, float]] = None,
                 fn=None, hint_ts_mode: str = "deadline",
                 burst_ahead: float = 0.0, allowed_lateness: float = 0.0,
                 probe_ahead: float = 0.0,
                 service_time: float = 10e-6,
                 cms_conf: Optional[dict] = None,
                 filter_conf: Optional[dict] = None):
        if (assigner is None) == (bounds is None):
            raise ValueError("exactly one of assigner (windowed) or "
                             "bounds (interval) must be set")
        if bounds is not None and hint_ts_mode == "deadline" \
                and probe_ahead <= 0:
            # probe_ahead == 0 silently collapses deadline hints to the
            # arrival ablation (ts = max(ts, min(d, ts + 0))); callers
            # must choose the protection horizon (build_query passes the
            # workload's out-of-orderness bound)
            raise ValueError("interval deadline hints need probe_ahead"
                             " > 0")
        super().__init__(engine, name, parallelism, assigner, key_of,
                         fn=fn, hint_ts_mode=hint_ts_mode,
                         burst_ahead=burst_ahead,
                         allowed_lateness=allowed_lateness,
                         service_time=service_time, cms_conf=cms_conf,
                         filter_conf=filter_conf)
        self.side_of = side_of
        self.hint_sides = tuple(hint_sides)
        self.bounds = bounds
        self.probe_ahead = float(probe_ahead)
        self.side_hints = {LEFT: 0, RIGHT: 0}
        self.side_suppressed = 0
        # per-subtask max integer join key seen (interval speculation):
        # entity ids in stream workloads grow monotonically (NEXMark
        # auction ids), so keys just ABOVE the frontier are the ones a
        # tuple has not named yet but is about to (DESIGN.md §13)
        self._spec_frontier = [-1] * parallelism

    def _emit_hints_for(self, sub: int, o: Tuple_) -> float:
        key = self.key_of(o)
        if key is None:
            return 0.0
        side = self.side_of(o.payload)
        if side not in self.hint_sides:
            self.side_suppressed += 1        # one-sided ablation
            return 0.0
        if self.assigner is not None:        # windowed: pane deadlines
            self.side_hints[side] += 1
            return self._hint_panes(sub, key, o.ts)
        lo, hi = self.bounds
        if self.hint_ts_mode == "deadline":
            d = o.ts + hi if side == LEFT else o.ts - lo
            # predicted FIRST probe, never beyond the retention deadline
            # and never behind the access itself (class docstring)
            ts = max(o.ts, min(d, o.ts + self.probe_ahead))
        else:
            ts = o.ts
        if self._admit(sub, key):
            self.side_hints[side] += 1
            self.emit_hint(sub, Hint(key, ts, origin=self.name))
        filt = self.filters[sub]
        if filt.speculative and isinstance(key, int) \
                and key > self._spec_frontier[sub]:
            # frontier speculation (class docstring frontier note, §13):
            # hint the next spec_width ids above the new frontier BEFORE
            # any tuple names them — their first probe lands soon after
            # this one's.  note_emit marks them resident so their
            # data-driven hints collapse into correct duplicates.  Fires
            # once per frontier advance, so the volume is bounded by the
            # distinct-key arrival rate, not the tuple rate.
            lo_k = max(key, self._spec_frontier[sub]) + 1
            self._spec_frontier[sub] = key + filt.spec_width
            spec_ts = o.ts + self.probe_ahead
            for nk in range(lo_k, key + filt.spec_width + 1):
                self.speculative_hints += 1
                filt.note_emit(nk, self.sim.t)
                self.emit_hint(sub, Hint(nk, spec_ts, origin=self.name))
        return HINT_COST

    def reset_volatile(self) -> None:
        super().reset_volatile()
        self._spec_frontier = [-1] * self.parallelism

    def extra_metrics(self) -> Dict[str, Any]:
        out = super().extra_metrics()
        out.update({"hints_left": self.side_hints[LEFT],
                    "hints_right": self.side_hints[RIGHT],
                    "side_suppressed": self.side_suppressed})
        return out
