"""NEXMark-style workload (paper §VI): Person 2% / Auction 6% / Bid 92%,
hot-auction probability 50%, hot-bidder 75%, auctions/bidders active for a
rolling window, the hottest auction/bidder rotating every second.

Queries (Fig 5): Q13 enrichment join, Q18 top-1 bid per (auction,bidder),
Q19 top-10 bids per auction, Q20 auction-bid incremental join with a
category filter.  All runs are scaled in state size, not in behaviour.

For the sharded-plane benchmark (DESIGN.md §9, benchmarks/sharding.py) the
classic NEXMark Q3 and Q4 are added in simplified stateful form: Q3 joins
sellers' person profiles with their auctions (keyed by seller, emitting
only "local" sellers), Q4 tracks the max bid and category per auction
(keyed by auction).  Both exercise a different key population than the
bid-dominated Q13/Q18-Q20 — person/seller keys churn far more slowly.

The event-time windowed queries q5/q7 (DESIGN.md §10) and the
stream-stream join queries (§11, benchmarks/joins.py) ride the same
generator: q8 joins newly registered persons with the auctions they open
in the same TUMBLING window (co-grouped panes, fired on watermark), and
q20 — when ``cfg.oo_bound > 0`` enables event time — becomes a true
auction⋈bid INTERVAL join with dual per-key buffers, retention-deadline
expiry, and two-sided hints.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.streaming.backend import (DISAGGREGATED, LOCAL_NVME, BackendModel,
                                     StateBackend)
from repro.streaming.engine import (Engine, MapOp, SinkOp, SourceOp,
                                    StatefulOp, hash_partition)
from repro.streaming.events import Tuple_

BID, AUCTION, PERSON = "bid", "auction", "person"
SIZES = {BID: 200, AUCTION: 500, PERSON: 200}


@dataclass
class NexmarkConfig:
    rate: float = 50_000.0            # events/s
    active_window: float = 60.0       # auctions/bidders stay active (scaled
    #                                   stand-in for the paper's 2 h)
    hot_auction_prob: float = 0.5
    hot_bidder_prob: float = 0.75
    auctions_per_s: float = None      # derived from rate (6%)
    seed: int = 7
    # bounded out-of-orderness (event-time queries, DESIGN.md §10): event
    # timestamps trail arrival by U(0, oo_bound); a late_prob fraction
    # trails by up to 2x the bound — genuinely LATE under a watermark of
    # (max event ts - oo_bound), exercising the drop/update paths
    oo_bound: float = 0.0
    late_prob: float = 0.02
    watermark_interval: float = 0.05
    # auction-id distribution over the active range (hint-quality
    # ablations, DESIGN.md §13): "nexmark" = the paper's hot-auction
    # process above; "uniform" = no skew; "zipf" = Zipf(~1) rank over the
    # active range (zipf_s > 1 sharpens the skew); "shift" = zipf whose
    # rank->id mapping ROTATES every shift_interval seconds — the
    # adversarial case where yesterday's hot set goes cold at once
    key_dist: str = "nexmark"
    zipf_s: float = 1.0
    shift_interval: float = 5.0

    def __post_init__(self):
        if self.auctions_per_s is None:
            self.auctions_per_s = 0.06 * self.rate
        if self.key_dist not in ("nexmark", "uniform", "zipf", "shift"):
            raise ValueError(f"key_dist {self.key_dist!r}")


class NexmarkGen:
    """Single generator for all event types (paper methodology §VI-c).

    Bid wars: a fraction of bids repeats a recent (auction, bidder) pair —
    the paper notes Q18 "has overall more keys that are frequent at any
    point in time"."""

    def __init__(self, cfg: NexmarkConfig):
        self.cfg = cfg
        # one counter-based numpy Generator per workload: every draw is a
        # pure function of (seed, draw index), so a run replays bit-exactly
        # from its seed — the determinism contract the chaos oracle's
        # golden-vs-perturbed comparison rests on (DESIGN.md §15)
        self.rng = np.random.Generator(np.random.PCG64(cfg.seed))
        self.n = 0
        self.recent_pairs = []
        # bid wars belong to the default workload; the synthetic
        # distributions keep a small repeat fraction so the dedup paths
        # stay exercised without masking the distribution's own shape
        self.repeat_pair_prob = 0.4 if cfg.key_dist == "nexmark" else 0.1

    def active_range(self, now: float, per_s: float) -> Tuple[int, int]:
        hi = max(1, int(now * per_s))
        lo = max(0, int((now - self.cfg.active_window) * per_s))
        return lo, hi

    def _auction_id(self, now: float) -> int:
        lo, hi = self.active_range(now, self.cfg.auctions_per_s)
        dist = self.cfg.key_dist
        if dist == "nexmark":
            if self.rng.random() < self.cfg.hot_auction_prob:
                # most popular auction changes once per second (paper §VI-d)
                return min(hi - 1, int(int(now) * self.cfg.auctions_per_s))
            return int(self.rng.integers(lo, max(lo, hi - 1) + 1))
        if dist == "uniform":
            return int(self.rng.integers(lo, max(lo, hi - 1) + 1))
        # zipf / shift: rank ~ Zipf(1) over the active range via the
        # log-uniform trick (rank = n**u - 1 puts prob ~1/(rank+1) mass
        # on each rank); zipf_s > 1 sharpens the head
        n = max(1, hi - lo)
        u = self.rng.random() ** self.cfg.zipf_s
        rank = min(n - 1, int(n ** u) - 1)
        if dist == "shift":
            # rotate the rank->id mapping each epoch: rank 0 (the hottest
            # id) jumps to a fresh region of the keyspace, so the learned
            # hot set goes cold INSTANTLY at the epoch boundary
            epoch = int(now / self.cfg.shift_interval)
            step = max(1, n // 7)
            rank = (rank + epoch * step) % n
        return lo + rank

    def _bidder_id(self, now: float) -> int:
        per_s = max(0.02 * self.cfg.rate, 1.0)
        lo, hi = self.active_range(now, per_s)
        if self.rng.random() < self.cfg.hot_bidder_prob:
            return min(hi - 1, int(int(now) * per_s))
        return int(self.rng.integers(lo, max(lo, hi - 1) + 1))

    def _event_ts(self, now: float) -> float:
        """Bounded-out-of-orderness event time (only when cfg.oo_bound>0):
        most events trail arrival by U(0, bound), a small fraction by up
        to 2x the bound (late under the watermark)."""
        b = self.cfg.oo_bound
        if self.rng.random() < self.cfg.late_prob:
            delay = b * (1.0 + self.rng.random())
        else:
            delay = b * self.rng.random()
        return max(0.0, now - delay)

    def __call__(self, now: float):
        rec = self._gen(now)
        if rec is not None and self.cfg.oo_bound > 0:
            rec = rec + (self._event_ts(now),)
        return rec

    def _gen(self, now: float):
        self.n += 1
        r = self.rng.random()
        if r < 0.92:
            if self.recent_pairs and self.rng.random() < self.repeat_pair_prob:
                a, b = self.recent_pairs[
                    int(self.rng.integers(len(self.recent_pairs)))]
            else:
                a = self._auction_id(now)
                b = self._bidder_id(now)
                self.recent_pairs.append((a, b))
                if len(self.recent_pairs) > 4096:
                    del self.recent_pairs[:2048]
            price = int(self.rng.integers(1, 10_001))
            return (a, {"type": BID, "auction": a, "bidder": b,
                        "price": price}, SIZES[BID])
        if r < 0.98:
            lo, hi = self.active_range(now, self.cfg.auctions_per_s)
            aid = hi                          # a new auction opens
            cat = 10 if self.rng.random() < 0.25 \
                else int(self.rng.integers(10))
            plo, phi = self.active_range(now, max(0.02 * self.cfg.rate, 1.0))
            seller = int(self.rng.integers(plo, max(plo, phi - 1) + 1))
            return (aid, {"type": AUCTION, "auction": aid, "category": cat,
                          "seller": seller}, SIZES[AUCTION])
        lo, hi = self.active_range(now, max(0.02 * self.cfg.rate, 1.0))
        return (hi, {"type": PERSON, "person": hi,
                     "state": int(self.rng.integers(50))}, SIZES[PERSON])


# --------------------------------------------------------------------- plans
def _mk_engine(marker_interval=0.1) -> Engine:
    return Engine(marker_interval)


def _parser(tup: Tuple_) -> Tuple_:
    return tup                          # JSON parse modelled by service time


def build_query(query: str, policy: str, mode: str, cfg: NexmarkConfig,
                cache_entries: int = 4096,
                backend: BackendModel = LOCAL_NVME,
                parallelism: int = 3, source_parallelism: int = 2,
                io_workers: int = 4,
                cms_conf: Optional[dict] = None,
                n_shards: Optional[int] = None,
                buffer_timeout: Optional[float] = None,
                hint_ts: str = "deadline",
                window_size: Optional[float] = None,
                window_slide: Optional[float] = None,
                allowed_lateness: Optional[float] = None,
                join_hints: str = "two",
                join_horizon: Optional[float] = None,
                replayable: bool = False,
                hint_filter: Optional[dict] = None,
                compress_hints: bool = False,
                fused: bool = False,
                fused_batch: int = 64,
                session_gap: Optional[float] = None) -> Engine:
    """policy: lru|clock|tac; mode: sync|async|prefetch.

    With ``n_shards`` the stateful operator runs the sharded state plane
    (DESIGN.md §9): data and hint channels route by shard ownership and
    ``Engine.migrate_shard`` can rebalance mid-run.  ``buffer_timeout``
    overrides the data channels' flush timeout (Flink's low-latency gear,
    e.g. 2 ms, keeps the latency floor from masking state-access effects
    in latency-focused benchmarks).

    The event-time windowed queries q5 (hot items, sliding) and q7
    (highest bid, tumbling) additionally take ``hint_ts`` ("deadline" =
    window-fire deadline hints + burst prefetch, "arrival" = per-tuple
    event-ts hints, the ablation), window geometry overrides, and
    ``allowed_lateness`` (DESIGN.md §10).

    The stream-stream JOIN queries (DESIGN.md §11) — q8 (tumbling-window
    person⋈auction) and q20 with ``cfg.oo_bound > 0`` (event-time
    auction⋈bid interval join; without watermarks q20 keeps its original
    processing-time incremental-join form, the paper-figure baseline) —
    additionally take ``join_hints`` ("two" = both sides emit cross-side
    hints, "one" = probe side only, the ablation) and, for the interval
    join, ``join_horizon`` (how long an auction accepts bids; defaults
    to ``cfg.active_window``).

    q11 (per-bidder activity sessions, DESIGN.md §15) counts bids per
    SESSION window: ``session_gap`` sets the inactivity gap (default
    0.5 s), panes merge on bridging bids, and deadline hints MOVE as
    sessions extend.  ``allowed_lateness`` defaults to ``cfg.oo_bound``
    with the ``update`` late policy (Aion-style re-open).

    ``replayable=True`` puts a durable log in front of the source
    (DESIGN.md §7): the generator runs on a logical clock and records are
    replayable from a checkpointed offset — required for the failure/
    recovery scenarios (``streaming/recovery.py``).

    ``hint_filter`` is a HintFilter config dict applied to every
    lookahead (DESIGN.md §13; e.g. ``{"mode": "selective",
    "speculative": True}``); ``compress_hints`` accounts hint-channel
    bytes under the delta codec.

    ``fused=True`` runs the stateful operator's hot path as one jitted
    device program per batch (DESIGN.md §14).  Only queries whose
    aggregation is declarative — q5 (windowed count = sum of ones) and
    q7 (windowed max bid) — compile; ``fused_batch`` sets the device
    batch width B."""
    if fused and query not in ("q5", "q7"):
        raise ValueError(f"query {query!r} has no fused spec "
                         "(fused mode covers q5/q7, DESIGN.md §14)")
    if query in ("q5", "q7"):
        return _build_windowed_query(
            query, policy, mode, cfg, cache_entries, backend, parallelism,
            source_parallelism, io_workers, cms_conf, n_shards,
            buffer_timeout, hint_ts, window_size, window_slide,
            allowed_lateness, replayable, hint_filter, compress_hints,
            fused, fused_batch)
    if query == "q11":
        return _build_session_query(
            query, policy, mode, cfg, cache_entries, backend, parallelism,
            source_parallelism, io_workers, cms_conf, n_shards,
            buffer_timeout, hint_ts, session_gap, allowed_lateness,
            replayable, hint_filter, compress_hints)
    if query == "q8" or (query == "q20" and cfg.oo_bound > 0):
        return _build_join_query(
            query, policy, mode, cfg, cache_entries, backend, parallelism,
            source_parallelism, io_workers, cms_conf, n_shards,
            buffer_timeout, hint_ts, window_size, allowed_lateness,
            join_hints, join_horizon, replayable, hint_filter,
            compress_hints)
    eng = _mk_engine()
    gen = NexmarkGen(cfg)

    if query == "q3":
        # classic NEXMark Q3 (simplified): person profiles keyed by person
        # id; each auction probes its SELLER's profile and joins when the
        # seller is "local" (state < 10, ~20% selectivity)
        want = {AUCTION, PERSON}
        key_field = "seller"                  # auctions rekey to the seller
        state_size = 300

        def apply_fn(tup, state):
            state = dict(state or {})
            p = tup.payload
            if p["type"] == PERSON:
                state["profile"] = p
                return state, []
            prof = state.get("profile")
            if prof is not None and prof["state"] < 10:
                return state, [Tuple_(tup.ts, tup.key, (p, prof), 300,
                                      tup.ingest_t)]
            return state, []
        read_only = False
        default_state = lambda k: {}
    elif query == "q4":
        # classic NEXMark Q4 (simplified): per-auction running max bid +
        # category (the per-category average is a cheap downstream fold;
        # the keyed-state pressure is all here)
        want = {BID, AUCTION}
        key_field = "auction"
        state_size = 240

        def apply_fn(tup, state):
            state = dict(state or {})
            p = tup.payload
            if p["type"] == AUCTION:
                state["category"] = p["category"]
                return state, []
            if p["price"] > state.get("max", 0):
                state["max"] = p["price"]
                cat = state.get("category", 0)
                return state, [Tuple_(tup.ts, tup.key,
                                      (cat, state["max"]), 200,
                                      tup.ingest_t)]
            return state, []
        read_only = False
        default_state = lambda k: {}
    elif query == "q13":
        want = {BID}
        key_field = "auction"
        state_size = 500

        def apply_fn(tup, state):
            out = Tuple_(tup.ts, tup.key, (tup.payload, state), 300,
                         tup.ingest_t)
            return state, [out]
        read_only = True
        default_state = lambda k: {"meta": k}
    elif query == "q18":
        want = {BID}
        key_field = ("auction", "bidder")
        state_size = 200

        def apply_fn(tup, state):
            state = tup.payload           # keep latest bid by time
            return state, [Tuple_(tup.ts, tup.key, state, 200, tup.ingest_t)]
        read_only = False
        default_state = lambda k: None
    elif query == "q19":
        want = {BID}
        key_field = "auction"
        state_size = 2000                 # ~top-10 bids

        def apply_fn(tup, state):
            top = list(state or [])
            top.append(tup.payload["price"])
            top = sorted(top, reverse=True)[:10]
            return top, [Tuple_(tup.ts, tup.key, tuple(top), 240,
                                tup.ingest_t)]
        read_only = False
        default_state = lambda k: []
    elif query == "q20":
        want = {BID, AUCTION}
        key_field = "auction"
        state_size = 700                  # auction record + last bids

        def apply_fn(tup, state):
            # incremental two-sided join: bids are buffered per auction id
            # (for auctions arriving later) AND probe the auction side
            state = dict(state or {})
            if tup.payload["type"] == AUCTION:
                if tup.payload["category"] == 10:
                    state["auction"] = tup.payload
                return state, []
            bids = state.get("bids") or []
            state["bids"] = (bids + [tup.payload["price"]])[-16:]
            if "auction" in state:
                out = Tuple_(tup.ts, tup.key,
                             (tup.payload, state["auction"]), 400,
                             tup.ingest_t)
                return state, [out]
            return state, []
        read_only = False
        default_state = lambda k: {}
    else:
        raise KeyError(query)

    def type_filter(tup: Tuple_):
        if tup.payload["type"] not in want:
            return None
        return tup

    def gen_filtered(now):
        rec = gen(now)
        return rec

    def key_of(tup: Tuple_):
        p = tup.payload
        if p["type"] not in want:
            return None
        if query == "q20" and p["type"] == AUCTION:
            return None                   # auctions are filtered/small side
        if query == "q3" and p["type"] == PERSON:
            return p["person"]            # profile side keys by person id
        if isinstance(key_field, tuple):
            return (p[key_field[0]], p[key_field[1]])
        return p[key_field]

    def rekey(tup: Tuple_):
        k = key_of(tup)
        if k is not None:
            tup.key = k
        return tup

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate,
                           gen_filtered, replayable=replayable))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=type_filter,
                          service_time=15e-6, key_of=key_of,
                          cms_conf=cms_conf, filter_conf=hint_filter))
    norm = eng.add(MapOp(eng, "normalize", parallelism, fn=rekey,
                         service_time=10e-6, key_of=key_of,
                         cms_conf=cms_conf, filter_conf=hint_filter))
    plane = None
    if n_shards is not None:
        from repro.streaming.shards import ShardPlane
        plane = ShardPlane(n_shards, parallelism)
    stateful = eng.add(StatefulOp(
        eng, "stateful", parallelism, apply_fn, backend, cache_entries
        * state_size, policy=policy, mode=mode, io_workers=io_workers,
        state_size=state_size, read_only=read_only,
        default_state=default_state, dense_backend=(query == "q13"),
        shards=plane))
    sink = eng.add(SinkOp(eng, "sink", 1))

    from repro.streaming.engine import BUFFER_TIMEOUT
    to = BUFFER_TIMEOUT if buffer_timeout is None else buffer_timeout
    # source -> parse is a STATELESS edge: rebalance round-robin (Flink's
    # default for non-keyed exchanges).  Hash-partitioning here would pin
    # the hot auction's ~50% of events to one parse subtask and cap the
    # whole pipeline at that subtask's service rate
    rr = itertools.count()
    eng.connect(src, parse, partition=lambda k, n: next(rr) % n, timeout=to)
    eng.connect(parse, norm, timeout=to)
    eng.connect(norm, stateful,
                partition=plane.route_data if plane else hash_partition,
                timeout=to)
    eng.connect(stateful, sink, partition=lambda k, n: 0, timeout=to)
    if mode == "prefetch":
        eng.register_prefetching(stateful, [parse, norm],
                                 compress_hints=compress_hints)
    return eng


def _build_windowed_query(query, policy, mode, cfg, cache_entries, backend,
                          parallelism, source_parallelism, io_workers,
                          cms_conf, n_shards, buffer_timeout, hint_ts,
                          window_size, window_slide, allowed_lateness,
                          replayable=False, hint_filter=None,
                          compress_hints=False, fused=False,
                          fused_batch=64):
    """Event-time windowed NEXMark queries (DESIGN.md §10).

    q5 (hot items, simplified): bid count per auction per SLIDING window,
    late tuples re-aggregate and re-emit (late-side update); the global
    argmax is a cheap downstream fold.  q7 (highest bid, simplified): max
    bid per auction per TUMBLING window, late tuples dropped.  Both key
    panes by ``WindowKey(auction, wid)`` and fire on watermark advance.
    """
    import itertools as _it

    from repro.streaming.windows import (WindowAssigner, WindowedLookaheadOp,
                                         WindowedStatefulOp)

    if cfg.oo_bound <= 0:
        raise ValueError("windowed queries need cfg.oo_bound > 0 "
                         "(event-time watermarks)")

    if query == "q5":
        size = 2.0 if window_size is None else window_size
        slide = size / 2 if window_slide is None else window_slide
        lateness = (slide if allowed_lateness is None
                    else allowed_lateness)
        late_policy = "update"
        state_size = 96                   # a counter + pane metadata

        def agg_fn(tup, acc):
            return (acc or 0) + 1

        def emit_fn(key, wid, end, acc):
            return ("count", key, acc) if acc else None
    else:                                 # q7
        size = 2.0 if window_size is None else window_size
        slide = size if window_slide is None else window_slide
        lateness = 0.0 if allowed_lateness is None else allowed_lateness
        late_policy = "drop" if lateness == 0 else "update"
        state_size = 96

        def agg_fn(tup, acc):
            price = tup.payload["price"]
            return price if acc is None or price > acc else acc

        def emit_fn(key, wid, end, acc):
            return ("maxbid", key, acc) if acc is not None else None

    fused_kw = {}
    if fused:
        # declarative device forms of the aggregations above (§14): the
        # pane accumulator is an int in both queries, exact in f32 for
        # counts < 2^24 and prices <= 10_000
        from repro.streaming.fused import FusedSpec
        if query == "q5":
            spec = FusedSpec(
                kind="sum", width=1,
                weight_of=lambda tup: 1.0,
                encode=lambda s: None if s is None else [float(s)],
                decode=lambda v: int(round(float(v[0]))))
        else:                             # q7: running max bid
            spec = FusedSpec(
                kind="max", width=1,
                weight_of=lambda tup: float(tup.payload["price"]),
                encode=lambda s: None if s is None else [float(s)],
                decode=lambda v: int(round(float(v[0]))))
        fused_kw = dict(fused=spec, fused_batch=fused_batch)

    assigner = WindowAssigner(size, slide)
    eng = _mk_engine()
    gen = NexmarkGen(cfg)

    def bid_filter(tup: Tuple_):
        return tup if tup.payload["type"] == BID else None

    def key_of(tup: Tuple_):
        p = tup.payload
        return p["auction"] if p["type"] == BID else None

    def rekey(tup: Tuple_):
        tup.key = tup.payload["auction"]
        return tup

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate,
                           gen, watermark_interval=cfg.watermark_interval,
                           oo_bound=cfg.oo_bound, replayable=replayable))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=bid_filter,
                          service_time=15e-6))
    winla = eng.add(WindowedLookaheadOp(
        eng, "win_lookahead", parallelism, assigner, key_of, fn=rekey,
        hint_ts_mode=hint_ts, burst_ahead=2 * cfg.watermark_interval,
        allowed_lateness=lateness, service_time=10e-6, cms_conf=cms_conf,
        filter_conf=hint_filter))
    plane = None
    if n_shards is not None:
        from repro.streaming.shards import ShardPlane
        plane = ShardPlane(n_shards, parallelism)
    stateful = eng.add(WindowedStatefulOp(
        eng, "stateful", parallelism, assigner, agg_fn, emit_fn, backend,
        cache_entries * state_size, allowed_lateness=lateness,
        late_policy=late_policy, policy=policy, mode=mode,
        io_workers=io_workers, state_size=state_size,
        # arrival-ts hints are accurate in KEY, only mistimed: disable the
        # per-origin mismatch discard so the ablation stays on (§10); the
        # deadline-aware eviction order belongs to deadline hints only —
        # arrival timestamps are recency, and ranking them as deadlines
        # would evict the hottest keys first
        miss_threshold=1.01, deadline_aware=(hint_ts == "deadline"),
        shards=plane, **fused_kw))
    sink = eng.add(SinkOp(eng, "sink", 1))

    from repro.streaming.engine import BUFFER_TIMEOUT
    to = BUFFER_TIMEOUT if buffer_timeout is None else buffer_timeout
    rr = _it.count()
    eng.connect(src, parse, partition=lambda k, n: next(rr) % n, timeout=to)
    rr2 = _it.count()
    eng.connect(parse, winla, partition=lambda k, n: next(rr2) % n,
                timeout=to)
    eng.connect(winla, stateful,
                partition=plane.route_data if plane else hash_partition,
                timeout=to)
    eng.connect(stateful, sink, partition=lambda k, n: 0, timeout=to)
    if mode == "prefetch":
        eng.register_prefetching(stateful, [winla],
                                 compress_hints=compress_hints)
    return eng


def _build_session_query(query, policy, mode, cfg, cache_entries, backend,
                         parallelism, source_parallelism, io_workers,
                         cms_conf, n_shards, buffer_timeout, hint_ts,
                         session_gap, allowed_lateness, replayable=False,
                         hint_filter=None, compress_hints=False):
    """NEXMark q11 (simplified): per-BIDDER activity sessions — bid count
    per session, a session closing after ``session_gap`` of inactivity
    (DESIGN.md §15).

    The only window type whose fire deadline is data-driven: every bid
    extends its session's end and a bridging bid MERGES two sessions, so
    the lookahead re-hints moving deadlines and the TAC renews resident
    panes in place.  The parser rekeys bids to the bidder BEFORE the
    keyed exchange into the lookahead, so the lookahead and the stateful
    operator partition by the same key and see each bidder's bids in one
    FIFO order — the lockstep their mirrored session registries need.
    """
    import itertools as _it

    from repro.streaming.sessions import (SessionLookaheadOp,
                                          SessionWindowAssigner,
                                          SessionWindowedOp)

    if cfg.oo_bound <= 0:
        raise ValueError("session query needs cfg.oo_bound > 0 "
                         "(event-time watermarks drive session firing)")
    gap = 0.5 if session_gap is None else float(session_gap)
    lateness = cfg.oo_bound if allowed_lateness is None \
        else float(allowed_lateness)
    late_policy = "update" if lateness > 0 else "drop"
    state_size = 96                       # a counter + pane metadata

    assigner = SessionWindowAssigner(gap)
    eng = _mk_engine()
    gen = NexmarkGen(cfg)

    def bid_rekey(tup: Tuple_):
        p = tup.payload
        if p["type"] != BID:
            return None
        tup.key = p["bidder"]
        return tup

    def key_of(tup: Tuple_):
        p = tup.payload
        return p["bidder"] if p["type"] == BID else None

    def agg_fn(tup, acc):
        return (acc or 0) + 1

    def merge_fn(a, b):
        return (a or 0) + (b or 0)

    def emit_fn(key, wid, end, acc):
        # the session id (canonical: derived from the session's earliest
        # bid) rides along so downstream — and the chaos oracle — can
        # identify WHICH session a count belongs to
        return ("session", key, wid, acc) if acc else None

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate,
                           gen, watermark_interval=cfg.watermark_interval,
                           oo_bound=cfg.oo_bound, replayable=replayable))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=bid_rekey,
                          service_time=15e-6))
    sessla = eng.add(SessionLookaheadOp(
        eng, "sess_lookahead", parallelism, assigner, key_of,
        hint_ts_mode=hint_ts, burst_ahead=2 * cfg.watermark_interval,
        allowed_lateness=lateness, service_time=10e-6, cms_conf=cms_conf,
        filter_conf=hint_filter))
    plane = None
    if n_shards is not None:
        from repro.streaming.shards import ShardPlane
        plane = ShardPlane(n_shards, parallelism)
    stateful = eng.add(SessionWindowedOp(
        eng, "stateful", parallelism, assigner, agg_fn, emit_fn, backend,
        cache_entries * state_size, merge_fn=merge_fn,
        allowed_lateness=lateness, late_policy=late_policy, policy=policy,
        mode=mode, io_workers=io_workers, state_size=state_size,
        miss_threshold=1.01, deadline_aware=(hint_ts == "deadline"),
        shards=plane))
    sink = eng.add(SinkOp(eng, "sink", 1))

    from repro.streaming.engine import BUFFER_TIMEOUT
    to = BUFFER_TIMEOUT if buffer_timeout is None else buffer_timeout
    rr = _it.count()
    eng.connect(src, parse, partition=lambda k, n: next(rr) % n, timeout=to)
    # parse -> lookahead is KEYED (unlike the fixed-window plans): the
    # session registry is per key, so the lookahead must see each
    # bidder's full, ordered bid stream
    eng.connect(parse, sessla, timeout=to)
    eng.connect(sessla, stateful,
                partition=plane.route_data if plane else hash_partition,
                timeout=to)
    eng.connect(stateful, sink, partition=lambda k, n: 0, timeout=to)
    if mode == "prefetch":
        eng.register_prefetching(stateful, [sessla],
                                 compress_hints=compress_hints)
    return eng


def _build_join_query(query, policy, mode, cfg, cache_entries, backend,
                      parallelism, source_parallelism, io_workers,
                      cms_conf, n_shards, buffer_timeout, hint_ts,
                      window_size, allowed_lateness, join_hints,
                      join_horizon, replayable=False, hint_filter=None,
                      compress_hints=False):
    """Stream-stream join queries with two-sided keyed prefetching
    (DESIGN.md §11).

    q8 (simplified classic NEXMark): persons who registered AND opened an
    auction in the same TUMBLING window — a co-grouped windowed join
    keyed by person/seller id (``WindowedJoinOp``), firing on watermark.
    Left side = person registrations, right side = that seller's
    auctions; hints carry the pane's window-fire deadline.

    q20 (event-time form): each bid enriched with its auction record
    when the auction is in category 10 — an INTERVAL join keyed by
    auction id (``IntervalJoinOp``) with bounds ``[0, join_horizon]``
    (a bid matches an auction opened up to ``join_horizon`` earlier).
    Auction entries retain until their interval end, bids only across
    the out-of-orderness slack, and expired keys purge on watermark
    advance.  Hints carry RETENTION deadlines: an auction hint protects
    the key's dual buffers for the auction's whole matchable life.

    ``join_hints``: "two" = both sides emit cross-side hints, "one" =
    probe side only (auctions for q8, bids for q20 — the one-sided
    ablation the joins benchmark measures against).
    """
    import itertools as _it

    from repro.streaming.joins import (LEFT, RIGHT, IntervalJoinOp,
                                       JoinLookaheadOp, WindowedJoinOp)
    from repro.streaming.windows import WindowAssigner

    if cfg.oo_bound <= 0:
        raise ValueError("join queries need cfg.oo_bound > 0 "
                         "(event-time watermarks drive retention/firing)")
    if join_hints not in ("one", "two"):
        raise ValueError(f"join_hints {join_hints!r}")

    eng = _mk_engine()
    gen = NexmarkGen(cfg)
    lateness = 0.0 if allowed_lateness is None else float(allowed_lateness)

    if query == "q8":
        want = {PERSON, AUCTION}
        size = 2.0 if window_size is None else window_size
        assigner = WindowAssigner(size)
        state_size = 160                  # person record + auction id list

        def side_of(p):
            return LEFT if p["type"] == PERSON else RIGHT

        def key_of(tup: Tuple_):
            p = tup.payload
            if p["type"] == PERSON:
                return p["person"]
            if p["type"] == AUCTION:
                return p["seller"]
            return None

        def join_fn(key, persons, auctions):
            # person registered and opened >= 1 auction in this window
            return ("active_seller", key, len(auctions))
        # the probe side (one-sided ablation) is the auction stream: it
        # dominates the keyed traffic and names the seller directly
        hint_sides = (LEFT, RIGHT) if join_hints == "two" else (RIGHT,)
    elif query == "q20":
        want = {AUCTION, BID}
        horizon = cfg.active_window if join_horizon is None \
            else float(join_horizon)
        bounds = (0.0, horizon)           # bid.ts - auction.ts in [0, hor]
        state_size = 700                  # auction record + live bid tail

        def side_of(p):
            return LEFT if p["type"] == AUCTION else RIGHT

        def key_of(tup: Tuple_):
            p = tup.payload
            return p["auction"] if p["type"] in want else None

        def join_fn(key, auction, bid):
            # the category filter must also guard the PROBE path: an
            # out-of-order non-cat-10 auction arriving after its bids
            # would otherwise enrich the buffered bids keep_fn kept
            return (bid, auction) if auction["category"] == 10 else None

        def keep_fn(side, p):
            # the category filter runs before the buffer on the build
            # side (Flink's q20 plan); bids buffer within retention so a
            # late/out-of-order auction still finds its early bids
            return side == RIGHT or p["category"] == 10
        hint_sides = (LEFT, RIGHT) if join_hints == "two" else (RIGHT,)
    else:
        raise KeyError(query)

    def type_filter(tup: Tuple_):
        return tup if tup.payload["type"] in want else None

    def rekey(tup: Tuple_):
        k = key_of(tup)
        if k is not None:
            tup.key = k
        return tup

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate,
                           gen, watermark_interval=cfg.watermark_interval,
                           oo_bound=cfg.oo_bound, replayable=replayable))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=type_filter,
                          service_time=15e-6))
    la_kw = dict(fn=rekey, hint_sides=hint_sides, hint_ts_mode=hint_ts,
                 allowed_lateness=lateness, service_time=10e-6,
                 cms_conf=cms_conf, filter_conf=hint_filter)
    if query == "q8":
        lookahead = eng.add(JoinLookaheadOp(
            eng, "join_lookahead", parallelism, side_of, key_of,
            assigner=assigner, burst_ahead=2 * cfg.watermark_interval,
            **la_kw))
    else:
        # build-side hints protect across the out-of-orderness slack in
        # which the first probe arrives (JoinLookaheadOp docstring)
        lookahead = eng.add(JoinLookaheadOp(
            eng, "join_lookahead", parallelism, side_of, key_of,
            bounds=bounds, probe_ahead=cfg.oo_bound, **la_kw))
    plane = None
    if n_shards is not None:
        from repro.streaming.shards import ShardPlane
        plane = ShardPlane(n_shards, parallelism)
    # the single lookahead must stay active to be a fair ablation, so
    # the per-origin mismatch discard is off (miss_threshold > 1, as the
    # windowed queries do, §10); q8 panes carry fire deadlines and use
    # deadline-aware eviction, while interval retention deadlines are
    # LAST-access bounds — min-ts protection is the right reading there
    # (Belady applies only when the deadline IS the next access, §11)
    if query == "q8":
        join = eng.add(WindowedJoinOp(
            eng, "join", parallelism, assigner, side_of, join_fn, backend,
            cache_entries * state_size, allowed_lateness=lateness,
            late_policy="drop" if lateness == 0 else "update",
            policy=policy, mode=mode, io_workers=io_workers,
            state_size=state_size, miss_threshold=1.01,
            deadline_aware=(hint_ts == "deadline"), shards=plane))
    else:
        join = eng.add(IntervalJoinOp(
            eng, "join", parallelism, side_of, join_fn, bounds, backend,
            cache_entries * state_size, allowed_lateness=lateness,
            keep_fn=keep_fn, out_size=400, policy=policy, mode=mode,
            io_workers=io_workers, state_size=state_size,
            miss_threshold=1.01, shards=plane))
    sink = eng.add(SinkOp(eng, "sink", 1))

    from repro.streaming.engine import BUFFER_TIMEOUT
    to = BUFFER_TIMEOUT if buffer_timeout is None else buffer_timeout
    rr = _it.count()
    eng.connect(src, parse, partition=lambda k, n: next(rr) % n, timeout=to)
    rr2 = _it.count()
    eng.connect(parse, lookahead, partition=lambda k, n: next(rr2) % n,
                timeout=to)
    eng.connect(lookahead, join,
                partition=plane.route_data if plane else hash_partition,
                timeout=to)
    eng.connect(join, sink, partition=lambda k, n: 0, timeout=to)
    if mode == "prefetch":
        eng.register_prefetching(join, [lookahead],
                                 compress_hints=compress_hints)
    return eng
