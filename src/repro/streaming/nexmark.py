"""NEXMark-style workload (paper §VI): Person 2% / Auction 6% / Bid 92%,
hot-auction probability 50%, hot-bidder 75%, auctions/bidders active for a
rolling window, the hottest auction/bidder rotating every second.

Queries (Fig 5): Q13 enrichment join, Q18 top-1 bid per (auction,bidder),
Q19 top-10 bids per auction, Q20 auction-bid incremental join with a
category filter.  All runs are scaled in state size, not in behaviour.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from repro.streaming.backend import (DISAGGREGATED, LOCAL_NVME, BackendModel,
                                     StateBackend)
from repro.streaming.engine import (Engine, MapOp, SinkOp, SourceOp,
                                    StatefulOp, hash_partition)
from repro.streaming.events import Tuple_

BID, AUCTION, PERSON = "bid", "auction", "person"
SIZES = {BID: 200, AUCTION: 500, PERSON: 200}


@dataclass
class NexmarkConfig:
    rate: float = 50_000.0            # events/s
    active_window: float = 60.0       # auctions/bidders stay active (scaled
    #                                   stand-in for the paper's 2 h)
    hot_auction_prob: float = 0.5
    hot_bidder_prob: float = 0.75
    auctions_per_s: float = None      # derived from rate (6%)
    seed: int = 7

    def __post_init__(self):
        if self.auctions_per_s is None:
            self.auctions_per_s = 0.06 * self.rate


class NexmarkGen:
    """Single generator for all event types (paper methodology §VI-c).

    Bid wars: a fraction of bids repeats a recent (auction, bidder) pair —
    the paper notes Q18 "has overall more keys that are frequent at any
    point in time"."""

    def __init__(self, cfg: NexmarkConfig):
        self.cfg = cfg
        self.rng = random.Random(cfg.seed)
        self.n = 0
        self.recent_pairs = []
        self.repeat_pair_prob = 0.4

    def active_range(self, now: float, per_s: float) -> Tuple[int, int]:
        hi = max(1, int(now * per_s))
        lo = max(0, int((now - self.cfg.active_window) * per_s))
        return lo, hi

    def _auction_id(self, now: float) -> int:
        lo, hi = self.active_range(now, self.cfg.auctions_per_s)
        if self.rng.random() < self.cfg.hot_auction_prob:
            # the most popular auction changes once per second (paper §VI-d)
            return min(hi - 1, int(int(now) * self.cfg.auctions_per_s))
        return self.rng.randint(lo, max(lo, hi - 1))

    def _bidder_id(self, now: float) -> int:
        per_s = max(0.02 * self.cfg.rate, 1.0)
        lo, hi = self.active_range(now, per_s)
        if self.rng.random() < self.cfg.hot_bidder_prob:
            return min(hi - 1, int(int(now) * per_s))
        return self.rng.randint(lo, max(lo, hi - 1))

    def __call__(self, now: float):
        self.n += 1
        r = self.rng.random()
        if r < 0.92:
            if self.recent_pairs and self.rng.random() < self.repeat_pair_prob:
                a, b = self.recent_pairs[
                    self.rng.randrange(len(self.recent_pairs))]
            else:
                a = self._auction_id(now)
                b = self._bidder_id(now)
                self.recent_pairs.append((a, b))
                if len(self.recent_pairs) > 4096:
                    del self.recent_pairs[:2048]
            price = self.rng.randint(1, 10_000)
            return (a, {"type": BID, "auction": a, "bidder": b,
                        "price": price}, SIZES[BID])
        if r < 0.98:
            lo, hi = self.active_range(now, self.cfg.auctions_per_s)
            aid = hi                          # a new auction opens
            cat = 10 if self.rng.random() < 0.25 else 0
            return (aid, {"type": AUCTION, "auction": aid, "category": cat},
                    SIZES[AUCTION])
        lo, hi = self.active_range(now, max(0.02 * self.cfg.rate, 1.0))
        return (hi, {"type": PERSON, "person": hi}, SIZES[PERSON])


# --------------------------------------------------------------------- plans
def _mk_engine(marker_interval=0.1) -> Engine:
    return Engine(marker_interval)


def _parser(tup: Tuple_) -> Tuple_:
    return tup                          # JSON parse modelled by service time


def build_query(query: str, policy: str, mode: str, cfg: NexmarkConfig,
                cache_entries: int = 4096,
                backend: BackendModel = LOCAL_NVME,
                parallelism: int = 3, source_parallelism: int = 2,
                io_workers: int = 4,
                cms_conf: Optional[dict] = None) -> Engine:
    """policy: lru|clock|tac; mode: sync|async|prefetch."""
    eng = _mk_engine()
    gen = NexmarkGen(cfg)

    if query == "q13":
        want = {BID}
        key_field = "auction"
        state_size = 500

        def apply_fn(tup, state):
            out = Tuple_(tup.ts, tup.key, (tup.payload, state), 300,
                         tup.ingest_t)
            return state, [out]
        read_only = True
        default_state = lambda k: {"meta": k}
    elif query == "q18":
        want = {BID}
        key_field = ("auction", "bidder")
        state_size = 200

        def apply_fn(tup, state):
            state = tup.payload           # keep latest bid by time
            return state, [Tuple_(tup.ts, tup.key, state, 200, tup.ingest_t)]
        read_only = False
        default_state = lambda k: None
    elif query == "q19":
        want = {BID}
        key_field = "auction"
        state_size = 2000                 # ~top-10 bids

        def apply_fn(tup, state):
            top = list(state or [])
            top.append(tup.payload["price"])
            top = sorted(top, reverse=True)[:10]
            return top, [Tuple_(tup.ts, tup.key, tuple(top), 240,
                                tup.ingest_t)]
        read_only = False
        default_state = lambda k: []
    elif query == "q20":
        want = {BID, AUCTION}
        key_field = "auction"
        state_size = 700                  # auction record + last bids

        def apply_fn(tup, state):
            # incremental two-sided join: bids are buffered per auction id
            # (for auctions arriving later) AND probe the auction side
            state = dict(state or {})
            if tup.payload["type"] == AUCTION:
                if tup.payload["category"] == 10:
                    state["auction"] = tup.payload
                return state, []
            bids = state.get("bids") or []
            state["bids"] = (bids + [tup.payload["price"]])[-16:]
            if "auction" in state:
                out = Tuple_(tup.ts, tup.key,
                             (tup.payload, state["auction"]), 400,
                             tup.ingest_t)
                return state, [out]
            return state, []
        read_only = False
        default_state = lambda k: {}
    else:
        raise KeyError(query)

    def type_filter(tup: Tuple_):
        if tup.payload["type"] not in want:
            return None
        return tup

    def gen_filtered(now):
        rec = gen(now)
        return rec

    def key_of(tup: Tuple_):
        p = tup.payload
        if p["type"] not in want:
            return None
        if query == "q20" and p["type"] == AUCTION:
            return None                   # auctions are filtered/small side
        if isinstance(key_field, tuple):
            return (p[key_field[0]], p[key_field[1]])
        return p[key_field]

    def rekey(tup: Tuple_):
        k = key_of(tup)
        if k is not None:
            tup.key = k
        return tup

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate,
                           gen_filtered))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=type_filter,
                          service_time=15e-6, key_of=key_of,
                          cms_conf=cms_conf))
    norm = eng.add(MapOp(eng, "normalize", parallelism, fn=rekey,
                         service_time=10e-6, key_of=key_of,
                         cms_conf=cms_conf))
    stateful = eng.add(StatefulOp(
        eng, "stateful", parallelism, apply_fn, backend, cache_entries
        * state_size, policy=policy, mode=mode, io_workers=io_workers,
        state_size=state_size, read_only=read_only,
        default_state=default_state, dense_backend=(query == "q13")))
    sink = eng.add(SinkOp(eng, "sink", 1))

    eng.connect(src, parse, partition=lambda k, n: hash(k) % n)
    eng.connect(parse, norm)
    eng.connect(norm, stateful)
    eng.connect(stateful, sink, partition=lambda k, n: 0)
    if mode == "prefetch":
        eng.register_prefetching(stateful, [parse, norm])
    return eng
