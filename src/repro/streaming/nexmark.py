"""NEXMark-style workload (paper §VI): Person 2% / Auction 6% / Bid 92%,
hot-auction probability 50%, hot-bidder 75%, auctions/bidders active for a
rolling window, the hottest auction/bidder rotating every second.

Queries (Fig 5): Q13 enrichment join, Q18 top-1 bid per (auction,bidder),
Q19 top-10 bids per auction, Q20 auction-bid incremental join with a
category filter.  All runs are scaled in state size, not in behaviour.

For the sharded-plane benchmark (DESIGN.md §9, benchmarks/sharding.py) the
classic NEXMark Q3 and Q4 are added in simplified stateful form: Q3 joins
sellers' person profiles with their auctions (keyed by seller, emitting
only "local" sellers), Q4 tracks the max bid and category per auction
(keyed by auction).  Both exercise a different key population than the
bid-dominated Q13/Q18-Q20 — person/seller keys churn far more slowly.
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from repro.streaming.backend import (DISAGGREGATED, LOCAL_NVME, BackendModel,
                                     StateBackend)
from repro.streaming.engine import (Engine, MapOp, SinkOp, SourceOp,
                                    StatefulOp, hash_partition)
from repro.streaming.events import Tuple_

BID, AUCTION, PERSON = "bid", "auction", "person"
SIZES = {BID: 200, AUCTION: 500, PERSON: 200}


@dataclass
class NexmarkConfig:
    rate: float = 50_000.0            # events/s
    active_window: float = 60.0       # auctions/bidders stay active (scaled
    #                                   stand-in for the paper's 2 h)
    hot_auction_prob: float = 0.5
    hot_bidder_prob: float = 0.75
    auctions_per_s: float = None      # derived from rate (6%)
    seed: int = 7
    # bounded out-of-orderness (event-time queries, DESIGN.md §10): event
    # timestamps trail arrival by U(0, oo_bound); a late_prob fraction
    # trails by up to 2x the bound — genuinely LATE under a watermark of
    # (max event ts - oo_bound), exercising the drop/update paths
    oo_bound: float = 0.0
    late_prob: float = 0.02
    watermark_interval: float = 0.05

    def __post_init__(self):
        if self.auctions_per_s is None:
            self.auctions_per_s = 0.06 * self.rate


class NexmarkGen:
    """Single generator for all event types (paper methodology §VI-c).

    Bid wars: a fraction of bids repeats a recent (auction, bidder) pair —
    the paper notes Q18 "has overall more keys that are frequent at any
    point in time"."""

    def __init__(self, cfg: NexmarkConfig):
        self.cfg = cfg
        self.rng = random.Random(cfg.seed)
        self.n = 0
        self.recent_pairs = []
        self.repeat_pair_prob = 0.4

    def active_range(self, now: float, per_s: float) -> Tuple[int, int]:
        hi = max(1, int(now * per_s))
        lo = max(0, int((now - self.cfg.active_window) * per_s))
        return lo, hi

    def _auction_id(self, now: float) -> int:
        lo, hi = self.active_range(now, self.cfg.auctions_per_s)
        if self.rng.random() < self.cfg.hot_auction_prob:
            # the most popular auction changes once per second (paper §VI-d)
            return min(hi - 1, int(int(now) * self.cfg.auctions_per_s))
        return self.rng.randint(lo, max(lo, hi - 1))

    def _bidder_id(self, now: float) -> int:
        per_s = max(0.02 * self.cfg.rate, 1.0)
        lo, hi = self.active_range(now, per_s)
        if self.rng.random() < self.cfg.hot_bidder_prob:
            return min(hi - 1, int(int(now) * per_s))
        return self.rng.randint(lo, max(lo, hi - 1))

    def _event_ts(self, now: float) -> float:
        """Bounded-out-of-orderness event time (only when cfg.oo_bound>0):
        most events trail arrival by U(0, bound), a small fraction by up
        to 2x the bound (late under the watermark)."""
        b = self.cfg.oo_bound
        if self.rng.random() < self.cfg.late_prob:
            delay = b * (1.0 + self.rng.random())
        else:
            delay = b * self.rng.random()
        return max(0.0, now - delay)

    def __call__(self, now: float):
        rec = self._gen(now)
        if rec is not None and self.cfg.oo_bound > 0:
            rec = rec + (self._event_ts(now),)
        return rec

    def _gen(self, now: float):
        self.n += 1
        r = self.rng.random()
        if r < 0.92:
            if self.recent_pairs and self.rng.random() < self.repeat_pair_prob:
                a, b = self.recent_pairs[
                    self.rng.randrange(len(self.recent_pairs))]
            else:
                a = self._auction_id(now)
                b = self._bidder_id(now)
                self.recent_pairs.append((a, b))
                if len(self.recent_pairs) > 4096:
                    del self.recent_pairs[:2048]
            price = self.rng.randint(1, 10_000)
            return (a, {"type": BID, "auction": a, "bidder": b,
                        "price": price}, SIZES[BID])
        if r < 0.98:
            lo, hi = self.active_range(now, self.cfg.auctions_per_s)
            aid = hi                          # a new auction opens
            cat = 10 if self.rng.random() < 0.25 else self.rng.randrange(10)
            plo, phi = self.active_range(now, max(0.02 * self.cfg.rate, 1.0))
            seller = self.rng.randint(plo, max(plo, phi - 1))
            return (aid, {"type": AUCTION, "auction": aid, "category": cat,
                          "seller": seller}, SIZES[AUCTION])
        lo, hi = self.active_range(now, max(0.02 * self.cfg.rate, 1.0))
        return (hi, {"type": PERSON, "person": hi,
                     "state": self.rng.randrange(50)}, SIZES[PERSON])


# --------------------------------------------------------------------- plans
def _mk_engine(marker_interval=0.1) -> Engine:
    return Engine(marker_interval)


def _parser(tup: Tuple_) -> Tuple_:
    return tup                          # JSON parse modelled by service time


def build_query(query: str, policy: str, mode: str, cfg: NexmarkConfig,
                cache_entries: int = 4096,
                backend: BackendModel = LOCAL_NVME,
                parallelism: int = 3, source_parallelism: int = 2,
                io_workers: int = 4,
                cms_conf: Optional[dict] = None,
                n_shards: Optional[int] = None,
                buffer_timeout: Optional[float] = None,
                hint_ts: str = "deadline",
                window_size: Optional[float] = None,
                window_slide: Optional[float] = None,
                allowed_lateness: Optional[float] = None) -> Engine:
    """policy: lru|clock|tac; mode: sync|async|prefetch.

    With ``n_shards`` the stateful operator runs the sharded state plane
    (DESIGN.md §9): data and hint channels route by shard ownership and
    ``Engine.migrate_shard`` can rebalance mid-run.  ``buffer_timeout``
    overrides the data channels' flush timeout (Flink's low-latency gear,
    e.g. 2 ms, keeps the latency floor from masking state-access effects
    in latency-focused benchmarks).

    The event-time windowed queries q5 (hot items, sliding) and q7
    (highest bid, tumbling) additionally take ``hint_ts`` ("deadline" =
    window-fire deadline hints + burst prefetch, "arrival" = per-tuple
    event-ts hints, the ablation), window geometry overrides, and
    ``allowed_lateness`` (DESIGN.md §10)."""
    if query in ("q5", "q7"):
        return _build_windowed_query(
            query, policy, mode, cfg, cache_entries, backend, parallelism,
            source_parallelism, io_workers, cms_conf, n_shards,
            buffer_timeout, hint_ts, window_size, window_slide,
            allowed_lateness)
    eng = _mk_engine()
    gen = NexmarkGen(cfg)

    if query == "q3":
        # classic NEXMark Q3 (simplified): person profiles keyed by person
        # id; each auction probes its SELLER's profile and joins when the
        # seller is "local" (state < 10, ~20% selectivity)
        want = {AUCTION, PERSON}
        key_field = "seller"                  # auctions rekey to the seller
        state_size = 300

        def apply_fn(tup, state):
            state = dict(state or {})
            p = tup.payload
            if p["type"] == PERSON:
                state["profile"] = p
                return state, []
            prof = state.get("profile")
            if prof is not None and prof["state"] < 10:
                return state, [Tuple_(tup.ts, tup.key, (p, prof), 300,
                                      tup.ingest_t)]
            return state, []
        read_only = False
        default_state = lambda k: {}
    elif query == "q4":
        # classic NEXMark Q4 (simplified): per-auction running max bid +
        # category (the per-category average is a cheap downstream fold;
        # the keyed-state pressure is all here)
        want = {BID, AUCTION}
        key_field = "auction"
        state_size = 240

        def apply_fn(tup, state):
            state = dict(state or {})
            p = tup.payload
            if p["type"] == AUCTION:
                state["category"] = p["category"]
                return state, []
            if p["price"] > state.get("max", 0):
                state["max"] = p["price"]
                cat = state.get("category", 0)
                return state, [Tuple_(tup.ts, tup.key,
                                      (cat, state["max"]), 200,
                                      tup.ingest_t)]
            return state, []
        read_only = False
        default_state = lambda k: {}
    elif query == "q13":
        want = {BID}
        key_field = "auction"
        state_size = 500

        def apply_fn(tup, state):
            out = Tuple_(tup.ts, tup.key, (tup.payload, state), 300,
                         tup.ingest_t)
            return state, [out]
        read_only = True
        default_state = lambda k: {"meta": k}
    elif query == "q18":
        want = {BID}
        key_field = ("auction", "bidder")
        state_size = 200

        def apply_fn(tup, state):
            state = tup.payload           # keep latest bid by time
            return state, [Tuple_(tup.ts, tup.key, state, 200, tup.ingest_t)]
        read_only = False
        default_state = lambda k: None
    elif query == "q19":
        want = {BID}
        key_field = "auction"
        state_size = 2000                 # ~top-10 bids

        def apply_fn(tup, state):
            top = list(state or [])
            top.append(tup.payload["price"])
            top = sorted(top, reverse=True)[:10]
            return top, [Tuple_(tup.ts, tup.key, tuple(top), 240,
                                tup.ingest_t)]
        read_only = False
        default_state = lambda k: []
    elif query == "q20":
        want = {BID, AUCTION}
        key_field = "auction"
        state_size = 700                  # auction record + last bids

        def apply_fn(tup, state):
            # incremental two-sided join: bids are buffered per auction id
            # (for auctions arriving later) AND probe the auction side
            state = dict(state or {})
            if tup.payload["type"] == AUCTION:
                if tup.payload["category"] == 10:
                    state["auction"] = tup.payload
                return state, []
            bids = state.get("bids") or []
            state["bids"] = (bids + [tup.payload["price"]])[-16:]
            if "auction" in state:
                out = Tuple_(tup.ts, tup.key,
                             (tup.payload, state["auction"]), 400,
                             tup.ingest_t)
                return state, [out]
            return state, []
        read_only = False
        default_state = lambda k: {}
    else:
        raise KeyError(query)

    def type_filter(tup: Tuple_):
        if tup.payload["type"] not in want:
            return None
        return tup

    def gen_filtered(now):
        rec = gen(now)
        return rec

    def key_of(tup: Tuple_):
        p = tup.payload
        if p["type"] not in want:
            return None
        if query == "q20" and p["type"] == AUCTION:
            return None                   # auctions are filtered/small side
        if query == "q3" and p["type"] == PERSON:
            return p["person"]            # profile side keys by person id
        if isinstance(key_field, tuple):
            return (p[key_field[0]], p[key_field[1]])
        return p[key_field]

    def rekey(tup: Tuple_):
        k = key_of(tup)
        if k is not None:
            tup.key = k
        return tup

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate,
                           gen_filtered))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=type_filter,
                          service_time=15e-6, key_of=key_of,
                          cms_conf=cms_conf))
    norm = eng.add(MapOp(eng, "normalize", parallelism, fn=rekey,
                         service_time=10e-6, key_of=key_of,
                         cms_conf=cms_conf))
    plane = None
    if n_shards is not None:
        from repro.streaming.shards import ShardPlane
        plane = ShardPlane(n_shards, parallelism)
    stateful = eng.add(StatefulOp(
        eng, "stateful", parallelism, apply_fn, backend, cache_entries
        * state_size, policy=policy, mode=mode, io_workers=io_workers,
        state_size=state_size, read_only=read_only,
        default_state=default_state, dense_backend=(query == "q13"),
        shards=plane))
    sink = eng.add(SinkOp(eng, "sink", 1))

    from repro.streaming.engine import BUFFER_TIMEOUT
    to = BUFFER_TIMEOUT if buffer_timeout is None else buffer_timeout
    # source -> parse is a STATELESS edge: rebalance round-robin (Flink's
    # default for non-keyed exchanges).  Hash-partitioning here would pin
    # the hot auction's ~50% of events to one parse subtask and cap the
    # whole pipeline at that subtask's service rate
    rr = itertools.count()
    eng.connect(src, parse, partition=lambda k, n: next(rr) % n, timeout=to)
    eng.connect(parse, norm, timeout=to)
    eng.connect(norm, stateful,
                partition=plane.route_data if plane else hash_partition,
                timeout=to)
    eng.connect(stateful, sink, partition=lambda k, n: 0, timeout=to)
    if mode == "prefetch":
        eng.register_prefetching(stateful, [parse, norm])
    return eng


def _build_windowed_query(query, policy, mode, cfg, cache_entries, backend,
                          parallelism, source_parallelism, io_workers,
                          cms_conf, n_shards, buffer_timeout, hint_ts,
                          window_size, window_slide, allowed_lateness):
    """Event-time windowed NEXMark queries (DESIGN.md §10).

    q5 (hot items, simplified): bid count per auction per SLIDING window,
    late tuples re-aggregate and re-emit (late-side update); the global
    argmax is a cheap downstream fold.  q7 (highest bid, simplified): max
    bid per auction per TUMBLING window, late tuples dropped.  Both key
    panes by ``WindowKey(auction, wid)`` and fire on watermark advance.
    """
    import itertools as _it

    from repro.streaming.windows import (WindowAssigner, WindowedLookaheadOp,
                                         WindowedStatefulOp)

    if cfg.oo_bound <= 0:
        raise ValueError("windowed queries need cfg.oo_bound > 0 "
                         "(event-time watermarks)")

    if query == "q5":
        size = 2.0 if window_size is None else window_size
        slide = size / 2 if window_slide is None else window_slide
        lateness = (slide if allowed_lateness is None
                    else allowed_lateness)
        late_policy = "update"
        state_size = 96                   # a counter + pane metadata

        def agg_fn(tup, acc):
            return (acc or 0) + 1

        def emit_fn(key, wid, end, acc):
            return ("count", key, acc) if acc else None
    else:                                 # q7
        size = 2.0 if window_size is None else window_size
        slide = size if window_slide is None else window_slide
        lateness = 0.0 if allowed_lateness is None else allowed_lateness
        late_policy = "drop" if lateness == 0 else "update"
        state_size = 96

        def agg_fn(tup, acc):
            price = tup.payload["price"]
            return price if acc is None or price > acc else acc

        def emit_fn(key, wid, end, acc):
            return ("maxbid", key, acc) if acc is not None else None

    assigner = WindowAssigner(size, slide)
    eng = _mk_engine()
    gen = NexmarkGen(cfg)

    def bid_filter(tup: Tuple_):
        return tup if tup.payload["type"] == BID else None

    def key_of(tup: Tuple_):
        p = tup.payload
        return p["auction"] if p["type"] == BID else None

    def rekey(tup: Tuple_):
        tup.key = tup.payload["auction"]
        return tup

    src = eng.add(SourceOp(eng, "source", source_parallelism, cfg.rate,
                           gen, watermark_interval=cfg.watermark_interval,
                           oo_bound=cfg.oo_bound))
    parse = eng.add(MapOp(eng, "parser", parallelism, fn=bid_filter,
                          service_time=15e-6))
    winla = eng.add(WindowedLookaheadOp(
        eng, "win_lookahead", parallelism, assigner, key_of, fn=rekey,
        hint_ts_mode=hint_ts, burst_ahead=2 * cfg.watermark_interval,
        allowed_lateness=lateness, service_time=10e-6, cms_conf=cms_conf))
    plane = None
    if n_shards is not None:
        from repro.streaming.shards import ShardPlane
        plane = ShardPlane(n_shards, parallelism)
    stateful = eng.add(WindowedStatefulOp(
        eng, "stateful", parallelism, assigner, agg_fn, emit_fn, backend,
        cache_entries * state_size, allowed_lateness=lateness,
        late_policy=late_policy, policy=policy, mode=mode,
        io_workers=io_workers, state_size=state_size,
        # arrival-ts hints are accurate in KEY, only mistimed: disable the
        # per-origin mismatch discard so the ablation stays on (§10); the
        # deadline-aware eviction order belongs to deadline hints only —
        # arrival timestamps are recency, and ranking them as deadlines
        # would evict the hottest keys first
        miss_threshold=1.01, deadline_aware=(hint_ts == "deadline"),
        shards=plane))
    sink = eng.add(SinkOp(eng, "sink", 1))

    from repro.streaming.engine import BUFFER_TIMEOUT
    to = BUFFER_TIMEOUT if buffer_timeout is None else buffer_timeout
    rr = _it.count()
    eng.connect(src, parse, partition=lambda k, n: next(rr) % n, timeout=to)
    rr2 = _it.count()
    eng.connect(parse, winla, partition=lambda k, n: next(rr2) % n,
                timeout=to)
    eng.connect(winla, stateful,
                partition=plane.route_data if plane else hash_partition,
                timeout=to)
    eng.connect(stateful, sink, partition=lambda k, n: 0, timeout=to)
    if mode == "prefetch":
        eng.register_prefetching(stateful, [winla])
    return eng
