"""Event-time windowed operators with watermark-driven keyed prefetching
(DESIGN.md §10).

Window panes are keyed state whose FUTURE ACCESS TIME is known exactly:
a pane keyed ``WindowKey(key, wid)`` is read when the watermark crosses
the window end.  That makes windows the sharpest consumer of the paper's
Timestamp-Aware Caching — hints carry the window-fire DEADLINE as their
access timestamp, so the TAC protects live panes until they fire and
ranks dead ones for eviction, and the upstream lookahead pre-stages every
live pane of a closing window right before the watermark crosses it
(fire-time burst prefetch).

Three pieces:

  * ``WindowAssigner`` — tumbling/sliding window membership by event time
    (tumbling is sliding with ``slide == size``).
  * ``WindowedStatefulOp`` — keys state by ``(key, window id)``, fires on
    watermark advance through the operator's normal keyed machinery (so
    fire-time state reads park/prefetch/queue exactly like tuple-time
    reads), and handles late tuples with a configurable allowed-lateness
    path: ``drop`` counts them, ``update`` re-aggregates and re-emits an
    updated result (late-side updates a la Aion).
  * ``WindowedLookaheadOp`` — the windowed Hint Extractor: per tuple it
    emits one hint per target pane with the chosen timestamp semantics
    (``deadline`` = window end, ``arrival`` = tuple event ts, the ablation
    baseline), and on watermark advance burst-emits deadline hints for all
    live panes of any window within ``burst_ahead`` of firing.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Set

import numpy as _np

from repro.streaming.engine import HINT_COST, MapOp, StatefulOp, _IOReq
from repro.streaming.events import Hint, Tuple_, WindowKey
from repro.streaming.fused import Lane


class _Fire:
    """Sentinel payload of a self-addressed fire message.  Identity IS
    the semantics (``payload is FIRE``), so copies and pickles — snapshot
    capture of pending FIREs, DESIGN.md §7 — must resolve back to the
    singleton."""
    __slots__ = ()

    def __repr__(self):
        return "<FIRE>"

    def __copy__(self):
        return self

    def __deepcopy__(self, memo):
        return self

    def __reduce__(self):
        return (_fire_singleton, ())


FIRE = _Fire()


def _fire_singleton() -> _Fire:
    return FIRE


class WindowAssigner:
    """Tumbling/sliding event-time window membership (DESIGN.md §10).

    Window ``wid`` covers ``[wid * slide, wid * slide + size)``; a
    timestamp belongs to ``size / slide`` windows (1 for tumbling).
    """

    def __init__(self, size: float, slide: Optional[float] = None):
        slide = size if slide is None else slide
        if not 0 < slide <= size:
            raise ValueError(f"need 0 < slide ({slide}) <= size ({size})")
        self.size = size
        self.slide = slide

    def assign(self, ts: float) -> List[int]:
        wid = math.floor(ts / self.slide)
        out = []
        while wid * self.slide > ts - self.size:
            out.append(wid)
            wid -= 1
        return out

    def start(self, wid: int) -> float:
        return wid * self.slide

    def end(self, wid: int) -> float:
        return wid * self.slide + self.size


class WindowedStatefulOp(StatefulOp):
    """Keyed windowed aggregation on the stateful-operator machinery
    (DESIGN.md §10; the co-grouped windowed JOIN of §11 subclasses this
    with a two-sided pane accumulator).

    Each incoming tuple expands into one state access per target pane
    (``WindowKey(key, wid)``) and flows through the inherited sync/async/
    prefetch paths unchanged — so pane reads park, prefetch, and queue
    exactly like any keyed access, and the sharded plane (§9) guards,
    forwards, and migrates panes by their BASE key.

    Firing: when the subtask watermark crosses a window end, one FIRE
    message per live pane is self-delivered through the input queue; its
    state read goes through the same cache/backend path (a pane evicted
    before firing is refetched — synchronously in ``sync`` mode, via the
    I/O lanes otherwise), then ``emit_fn`` produces the result tuple with
    ``ingest_t`` = the fire-eligible time, so sink latency measures
    watermark-to-delivery.

    Late tuples (window end + ``allowed_lateness`` behind the watermark)
    are dropped and counted.  Tuples for a FIRED window still inside the
    lateness horizon follow ``late_policy``: ``drop`` discards them,
    ``update`` re-aggregates and immediately re-emits an updated result.
    Panes purge (cache drop + backend delete, no write-back) at fire time
    when lateness is zero, else when the horizon passes.
    """

    def __init__(self, engine, name, parallelism, assigner: WindowAssigner,
                 agg_fn: Callable[[Tuple_, Any], Any],
                 emit_fn: Callable[[Any, int, float, Any], Any],
                 backend_model, cache_capacity: int,
                 allowed_lateness: float = 0.0, late_policy: str = "drop",
                 out_size: int = 200, **kw):
        if late_policy not in ("drop", "update"):
            raise ValueError(f"late_policy {late_policy!r}")
        if late_policy == "update" and allowed_lateness <= 0:
            # with zero lateness a pane purges at fire time, so there is
            # no retained state for a late-side update to refresh
            raise ValueError("late_policy='update' needs allowed_lateness"
                             " > 0")
        kw.setdefault("default_state", lambda k: None)
        # pass deadline_aware=True (StatefulOp kwarg) when hints carry
        # fire deadlines: pane timestamps are then far-future access
        # times, where the paper's plain min-ts eviction would remove the
        # panes firing next (core/tac.py, DESIGN.md §10).  Arrival-ts
        # hint pipelines keep the default order — their timestamps are
        # recency, not deadlines.
        super().__init__(engine, name, parallelism, None, backend_model,
                         cache_capacity, **kw)
        self.hint_lateness = float(allowed_lateness)
        self.assigner = assigner
        self.agg_fn = agg_fn
        self.emit_fn = emit_fn
        self.allowed_lateness = float(allowed_lateness)
        self.late_policy = late_policy
        self.out_size = out_size
        # wid -> {"keys": live base keys, "fired": watermark crossed the
        # end, "fired_keys": keys whose FIRE was scheduled (or that
        # arrived late and must not fire)}, per subtask.  Fired state is
        # per KEY, not just per window: a migration can merge fired and
        # unfired pane populations of the same window when the source and
        # destination watermarks straddle its end.
        self.windows: List[Dict[int, dict]] = \
            [dict() for _ in range(parallelism)]
        self.fires = 0
        self.fires_lost = 0
        self.late_dropped = 0
        self.late_updates = 0
        self.panes_purged = 0

    # ------------------------------------------------------------- data path
    def _on_data(self, sub: int, tup: Tuple_) -> float:
        if isinstance(tup.key, WindowKey):
            # already a pane access: a migration replay or parked resume
            return super()._on_data(sub, tup)
        wm = self.wm[sub]
        svc, n = 0.0, 0
        for wid in self.assigner.assign(tup.ts):
            end = self.assigner.end(wid)
            if end + self.allowed_lateness < wm:
                self.late_dropped += 1          # beyond the lateness horizon
                continue
            meta = self.windows[sub].get(wid)
            if meta is not None and meta["fired"] \
                    and self.late_policy == "drop":
                self.late_dropped += 1          # fired, drop-policy
                continue
            if meta is None:
                meta = {"keys": set(), "fired": False,
                        "fired_keys": set()}
                self.windows[sub][wid] = meta
            meta["keys"].add(tup.key)
            if meta["fired"]:
                # late key joining a fired window (update policy): it
                # emits per-tuple updates, never a FIRE of its own
                meta["fired_keys"].add(tup.key)
            n += 1
            svc += super()._on_data(sub, Tuple_(
                tup.ts, WindowKey(tup.key, wid), tup.payload, tup.size,
                tup.ingest_t, trace=tup.trace))
        if not n:
            self._trace_absorbed(tup.trace)  # dropped before any pane
        return svc if n else 5e-7

    def _apply(self, sub: int, tup: Tuple_, state: Any) -> float:
        wk: WindowKey = tup.key
        if tup.payload is FIRE:
            end = self.assigner.end(wk.wid)
            payload = self.emit_fn(wk.base, wk.wid, end, state)
            self.fires += 1
            if self.engine.record_events:
                self.engine.log_event("fire", op=self.name, wid=wk.wid)
            if payload is not None:
                self.outputs += 1
                self.emit(sub, Tuple_(end, wk.base, payload, self.out_size,
                                      tup.ingest_t, trace=tup.trace))
            if self.allowed_lateness == 0:
                self._purge_pane(sub, wk)
            return self.service_time
        meta = self.windows[sub].get(wk.wid)
        if meta is not None and meta["fired"] and self.late_policy != \
                "update":
            # drop policy, yet the tuple reached _apply after the fire:
            # it parked on a state fetch across the window boundary, so
            # its contribution can no longer reach the fired result (and
            # writing would resurrect a purged pane)
            self.late_dropped += 1
            self._trace_absorbed(tup.trace)
            return self.service_time
        acc = self.agg_fn(tup, state)
        emitted = False
        if meta is not None and meta["fired"]:
            # late-side update: re-emit the refreshed result immediately
            self.late_updates += 1
            payload = self.emit_fn(wk.base, wk.wid,
                                   self.assigner.end(wk.wid), acc)
            if payload is not None:
                self.outputs += 1
                emitted = True
                self.emit(sub, Tuple_(tup.ts, wk.base, payload,
                                      self.out_size, tup.ingest_t,
                                      trace=tup.trace))
        if acc is not state:
            self.caches[sub].write(wk, acc, tup.ts, size=self.state_size)
            self._io_kick(sub)
        if not emitted:
            self._trace_absorbed(tup.trace)  # folded into the pane
        return self.service_time

    # ------------------------------------------------------ fused data path
    def _fused_prospect(self, sub: int, tup: Tuple_):
        if isinstance(tup.key, WindowKey):
            return (tup.key,), tup.payload is FIRE
        return (tuple(WindowKey(tup.key, wid)
                      for wid in self.assigner.assign(tup.ts)), False)

    def _fused_expand(self, sub: int, tup: Tuple_, keys=None):
        """Pane expansion for a fused batch, mirroring ``_on_data``: the
        lateness-horizon and fired-window checks run here (device lanes
        cannot re-check mid-batch; no watermark can interleave, so the
        decision is the same one ``_apply`` would take).  FIRE lanes ride
        as read-only lanes; tuples joining a fired window under the
        update policy become late-update lanes (§14).  ``keys`` reuses
        the prospect's WindowKeys so assignment runs once per tuple."""
        spec = self.fused_spec
        zeros = (0.0,) * spec.width
        if isinstance(tup.key, WindowKey):
            wk = tup.key
            if tup.payload is FIRE:
                return [Lane(wk, tup.ts, zeros, True, False, tup)]
            # replayed / re-delivered pane access (migration replay is
            # unreachable — fused excludes shards — but recovery
            # re-delivery lands here): take the fired checks now
            meta = self.windows[sub].get(wk.wid)
            if meta is not None and meta["fired"]:
                if self.late_policy != "update":
                    self.late_dropped += 1
                    self._trace_absorbed(tup.trace)
                    return []
                return [Lane(wk, tup.ts, spec.weight_raw(tup), False,
                             True, tup)]
            return [Lane(wk, tup.ts, spec.weight_raw(tup), False, False,
                         tup)]
        wm = self.wm[sub]
        out = []
        wks = keys if keys is not None \
            else tuple(WindowKey(tup.key, wid)
                       for wid in self.assigner.assign(tup.ts))
        w_raw = None
        for wk in wks:
            wid = wk.wid
            end = self.assigner.end(wid)
            if end + self.allowed_lateness < wm:
                self.late_dropped += 1          # beyond the horizon
                continue
            meta = self.windows[sub].get(wid)
            if meta is not None and meta["fired"] \
                    and self.late_policy == "drop":
                self.late_dropped += 1          # fired, drop-policy
                continue
            if meta is None:
                meta = {"keys": set(), "fired": False,
                        "fired_keys": set()}
                self.windows[sub][wid] = meta
            meta["keys"].add(tup.key)
            late = meta["fired"]
            if late:
                meta["fired_keys"].add(tup.key)
            if w_raw is None:
                w_raw = spec.weight_raw(tup)
            out.append(Lane(wk, tup.ts, w_raw, False, late, tup))
        if not out:
            self._trace_absorbed(tup.trace)     # dropped before any pane
        return out

    def _fused_fire(self, sub: int, lane: Lane, state: Any) -> None:
        """Device-hit FIRE lane: the pane value came back in the batch
        read — emit exactly like ``_apply``'s FIRE branch.  (A fire lane
        whose pane was evicted device-misses and parks/refetches through
        the interpreted path instead.)"""
        wk: WindowKey = lane.key
        end = self.assigner.end(wk.wid)
        payload = self.emit_fn(wk.base, wk.wid, end, state)
        self.fires += 1
        if self.engine.record_events:
            self.engine.log_event("fire", op=self.name, wid=wk.wid)
        if payload is not None:
            self.outputs += 1
            self.emit(sub, Tuple_(end, wk.base, payload, self.out_size,
                                  lane.tup.ingest_t, trace=lane.tup.trace))
        if self.allowed_lateness == 0:
            self._purge_pane(sub, wk)

    def _fused_late(self, sub: int, lane: Lane, acc: Any) -> None:
        """Device-hit late-update lane: the device already composed and
        wrote the refreshed accumulator; re-emit it (§10 update policy)."""
        wk: WindowKey = lane.key
        tup = lane.tup
        self.late_updates += 1
        payload = self.emit_fn(wk.base, wk.wid, self.assigner.end(wk.wid),
                               acc)
        if payload is not None:
            self.outputs += 1
            self.emit(sub, Tuple_(tup.ts, wk.base, payload, self.out_size,
                                  tup.ingest_t, trace=tup.trace))
        else:
            self._trace_absorbed(tup.trace)

    # ---------------------------------------------------------------- firing
    def on_watermark(self, sub: int, wm: float) -> None:
        set_clock = getattr(self.caches[sub], "set_clock", None)
        if set_clock is not None:
            # deadline_aware staleness boundary: panes whose fire deadline
            # is still ahead of the WATERMARK stay protected
            set_clock(wm)
        fire_batch = []
        now = self.sim.t
        for wid in sorted(self.windows[sub]):
            meta = self.windows[sub][wid]
            end = self.assigner.end(wid)
            to_fire = meta["keys"] - meta["fired_keys"] \
                if end <= wm else None
            if to_fire:
                # covers both the first crossing and unfired panes merged
                # in by a migration after this window already fired here
                meta["fired"] = True
                meta["fired_keys"] |= to_fire
                for base in to_fire:
                    fire_batch.append(Tuple_(end, WindowKey(base, wid),
                                             FIRE, 32, now))
            elif not meta["fired"] and end <= wm:
                meta["fired"] = True            # crossed with nothing live
            elif meta["fired"] and self.allowed_lateness > 0 \
                    and end + self.allowed_lateness < wm:
                # horizon purge stays one advance behind the fire so FIRE
                # messages scheduled above are never raced by their purge
                for base in list(meta["keys"]):
                    self._purge_pane(sub, WindowKey(base, wid))
        if fire_batch:
            self.deliver_batch(sub, fire_batch)

    def _purge_pane(self, sub: int, wk: WindowKey) -> None:
        self.caches[sub].drop(wk)
        self.backends[sub].delete(wk)
        self.panes_purged += 1
        meta = self.windows[sub].get(wk.wid)
        if meta is not None:
            meta["keys"].discard(wk.base)
            meta["fired_keys"].discard(wk.base)
            if not meta["keys"] and meta["fired"]:
                self.windows[sub].pop(wk.wid, None)

    # ----------------------------------------------------- purge/I-O races
    def _completion_dead(self, sub: int, req: _IOReq) -> bool:
        """A fetch or write-back completing for a pane that was PURGED
        while it was in flight must be dropped, not resurrect dead state
        in cache or backend.  A hint legitimately runs ahead of the first
        data tuple, so an unregistered pane only counts as dead once its
        window is past the lateness horizon."""
        wk = req.key
        if not isinstance(wk, WindowKey):
            return False
        meta = self.windows[sub].get(wk.wid)
        if meta is None:
            return self.assigner.end(wk.wid) + self.allowed_lateness \
                < self.wm[sub]
        return meta["fired"] and wk.base not in meta["keys"]

    def _on_dead_parked(self, sub: int, tup: Tuple_) -> None:
        if tup.payload is FIRE:
            # a FIRE that parked on a fetch and outlived the lateness
            # horizon: the pane is purged, its result unrecoverable —
            # record the loss instead of dropping it silently
            self.fires_lost += 1
        else:
            self.late_dropped += 1

    # ------------------------------------------------------------- migration
    def migrate_shard(self, shard: int, dst_sub: int) -> None:
        """Panes migrate with their shard (§9); the per-window live-key
        registrations must follow so fires happen at the new owner."""
        plane = self.shards
        src = plane.owner[shard] if plane is not None else None
        super().migrate_shard(shard, dst_sub)
        if plane is None or src is None or src == dst_sub:
            return
        for wid, meta in list(self.windows[src].items()):
            moving = {b for b in meta["keys"]
                      if plane.shard_of(b) == shard}
            if not moving:
                continue
            meta["keys"] -= moving
            dmeta = self.windows[dst_sub].get(wid)
            if dmeta is None:
                # the destination's OWN watermark decides when this
                # window counts as fired there; per-key fired state rides
                # along so the merge neither refires panes whose FIRE was
                # already scheduled at the source nor suppresses unfired
                # ones landing in a window the destination already fired
                dmeta = {"keys": set(), "fired": False,
                         "fired_keys": set()}
                self.windows[dst_sub][wid] = dmeta
            dmeta["keys"] |= moving
            dmeta["fired_keys"] |= moving & meta["fired_keys"]
            meta["fired_keys"] -= moving
            if not meta["keys"]:
                del self.windows[src][wid]

    # ---------------------------------------------------- snapshot / restore
    def snapshot_extra(self, sub: int) -> Dict[str, Any]:
        """The per-window live-key/fired registry rides the snapshot
        (DESIGN.md §7): restored panes must know which windows already
        fired (their replayed stragglers take the late path, §10) and
        which keys still await a FIRE."""
        import copy
        out = super().snapshot_extra(sub) or {}
        out["windows"] = copy.deepcopy(self.windows[sub])
        return out

    def restore_extra(self, sub: int, extra: Optional[dict]) -> None:
        super().restore_extra(sub, extra)
        if extra and "windows" in extra:
            self.windows[sub] = extra["windows"]

    def _snapshot_inflight(self, sub: int) -> List[Any]:
        """Pending FIRE messages join the in-flight capture: a FIRE
        scheduled by a pre-barrier watermark but not yet applied at the
        cut has already marked its key fired in the registry — without
        re-delivery the restored window would never emit (§10 ∩ §7)."""
        out = super()._snapshot_inflight(sub)
        out.extend(t for t in self.queues[sub]
                   if isinstance(t, Tuple_) and t.payload is FIRE)
        return out

    def reset_volatile(self) -> None:
        super().reset_volatile()
        self.windows = [dict() for _ in range(self.parallelism)]

    # --------------------------------------------------------------- metrics
    def extra_metrics(self) -> Dict[str, Any]:
        return {"fires": self.fires, "fires_lost": self.fires_lost,
                "late_dropped": self.late_dropped,
                "late_updates": self.late_updates,
                "panes_purged": self.panes_purged,
                "live_windows": sum(len(w) for w in self.windows)}


class WindowedLookaheadOp(MapOp):
    """Windowed Hint Extractor (DESIGN.md §10; the two-sided join
    lookahead of §11 subclasses this, reusing the pane-deadline and
    burst machinery for windowed joins).

    Per tuple: one hint per target pane, keyed ``WindowKey(key, wid)``.
    ``hint_ts_mode`` picks the hint's access-timestamp semantics:

      * ``deadline`` — the window-fire deadline (window end).  The TAC
        then holds live panes until they fire (a renew bumps a cached
        pane to its deadline) and the fire-time read hits.
      * ``arrival`` — the tuple's event timestamp (the per-tuple-hint
        semantics of non-windowed lookaheads; the ablation baseline —
        accurate in key, mistimed for fire-time reads).

    In ``deadline`` mode the operator also tracks the live key set per
    window and, when its watermark reaches ``end - burst_ahead``,
    burst-emits deadline hints for every live pane of that window —
    pre-staging evicted panes right before the downstream fire
    (CMS suppression is bypassed: the burst IS the timeliness path).
    """

    def __init__(self, engine, name, parallelism, assigner: WindowAssigner,
                 key_of: Callable, fn=None, hint_ts_mode: str = "deadline",
                 burst_ahead: float = 0.0, allowed_lateness: float = 0.0,
                 service_time: float = 10e-6, cms_conf: Optional[dict] = None,
                 filter_conf: Optional[dict] = None):
        if hint_ts_mode not in ("deadline", "arrival"):
            raise ValueError(f"hint_ts_mode {hint_ts_mode!r}")
        super().__init__(engine, name, parallelism, fn=fn,
                         service_time=service_time, key_of=key_of,
                         cms_conf=cms_conf, filter_conf=filter_conf)
        self.assigner = assigner
        self.hint_ts_mode = hint_ts_mode
        self.burst_ahead = burst_ahead
        self.allowed_lateness = float(allowed_lateness)
        self.win_keys: List[Dict[int, Set]] = \
            [dict() for _ in range(parallelism)]
        self._burst_done: List[Set[int]] = \
            [set() for _ in range(parallelism)]
        self.burst_hints = 0

    def _emit_hints_for(self, sub: int, o: Tuple_) -> float:
        # MapOp.process hook: one hint per target pane instead of one
        # per tuple
        base = self.key_of(o)
        if base is None:
            return 0.0
        return self._hint_panes(sub, base, o.ts)

    def _hint_panes(self, sub: int, base: Any, ts: float) -> float:
        svc = 0.0
        wm = self.wm[sub]
        deadline = self.hint_ts_mode == "deadline"
        for wid in self.assigner.assign(ts):
            end = self.assigner.end(wid)
            if end + self.allowed_lateness < wm:
                continue                   # late: dropped downstream anyway
            wk = WindowKey(base, wid)
            svc += HINT_COST
            # the pane key is hinted; the BASE key carries the frequency
            # (stable across panes — a pane key is new every window, so
            # counting it would never see a selective filter's cold/hot
            # signal).  "hot" mode ignores freq_key (legacy semantics).
            if self._admit(sub, wk, freq_key=base):
                self.emit_hint(sub, Hint(wk, end if deadline else ts,
                                         origin=self.name))
            if deadline:
                self.win_keys[sub].setdefault(wid, set()).add(base)
        return svc

    def on_watermark(self, sub: int, wm: float) -> None:
        if self.hint_ts_mode != "deadline":
            return
        horizon = wm + self.burst_ahead
        for wid in sorted(self.win_keys[sub]):
            end = self.assigner.end(wid)
            if end + self.allowed_lateness < wm:
                # window closed downstream: forget it
                del self.win_keys[sub][wid]
                self._burst_done[sub].discard(wid)
            elif end <= horizon and wid not in self._burst_done[sub] \
                    and self.hint_active:
                self._burst_done[sub].add(wid)
                filt = self.filters[sub]
                nxt = wid + 1
                nxt_end = self.assigner.end(nxt)
                for base in self.win_keys[sub][wid]:
                    self.burst_hints += 1
                    self.emit_hint(sub, Hint(WindowKey(base, wid), end,
                                             origin=self.name))
                    # speculative next-pane pre-hint (DESIGN.md §13): a
                    # base hot in THIS window is likely live in the next
                    # one — hint its next pane now, at watermark advance,
                    # before any of its tuples arrive.  note_emit marks
                    # it resident so the data-driven hint that follows is
                    # suppressed as a correct duplicate.  The pane is NOT
                    # added to win_keys: if no tuple ever materialises
                    # it, there is nothing to burst later.
                    if filt.speculate_ok(base):
                        self.speculative_hints += 1
                        wk_next = WindowKey(base, nxt)
                        filt.note_emit(wk_next, self.sim.t)
                        self.emit_hint(sub, Hint(wk_next, nxt_end,
                                                 origin=self.name))

    def reset_volatile(self) -> None:
        # live-key tracking and burst bookkeeping are process-local soft
        # state: replayed tuples rebuild them (DESIGN.md §7)
        super().reset_volatile()
        self.win_keys = [dict() for _ in range(self.parallelism)]
        self._burst_done = [set() for _ in range(self.parallelism)]

    def extra_metrics(self) -> Dict[str, Any]:
        out = super().extra_metrics()
        out.update({"burst_hints": self.burst_hints,
                    "tracked_windows": sum(len(w) for w in self.win_keys)})
        return out
