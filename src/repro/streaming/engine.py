"""Tuple-at-a-time dataflow engine with a discrete-event clock.

Every policy data structure (TAC/LRU/Clock caches, CMS filter, hints buffer,
prefetch controller/manager) is the real implementation; the engine
simulates only TIME: operator service times, network buffering (size/timeout
flush like Flink's network stack), and state-backend latency with bounded
I/O parallelism.  This is how the paper's latency experiments are reproduced
deterministically on one CPU (DESIGN.md §2).
"""
from __future__ import annotations

import heapq
import itertools
from collections import defaultdict, deque

import numpy as np
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.hint_filter import HintFilter
from repro.core.policies import ClockCache, LRUCache
from repro.core.prefetch import (LookaheadCandidate, PrefetchingController,
                                 PrefetchingManager)
from repro.core.tac import TimestampAwareCache
from repro.obs import (HealthMonitor, MetricsRegistry, PrefetchRecorder,
                       QuantileSketch, Timeline, Tracer)
from repro.runtime.compression import hint_batch_nbytes
from repro.streaming.backend import BackendModel, StateBackend
from repro.streaming.fused import FusedPlane, FusedSpec, Lane
from repro.streaming.events import (CheckpointBarrier, Hint, Marker,
                                    Tuple_, Watermark)
from repro.streaming.shards import (MIGRATE_BANDWIDTH, MIGRATE_RTT,
                                    ShardPlane, hash_partition)

# calibrated engine constants (documented in DESIGN.md §8)
NET_LATENCY = 150e-6              # per flushed buffer hop
NET_PER_MSG = 0.1e-6
FLUSH_OVERHEAD = 5e-6
BUFFER_BYTES = 8 * 1024           # Flink network buffer (low-latency gear)
BUFFER_TIMEOUT = 0.030            # 30 ms (paper §VI-e)
IO_ISSUE = 1.5e-6
HINT_COST = 0.5e-6                # extract + CMS update
HINT_TIMEOUT = 0.2e-3               # hint side channel flushes aggressively:
#                                   hints are tiny and latency-critical
ASYNC_RESUME = 4e-6               # async I/O completion handling per tuple
#                                   (paper §VI-A: thread/completion overheads)
FUSED_LAUNCH = 4e-6               # one fused device-program dispatch (§14)
FUSED_LANE = 0.3e-6               # per-lane share of a fused batch: the
#                                   interpreter's ~3µs/tuple collapses to
#                                   the kernel's per-element cost


class Sim:
    def __init__(self):
        self.t = 0.0
        self._heap: List = []
        self._seq = itertools.count()

    def at(self, t: float, fn: Callable, *args) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    def after(self, delay: float, fn: Callable, *args) -> None:
        self.at(self.t + delay, fn, *args)

    def run_until(self, t_end: float) -> None:
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn, args = heapq.heappop(self._heap)
            self.t = t
            fn(*args)
        self.t = max(self.t, t_end)

    def purge(self, pred: Callable[[Tuple], bool]) -> int:
        """Drop scheduled events matching ``pred((t, seq, fn, args))`` —
        the failure-injection path (DESIGN.md §7) uses this to kill the
        dead incarnation's pending callbacks (service completions, I/O
        completions, source ticks) so they cannot fire into the restored
        state."""
        kept = [ev for ev in self._heap if not pred(ev)]
        n = len(self._heap) - len(kept)
        heapq.heapify(kept)
        self._heap = kept
        return n


class Channel:
    """One src_op -> dst_op edge with per-(src,dst)-subtask network buffers.

    Implements the Flink-style network stack of DESIGN.md §2: records
    accumulate in an 8 KiB buffer per subtask pair and flush on size or
    timeout (constants in §8).  ``kind`` distinguishes the data edge from
    the hint side channel (§3), which flushes on the much shorter
    ``HINT_TIMEOUT`` because hints are tiny and latency-critical.  The
    ``partition`` function picks the destination subtask per key — by
    default ``hash_partition``, or a ``ShardPlane`` router when the
    destination operator runs the sharded state plane (§9).  Control
    messages (markers, barriers) broadcast and flush immediately so they
    never reorder behind buffered records.
    """

    _ids = itertools.count()

    def __init__(self, sim: Sim, dst_op: "Operator", kind: str,
                 partition: Callable[[Any, int], int],
                 n_src: int, timeout: float = BUFFER_TIMEOUT,
                 codec: Optional[str] = None):
        self.sim = sim
        self.chan_id = next(Channel._ids)
        self.dst = dst_op
        self.kind = kind                  # data | hint
        self.partition = partition
        self.timeout = timeout
        # "delta" = per-flush delta compression of sorted key batches
        # (runtime/compression.py, DESIGN.md §13).  Affects byte
        # ACCOUNTING only: flush thresholds and the delay model keep
        # operating on raw sizes, so enabling the codec never perturbs
        # latency semantics — bytes_sent vs bytes_raw shows the saving.
        self.codec = codec
        # chaos hook (streaming/chaos.py, DESIGN.md §15): a fault
        # schedule may attach a ChannelChaos here to drop hints at send
        # time or stretch flush delays.  None (the default) keeps the
        # hot path to one attribute check; the FIFO arrival clamp below
        # makes any added delay ordering-safe.
        self.chaos = None
        self.bufs: Dict[Tuple[int, int], List] = defaultdict(list)
        self.buf_bytes: Dict[Tuple[int, int], int] = defaultdict(int)
        self.flush_scheduled: Dict[Tuple[int, int], bool] = defaultdict(bool)
        self.last_arrival: Dict[Tuple[int, int], float] = defaultdict(float)
        self.bytes_sent = 0
        self.bytes_raw = 0
        self.msgs_sent = 0

    def send(self, src_sub: int, msg: Any) -> None:
        if isinstance(msg, CheckpointBarrier):
            # barriers broadcast and flush like markers, but are tagged
            # with the (channel, src subtask) input they travelled on so
            # the destination can ALIGN across all its inputs (DESIGN.md
            # §7); flushing keeps each copy ordered behind the pre-barrier
            # records it covers
            for d in range(self.dst.parallelism):
                self.bufs[(src_sub, d)].append(
                    CheckpointBarrier(msg.checkpoint_id,
                                      origin=(self.chan_id, src_sub)))
                self._flush(src_sub, d)
            return
        if isinstance(msg, Marker):
            # control messages are broadcast and flush the buffer (order!)
            for d in range(self.dst.parallelism):
                self.bufs[(src_sub, d)].append(msg)
                self._flush(src_sub, d)
            return
        if isinstance(msg, Watermark):
            # watermarks broadcast like markers, tagged with the (channel,
            # src subtask) input they travelled on so the destination can
            # take the min across ALL its inputs (DESIGN.md §10); flushing
            # keeps them ordered behind the records they cover
            for d in range(self.dst.parallelism):
                self.bufs[(src_sub, d)].append(
                    Watermark(msg.ts, origin=(self.chan_id, src_sub)))
                self._flush(src_sub, d)
            return
        if self.chaos is not None and isinstance(msg, Hint) \
                and self.chaos.drop(msg):
            return                        # hint lost in transit (§15)
        key = getattr(msg, "key", None)
        d = self.partition(key, self.dst.parallelism)
        slot = (src_sub, d)
        self.bufs[slot].append(msg)
        self.buf_bytes[slot] += getattr(msg, "size", 64)
        if self.buf_bytes[slot] >= BUFFER_BYTES:
            self._flush(src_sub, d)
        elif not self.flush_scheduled[slot]:
            self.flush_scheduled[slot] = True
            self.sim.after(self.timeout, self._timeout_flush, src_sub, d)

    def _timeout_flush(self, s: int, d: int) -> None:
        self.flush_scheduled[(s, d)] = False
        if self.bufs[(s, d)]:
            self._flush(s, d)

    def _flush(self, s: int, d: int) -> None:
        batch = self.bufs[(s, d)]
        if not batch:
            return
        self.bufs[(s, d)] = []
        nbytes = self.buf_bytes[(s, d)]
        self.buf_bytes[(s, d)] = 0
        raw = nbytes + 8 * len(batch)
        self.bytes_raw += raw
        self.bytes_sent += self._wire_bytes(batch, raw)
        self.msgs_sent += len(batch)
        delay = NET_LATENCY + NET_PER_MSG * len(batch)
        if self.chaos is not None:
            delay += self.chaos.delay()
        # the per-message term makes a small batch faster than a LARGE
        # batch flushed just before it; a TCP-like channel never reorders,
        # so clamp arrival to per-(src,dst)-pair FIFO — watermarks and
        # checkpoint barriers (§7, §10) rely on never overtaking the
        # records they cover
        arrive = max(self.sim.t + delay, self.last_arrival[(s, d)])
        self.last_arrival[(s, d)] = arrive
        self.sim.at(arrive, self.dst.deliver_batch, d, batch,
                    (self.chan_id, s))

    def _wire_bytes(self, batch: List, raw: int) -> int:
        """Bytes this flush puts on the wire.  With the delta codec, the
        batch's hint keys ship as sorted delta streams plus an f32
        access timestamp each (``hint_batch_nbytes``); control messages
        and anything else keep their raw size."""
        if self.codec is None:
            return raw
        hint_keys = [m.key for m in batch if isinstance(m, Hint)]
        if not hint_keys:
            return raw
        other = sum(getattr(m, "size", 64) + 8 for m in batch
                    if not isinstance(m, Hint))
        return hint_batch_nbytes(hint_keys) + other


# hash_partition lives in repro.streaming.shards (one canonical definition
# shared with the shard plane); re-exported here for existing callers.


class Operator:
    """Base dataflow operator (DESIGN.md §2).

    Each of ``parallelism`` subtasks pulls ONE message at a time from its
    input queue; ``handle`` returns the service time the discrete-event
    clock charges before the subtask takes the next message, so queueing
    delay emerges from the simulation rather than being modelled.  Parked
    messages resume through the higher-priority ``ready`` queue.  ``emit``
    fans out to every data edge, ``emit_hint`` to every hint side channel
    (§3); each channel routes per key.
    """

    def __init__(self, engine: "Engine", name: str, parallelism: int,
                 service_time: float = 2e-6):
        self.engine = engine
        self.sim = engine.sim
        self.name = name
        self.parallelism = parallelism
        self.service_time = service_time
        self.queues: List[deque] = [deque() for _ in range(parallelism)]
        self.ready: List[deque] = [deque() for _ in range(parallelism)]
        self.busy = [False] * parallelism
        self.busy_time = [0.0] * parallelism
        self.out_data: List[Channel] = []
        self.out_hint: List[Channel] = []
        self.plan_pos = 0
        self.processed = 0
        self._barrier_seen = set()
        # barrier alignment state (DESIGN.md §7): per-subtask active
        # alignment {epoch, arrived origins, buffered post-barrier msgs,
        # t0}; barrier_expected counts data-edge (channel, src subtask)
        # inputs, maintained by Engine.connect alongside wm_expected
        self._align: List[Optional[dict]] = [None] * parallelism
        self.barrier_expected = 0
        # event-time watermark state (DESIGN.md §10): per-subtask current
        # watermark, last value seen per input (channel, src subtask), and
        # the number of inputs that must report before the min is valid
        # (set by Engine.connect as data edges are wired)
        self.wm = [float("-inf")] * parallelism
        self._wm_in: List[Dict[Any, float]] = \
            [dict() for _ in range(parallelism)]
        self.wm_expected = 0

    def extra_metrics(self) -> Dict[str, Any]:
        """Operator-specific counters surfaced by ``Engine.metrics``
        under ``{name}_{key}``; subclasses extend via ``super()``."""
        return {}

    # ------------------------------------------------------------- plumbing
    def deliver_batch(self, sub: int, batch: List[Any],
                      origin: Any = None) -> None:
        """``origin`` identifies the (channel, src subtask) a network
        batch travelled on; engine-internal deliveries (self-addressed
        FIRE messages, shard forwarding, migration replay, recovery
        re-delivery) pass None and bypass barrier alignment."""
        if origin is not None and self.barrier_expected > 0 \
                and self.engine.barriers_active and (
                self._align[sub] is not None
                or any(isinstance(m, CheckpointBarrier) for m in batch)):
            # only pay the filter when checkpointing is in use AND an
            # alignment is open or a barrier is arriving — the common
            # no-checkpoint batch passes through untouched
            batch = self._align_filter(sub, batch, origin)
        if batch:
            self.queues[sub].extend(batch)
            self._kick(sub)

    def _align_filter(self, sub: int, batch: List[Any],
                      origin: Any) -> List[Any]:
        """Aligned-barrier protocol (DESIGN.md §7), run at delivery time.

        The first barrier copy of an epoch opens an alignment: from then
        on, messages from inputs whose barrier already arrived are
        POST-barrier and get buffered.  When the last expected input
        reports, an ``_AlignedBarrier`` sentinel is enqueued (behind all
        pre-barrier messages — channels are FIFO, so everything still in
        the queue is pre-barrier) followed by the buffered traffic.  One
        epoch aligns at a time; the coordinator never overlaps epochs."""
        out = []
        for msg in batch:
            al = self._align[sub]
            if isinstance(msg, CheckpointBarrier):
                if al is None:
                    al = self._align[sub] = {
                        "epoch": msg.checkpoint_id, "arrived": set(),
                        "buffer": [], "t0": self.sim.t}
                if origin in al["arrived"] \
                        or msg.checkpoint_id != al["epoch"]:
                    if origin in al["arrived"]:
                        # a NEWER epoch's barrier from an already-aligned
                        # input is post-barrier traffic: buffer it, and
                        # the reprocessing below opens its alignment once
                        # the current epoch completes (overlapping
                        # triggers must not wedge the subtask)
                        al["buffer"].append((origin, msg))
                    continue              # else: stale copy, drop
                al["arrived"].add(origin)
                if len(al["arrived"]) >= self.barrier_expected:
                    out.append(_AlignedBarrier(
                        al["epoch"], self.sim.t - al["t0"],
                        len(al["buffer"])))
                    buffered = al["buffer"]
                    self._align[sub] = None
                    # buffered traffic re-enters the filter: it may carry
                    # the NEXT epoch's barriers
                    for o, m in buffered:
                        out.extend(self._align_filter(sub, [m], o))
            elif al is not None and origin in al["arrived"]:
                al["buffer"].append((origin, msg))
            else:
                out.append(msg)
        return out

    def _kick(self, sub: int) -> None:
        if not self.busy[sub] and (self.ready[sub] or self.queues[sub]):
            self._start(sub)

    def _start(self, sub: int) -> None:
        if self.busy[sub]:
            return
        q = self.ready[sub] if self.ready[sub] else self.queues[sub]
        if not q:
            return
        msg = q.popleft()
        self.busy[sub] = True
        svc = self.handle(sub, msg)
        if svc is None:
            svc = self.service_time
        self.busy_time[sub] += svc
        self.sim.after(svc, self._finish, sub)

    def _finish(self, sub: int) -> None:
        self.busy[sub] = False
        self._kick(sub)

    def emit(self, sub: int, msg: Any) -> None:
        for ch in self.out_data:
            ch.send(sub, msg)

    def emit_hint(self, sub: int, msg: Any) -> None:
        for ch in self.out_hint:
            ch.send(sub, msg)

    # ----------------------------------------------------------- watermarks
    def _recv_watermark(self, sub: int, w: Watermark) -> None:
        """Min-of-inputs watermark propagation (DESIGN.md §10): the
        subtask's watermark advances only once every input (channel, src
        subtask) pair has reported, and then to the minimum across them."""
        cur = self._wm_in[sub].get(w.origin, float("-inf"))
        if w.ts > cur:
            self._wm_in[sub][w.origin] = w.ts
        if len(self._wm_in[sub]) < self.wm_expected:
            return
        new = min(self._wm_in[sub].values())
        if new > self.wm[sub]:
            self.wm[sub] = new
            self.on_watermark(sub, new)
            self.emit_watermark(sub, new)

    def on_watermark(self, sub: int, wm: float) -> None:
        """Hook: the subtask's event-time watermark advanced to ``wm``."""

    def emit_watermark(self, sub: int, wm: float) -> None:
        for ch in self.out_data:
            ch.send(sub, Watermark(wm))

    # ------------------------------------------------------------ behaviour
    def handle(self, sub: int, msg: Any) -> Optional[float]:
        if isinstance(msg, Watermark):
            self._recv_watermark(sub, msg)
            return 2e-7
        if isinstance(msg, Marker):
            self.on_marker(sub, msg)
            return 1e-7
        if isinstance(msg, _AlignedBarrier):
            return self._on_aligned_barrier(sub, msg)
        if isinstance(msg, CheckpointBarrier):
            # barriers normally complete at delivery time (_align_filter);
            # a barrier reaching handle() was injected without channel
            # origin — treat it as a single-input alignment
            if (msg.checkpoint_id, sub) in self._barrier_seen:
                return 1e-7
            self._barrier_seen.add((msg.checkpoint_id, sub))
            return self._on_aligned_barrier(
                sub, _AlignedBarrier(msg.checkpoint_id, 0.0, 0))
        self.processed += 1
        return self.process(sub, msg)

    # ----------------------------------------------------------- checkpoint
    def _on_aligned_barrier(self, sub: int, ab: _AlignedBarrier) -> float:
        """The subtask reached the epoch's consistent cut (DESIGN.md §7):
        snapshot local state, report to the engine/coordinator, forward
        the barrier downstream."""
        payload = self.snapshot_state(sub, ab.epoch)
        self.engine.on_snapshot(ab.epoch, self.name, sub, payload,
                                ab.stall, ab.buffered)
        self.emit(sub, CheckpointBarrier(ab.epoch))
        if payload is not None:
            return 1e-6 * max(1, payload.get("n_flushed", 0))
        return 1e-7

    def snapshot_state(self, sub: int, epoch: int) -> Optional[dict]:
        """Hook: return this subtask's durable snapshot payload (None for
        stateless operators — they only align and forward).  Stateless
        soft state (CMS counters, adaptation statistics) is deliberately
        NOT snapshotted: a recorded deviation, see DESIGN.md §7."""
        return None

    def restore_extra(self, sub: int, extra: Optional[dict]) -> None:
        """Hook: re-install operator-specific registries from a snapshot
        payload's ``extra`` block (window registries §10, join retention
        §11, shard-plane ownership §9)."""

    def reset_volatile(self) -> None:
        """Failure handling (DESIGN.md §7): discard everything a process
        crash would lose — queues, watermark state, alignment state.
        Subclasses drop caches, I/O lanes, and parked work on top."""
        for s in range(self.parallelism):
            self.queues[s].clear()
            self.ready[s].clear()
            self.busy[s] = False
        self.wm = [float("-inf")] * self.parallelism
        self._wm_in = [dict() for _ in range(self.parallelism)]
        self._align = [None] * self.parallelism
        self._barrier_seen.clear()

    def on_marker(self, sub: int, m: Marker) -> None:
        self.emit(sub, m)

    def process(self, sub: int, tup: Tuple_) -> Optional[float]:
        self.emit(sub, tup)
        return self.service_time


class MapOp(Operator):
    """Stateless transform; optionally a lookahead (Hint Extractor inside)."""

    def __init__(self, engine, name, parallelism, fn=None,
                 service_time=2e-6, key_of: Optional[Callable] = None,
                 cms_conf: Optional[dict] = None,
                 filter_conf: Optional[dict] = None):
        super().__init__(engine, name, parallelism, service_time)
        self.fn = fn
        self.key_of = key_of               # state-access key extractor
        self.hint_active = False
        if key_of is not None:
            # hint admission (DESIGN.md §13); cms_conf stays a separate
            # kwarg for existing callers and folds into the filter
            conf = dict(filter_conf or {})
            conf.setdefault("cms_conf", cms_conf)
            self.filters: Optional[List[HintFilter]] = [
                HintFilter(**conf) for _ in range(parallelism)]
        else:
            self.filters = None
        # bound by Engine.register_prefetching: the downstream stateful
        # operator's PrefetchRecorder, so suppression verdicts can be
        # graded against what the cache actually did next (§13)
        self.sink_recorder = None
        self.hints_emitted = 0
        self.hints_suppressed = 0
        self.speculative_hints = 0

    @property
    def cms(self):
        """Per-subtask CMS sketches (compat view over the filters)."""
        return [f.cms for f in self.filters] if self.filters else None

    def _admit(self, sub: int, key, freq_key=None) -> bool:
        """Run one hint through the subtask's HintFilter; True = emit.
        Suppressions report to the sink recorder for retroactive
        grading."""
        if self.filters[sub].admit(key, self.sim.t, freq_key):
            self.hints_emitted += 1
            return True
        self.hints_suppressed += 1
        if self.sink_recorder is not None:
            self.sink_recorder.on_suppressed(key)
        return False

    def on_marker(self, sub: int, m: Marker) -> None:
        # side-channel copy first: the hint path must never trail the data
        # copy of the same marker or slack would be measured against the
        # NEXT round's marker
        if self.key_of is not None:
            self.emit_hint(sub, Marker(m.marker_id, lookahead_id=self.name))
        self.emit(sub, m)

    def reset_volatile(self) -> None:
        super().reset_volatile()
        if self.filters is not None:
            # filter state (CMS counters, residency map, budget) is
            # process-local soft state: a crash loses it and admission
            # re-learns (DESIGN.md §7)
            for f in self.filters:
                f.reset()

    def _emit_hints_for(self, sub: int, o: Tuple_) -> float:
        """Hint Extractor for one output tuple; returns the extraction
        cost.  The windowed lookahead (streaming/windows.py) overrides
        this single hook to emit per-pane deadline hints."""
        k = self.key_of(o)
        if k is None:
            return 0.0
        if self._admit(sub, k):
            self.emit_hint(sub, Hint(k, o.ts, origin=self.name,
                                     emit_t=self.sim.t))
        return HINT_COST

    def extra_metrics(self) -> Dict[str, Any]:
        out = super().extra_metrics()
        if self.filters:
            agg: Dict[str, int] = {}
            for f in self.filters:
                for k, v in f.counters.items():
                    agg[k] = agg.get(k, 0) + v
            out["hint_filter"] = {"mode": self.filters[0].mode, **agg}
            out["speculative_hints"] = self.speculative_hints
        return out

    def process(self, sub: int, tup: Tuple_) -> Optional[float]:
        out = self.fn(tup) if self.fn else tup
        svc = self.service_time
        if out is None:
            return svc
        outs = out if isinstance(out, list) else [out]
        for o in outs:
            if tup.trace is not None and o.trace is None:
                o.trace = tup.trace        # sampled span rides derived tuples
            if self.hint_active and self.key_of is not None:
                svc += self._emit_hints_for(sub, o)
            self.emit(sub, o)
        return svc


class SourceOp(Operator):
    """Rate-driven source; generator yields (key, payload, size) or
    (key, payload, size, event_ts) for out-of-order event time.

    With ``watermark_interval`` > 0 the source runs a bounded-out-of-
    orderness watermark generator (DESIGN.md §10): every interval it
    emits ``Watermark(max emitted event ts - oo_bound)`` on its data
    edges — the promise that no tuple more than ``oo_bound`` behind the
    frontier will follow (the generator's late tail beyond the bound is
    exactly what the windowed late-data path handles).

    With ``replayable=True`` the source models a DURABLE LOG in front of
    the pipeline (a Kafka-style topic, DESIGN.md §7): the generator runs
    on a LOGICAL clock (one ``interval`` per record, so the record
    sequence is a pure function of position, independent of processing
    stalls), every record is appended to ``log``, and recovery can
    ``rewind`` a subtask to a checkpointed ``offset`` and replay —
    first draining the log at ``replay_speedup`` x the live rate
    (catch-up), then resuming live generation where the logical clock
    left off.  Event timestamps come from the record (or the logical
    clock), so a replayed stream carries the SAME event times and the
    event-time results are reproducible across a failure.
    """

    def __init__(self, engine, name, parallelism, rate: float, gen,
                 service_time=1e-6, watermark_interval: float = 0.0,
                 oo_bound: float = 0.0, replayable: bool = False):
        super().__init__(engine, name, parallelism, service_time)
        self.rate = rate
        self.gen = gen
        self.stopped = False
        # load-shift knob (streaming/chaos.py, DESIGN.md §15): scales the
        # WALL-CLOCK tick pacing only.  The logical clock still advances
        # one ``interval`` per record, so the record sequence — and with
        # it the durable log and every event timestamp — is identical at
        # any rate_scale; a load shift changes when records ARRIVE, never
        # what they say.
        self.rate_scale = 1.0
        self.watermark_interval = watermark_interval
        self.oo_bound = oo_bound
        self._max_ts = [float("-inf")] * parallelism
        # durable-log state (replayable mode, DESIGN.md §7)
        self.replayable = replayable
        self.log: List[List] = [[] for _ in range(parallelism)]
        self.log_base = [0] * parallelism      # offset of log[sub][0]
        self.replay_pos = [0] * parallelism    # next position to emit
        self.logical_t = [0.0] * parallelism
        self.replay_speedup = 1.0
        self.replayed = 0
        self.replay_done_t = [None] * parallelism
        self._interval = 1.0 / (rate / parallelism)

    def start(self) -> None:
        per = self.rate / self.parallelism
        self._interval = 1.0 / per
        for s in range(self.parallelism):
            self.sim.after(1.0 / per * (s + 1) / self.parallelism,
                           self._tick, s, 1.0 / per)
            if self.watermark_interval > 0:
                self.sim.after(self.watermark_interval * (s + 1)
                               / self.parallelism, self._wm_tick, s)

    def _emit_rec(self, sub: int, lt: float, rec) -> None:
        now = self.sim.t
        ts = rec[3] if len(rec) > 3 else (lt if self.replayable else now)
        tup = Tuple_(ts=ts, key=rec[0], payload=rec[1], size=rec[2],
                     ingest_t=now)
        tracer = self.engine.tracer
        if tracer.sample_every:            # span sampling (off by default)
            tup.trace = tracer.maybe_start(now)
        if ts > self._max_ts[sub]:
            self._max_ts[sub] = ts
        self.processed += 1
        self.busy_time[sub] += self.service_time
        self.emit(sub, tup)

    def _tick(self, sub: int, interval: float) -> None:
        if self.stopped:
            return
        if self.replayable:
            end = self.log_base[sub] + len(self.log[sub])
            if self.replay_pos[sub] < end:
                # catch-up: re-emit logged records at replay speed
                lt, rec = self.log[sub][self.replay_pos[sub]
                                        - self.log_base[sub]]
                self.replay_pos[sub] += 1
                self.replayed += 1
                self._emit_rec(sub, lt, rec)
                if self.replay_pos[sub] >= end:
                    self.replay_done_t[sub] = self.sim.t
                self.sim.after(interval / self.replay_speedup,
                               self._tick, sub, interval)
                return
            lt = self.logical_t[sub]
            self.logical_t[sub] = lt + interval
            rec = self.gen(lt)
            if rec is not None:
                self.log[sub].append((lt, rec))
                self.replay_pos[sub] = end + 1
                self._emit_rec(sub, lt, rec)
            self.sim.after(interval / self.rate_scale, self._tick, sub,
                           interval)
            return
        now = self.sim.t
        rec = self.gen(now)
        if rec is not None:
            self._emit_rec(sub, now, rec)
        self.sim.after(interval / self.rate_scale, self._tick, sub, interval)

    def _wm_tick(self, sub: int) -> None:
        if self.stopped:
            return
        if self._max_ts[sub] > float("-inf"):
            wm = self._max_ts[sub] - self.oo_bound
            if wm > self.wm[sub]:
                self.wm[sub] = wm
                self.emit_watermark(sub, wm)
        self.sim.after(self.watermark_interval, self._wm_tick, sub)

    # ------------------------------------------------- durable log / replay
    def offset(self, sub: int) -> int:
        """Checkpointed log position: the next record to emit (everything
        before it is pre-barrier at this source)."""
        return self.replay_pos[sub]

    def trim_log(self, sub: int, offset: int) -> None:
        """Reclaim log records no restore can need (before the last
        COMPLETED epoch's offset)."""
        cut = offset - self.log_base[sub]
        if cut > 0:
            del self.log[sub][:cut]
            self.log_base[sub] = offset

    def rewind(self, sub: int, offset: int) -> None:
        """Recovery (DESIGN.md §7): reset the emit cursor to a
        checkpointed offset.  Watermark state restarts from scratch —
        the replayed stream re-advances it."""
        if offset < self.log_base[sub]:
            raise ValueError(f"offset {offset} already trimmed "
                             f"(base {self.log_base[sub]})")
        self.replay_pos[sub] = offset
        self._max_ts[sub] = float("-inf")
        self.replay_done_t[sub] = None

    def resume(self, replay_speedup: float = 1.0) -> None:
        """Restart ticking after a failure: drain the log at
        ``replay_speedup`` x the live rate, then continue generating."""
        if not self.replayable:
            raise RuntimeError(f"{self.name} is not replayable")
        self.stopped = False
        self.replay_speedup = replay_speedup
        for s in range(self.parallelism):
            self.sim.after(self._interval * (s + 1) / self.parallelism,
                           self._tick, s, self._interval)
            if self.watermark_interval > 0:
                self.sim.after(self.watermark_interval * (s + 1)
                               / self.parallelism, self._wm_tick, s)


@dataclass
class _AlignedBarrier:
    """Engine-internal sentinel enqueued when the LAST expected barrier
    copy of an epoch is delivered to a subtask (DESIGN.md §7).  It sits
    in the input queue behind every pre-barrier message, so by the time
    it is handled all pre-barrier effects are applied — the consistent
    cut at which ``snapshot_state`` runs."""
    epoch: int
    stall: float              # first-to-last barrier-copy delivery time
    buffered: int             # post-barrier messages parked meanwhile


@dataclass
class _IOReq:
    kind: str            # read | prefetch | write
    key: Any
    hint_ts: float = 0.0
    entry: Any = None    # for writes
    origin: str = ""     # lookahead that triggered a prefetch


class StatefulOp(Operator):
    """Keyed stateful operator with pluggable cache policy and access mode.

    Implements the paper's three access modes (DESIGN.md §2): ``sync`` (a
    cache miss blocks the subtask for the full backend fetch), ``async``
    (a miss parks the tuple and the CPU moves on), and ``prefetch`` (async
    + Keyed Prefetching: upstream hints feed the TAC, §3).  Each subtask
    owns a cache, a backend partition, and a PrefetchingManager; I/O runs
    over ``io_workers`` bounded lanes (the state thread pool).

    With ``shards`` set, the operator joins the sharded state plane (§9):
    keyed messages are guarded by shard ownership — a message for a shard
    this subtask no longer owns is forwarded one hop to the owner, and a
    message for a shard whose state is still in transit parks until
    ``migrate_shard``'s re-admission completes.  Prefetch hits are
    additionally counted per shard.
    """

    def __init__(self, engine, name, parallelism, apply_fn,
                 backend_model: BackendModel, cache_capacity: int,
                 policy: str = "lru", mode: str = "sync",
                 io_workers: int = 4, state_size: int = 200,
                 service_time: float = 3e-6, read_only: bool = False,
                 default_state=None, gamma: float = 0.003,
                 miss_threshold: float = 0.0,
                 dense_backend: bool = False,
                 deadline_aware: bool = False,
                 shards: Optional[ShardPlane] = None,
                 fused: Optional[FusedSpec] = None,
                 fused_batch: int = 64):
        super().__init__(engine, name, parallelism, service_time)
        if shards is not None and shards.n_owners != parallelism:
            raise ValueError(f"ShardPlane has {shards.n_owners} owners for "
                             f"parallelism {parallelism}")
        # fused execution mode (DESIGN.md §14): the keyed plane lives on
        # device behind a FusedPlane and runs of data tuples batch into
        # one jitted program; all control-plane paths stay interpreted
        if fused is not None:
            if shards is not None:
                raise ValueError("fused mode runs on the unsharded plane")
            if policy != "tac":
                raise ValueError("fused mode requires policy='tac'")
        self.fused_spec = fused
        self.fused_batch = int(fused_batch)
        self.shards = shards
        self.shard_pending: Dict[int, List[Any]] = {}
        self.apply_fn = apply_fn           # (tup, state) -> (state', outputs)
        self.mode = mode
        self.state_size = state_size
        self.read_only = read_only
        self.policy = policy
        self.cache_capacity = cache_capacity
        self.deadline_aware = deadline_aware
        self.caches = []
        self.backends = []
        self.managers: List[PrefetchingManager] = []
        for s in range(parallelism):
            self.caches.append(self._new_cache())
            self.backends.append(StateBackend(
                backend_model, default_factory=default_state,
                assume_present=dense_backend))
            self.managers.append(PrefetchingManager(
                name, s, engine.controller, gamma=gamma,
                miss_threshold=miss_threshold,
                shared=self.managers[0] if self.managers else None))
        # event-time lateness horizon for hint admission (windowed
        # subclasses widen it); with wm at -inf nothing is ever late
        self.hint_lateness = 0.0
        # prefetch-quality telemetry (DESIGN.md §12): one recorder for
        # all subtasks bridges TAC staged/used/wasted outcomes and the
        # I/O layer's late stagings into the metrics registry
        self.recorder = PrefetchRecorder(engine.registry,
                                         f"engine.{name}",
                                         lambda: engine.sim.t)
        self.access_hist = engine.registry.histogram(
            f"engine.{name}.access.latency")
        self.pf_demand = engine.registry.counter(
            f"engine.{name}.prefetch.demand_fetches")
        self._attach_obs()
        # first-park processing time per key: the "first need" timestamp
        # a late staging's negative lead time is measured against
        self._park_t: List[Dict[Any, float]] = \
            [dict() for _ in range(parallelism)]
        self.io_free = [io_workers] * parallelism
        self.io_q: List[deque] = [deque() for _ in range(parallelism)]
        self.waiting: List[Dict[Any, List[Tuple_]]] = \
            [defaultdict(list) for _ in range(parallelism)]
        self.in_flight: List[set] = [set() for _ in range(parallelism)]
        # memtable semantics for in-flight write-backs (DESIGN.md §3):
        # an entry popped for async write-back stays readable here until
        # its write LANDS — otherwise a concurrent fetch of the same key
        # reads the backend's stale copy and the in-flight updates are
        # lost (a real lost-update race; RocksDB's memtable is exactly
        # this shield)
        self.wb_pending: List[Dict[Any, Any]] = \
            [dict() for _ in range(parallelism)]
        self.io_workers = io_workers
        self.blocked_time = [0.0] * parallelism
        self.outputs = 0
        self.miss_reported = [False] * parallelism
        # hint WAL (DESIGN.md §7): hints are tiny (key + ts), so logging
        # them durably is cheap; on recovery the log for the replay
        # horizon is re-issued through the PrefetchingManager to warm the
        # cold cache before replayed data arrives.  Only populated when a
        # CheckpointCoordinator is attached (the coordinator trims it at
        # each completed epoch).
        self.hint_log: List[List] = [[] for _ in range(parallelism)]

    def _attach_obs(self) -> None:
        """Wire the recorder into every TAC and the access-latency
        histogram into every manager (re-run after reset_volatile
        recreates the caches)."""
        for c in self.caches:
            if isinstance(c, (TimestampAwareCache, FusedPlane)):
                c.recorder = self.recorder
        for m in self.managers:
            m.lat_hist = self.access_hist

    def _new_cache(self):
        if self.fused_spec is not None:
            return FusedPlane(self.cache_capacity,
                              entry_size=self.state_size,
                              spec=self.fused_spec,
                              deadline_aware=self.deadline_aware,
                              batch=self.fused_batch)
        if self.policy == "tac":
            # deadline_aware: window panes carry far-future fire
            # deadlines, where plain min-ts eviction would remove the
            # panes firing next (core/tac.py, DESIGN.md §10)
            return TimestampAwareCache(self.cache_capacity,
                                       deadline_aware=self.deadline_aware)
        if self.policy == "clock":
            return ClockCache(self.cache_capacity)
        return LRUCache(self.cache_capacity)

    # ------------------------------------------------------------- messages
    def handle(self, sub: int, msg: Any) -> Optional[float]:
        if isinstance(msg, Watermark):
            self._recv_watermark(sub, msg)
            return 2e-7
        if self.shards is not None and \
                isinstance(msg, (Hint, Tuple_)) and msg.key is not None:
            routed = self._shard_guard(sub, msg)
            if routed is not None:
                return routed
        if isinstance(msg, Marker):
            if msg.lookahead_id is not None:      # via hint channel
                self.managers[sub].on_marker_hint(msg.marker_id,
                                                  msg.lookahead_id,
                                                  self.sim.t)
            else:
                self.managers[sub].on_marker_data(msg.marker_id, self.sim.t)
                self.emit(sub, msg)
            return 1e-7
        if isinstance(msg, (_AlignedBarrier, CheckpointBarrier)):
            # the aligned-barrier cut, snapshot, and forward live on the
            # base class; snapshot_state below adds the keyed payload
            return Operator.handle(self, sub, msg)
        if isinstance(msg, Hint):
            return self._on_hint(sub, msg)
        self.processed += 1
        return self._on_data(sub, msg)

    # ------------------------------------------------------- sharded plane
    def _shard_guard(self, sub: int, msg: Any) -> Optional[float]:
        """Ownership check for keyed messages on the sharded plane
        (DESIGN.md §9).  Returns the service time when the message was
        intercepted (forwarded or parked), None to process normally."""
        plane = self.shards
        shard = plane.shard_of(msg.key)
        owner = plane.owner[shard]
        if owner != sub:
            # in flight across an ownership flip: one extra hop (Megaphone
            # routes at the new owner; stale deliveries self-correct)
            plane.misroutes += 1
            self.sim.after(NET_LATENCY, self.deliver_batch, owner, [msg])
            return 0.2e-6
        if shard in plane.migrating:
            # state still in transit: park until re-admission, then replay
            plane.parked_in_migration += 1
            self.shard_pending.setdefault(shard, []).append(msg)
            return 0.2e-6
        return None

    def migrate_shard(self, shard: int, dst_sub: int) -> None:
        """Key-range migration (DESIGN.md §9, à la Megaphone): flip
        ownership (new traffic parks at ``dst_sub``), drain the source
        subtask's cache entries and backend partition for the shard, model
        the bulk state transfer, then re-admit at the destination with
        preserved timestamps and replay everything parked."""
        plane = self.shards
        if plane is None:
            raise RuntimeError(f"{self.name} has no ShardPlane")
        if not 0 <= shard < plane.n_shards:
            raise ValueError(f"shard {shard} out of range")
        src = plane.owner[shard]
        if src == dst_sub:
            return
        plane.begin_migration(shard, dst_sub)
        in_shard = lambda k: plane.shard_of(k) == shard
        entries = self.caches[src].export_entries(in_shard)
        # dirty entries whose write-back is STILL IN FLIGHT at the source
        # left the eviction buffer already, so the cache drain missed
        # them — their latest state must ride the migration too, or a
        # fetch at the destination racing the write-back reads the stale
        # backend copy (the cross-subtask face of the memtable race; the
        # in-flight write itself still lands at the destination backend,
        # idempotently, via the owner-directed write in _io_done)
        for key in [k for k in self.wb_pending[src] if in_shard(k)]:
            entries.append(self.wb_pending[src][key])
        # parked tuples whose fetch is still in flight at the source move
        # with the shard; their completions are dropped by the owner guard
        # in _io_done (the destination refetches on replay if needed)
        for key in [k for k in self.waiting[src] if in_shard(k)]:
            self.shard_pending.setdefault(shard, []).extend(
                self.waiting[src].pop(key))
        # likewise tuples already resumed into the ready queue but not yet
        # processed: they would otherwise run at the drained source
        keep = deque()
        for tup in self.ready[src]:
            if in_shard(tup.key):
                self.shard_pending.setdefault(shard, []).append(tup)
            else:
                keep.append(tup)
        self.ready[src] = keep
        # authoritative backend partition moves off the tuple path
        self.backends[dst_sub].import_keys(
            self.backends[src].export_keys(in_shard))
        nbytes = sum(e.size for e in entries)
        delay = MIGRATE_RTT + nbytes / MIGRATE_BANDWIDTH
        mig_id = next(self.engine._event_ids)
        self.engine.log_event("migrate_begin", id=mig_id, op=self.name,
                              shard=shard, src=src, dst=dst_sub,
                              bytes=nbytes)
        self.sim.after(delay, self._finish_migration, shard, dst_sub,
                       entries, mig_id)

    def _finish_migration(self, shard: int, dst_sub: int,
                          entries: List[Any],
                          mig_id: Optional[int] = None) -> None:
        # TAC entries keep their timestamps (a prefetched entry whose
        # hint ts lies in the future stays protected across the move);
        # LRU/Clock entries carry none and re-enter at migration time
        self.caches[dst_sub].import_entries(entries, now_ts=self.sim.t)
        self.shards.last_finish_t = self.sim.t
        self.shards.finish_migration(shard)
        self.engine.log_event("migrate_end", id=mig_id, shard=shard,
                              entries=len(entries))
        pending = self.shard_pending.pop(shard, [])
        if pending:
            self.deliver_batch(dst_sub, pending)

    def _on_hint(self, sub: int, h: Hint) -> float:
        mgr = self.managers[sub]
        if h.emit_t:
            # hint-channel delay: lookahead emit -> operator receive
            self.recorder.on_channel_delay(self.sim.t - h.emit_t)
        if self.engine.coordinator is not None:
            # hint WAL for prefetch-warmed recovery (DESIGN.md §7)
            self.hint_log[sub].append((self.sim.t, h.key, h.ts))
        # hints whose access ts fell behind the lateness horizon target
        # state the operator will drop or has purged (windowed, §10);
        # with no watermarks wm is -inf and the check never fires
        if mgr.on_hint(h.key, h.ts, self.caches[sub],
                       watermark=self.wm[sub],
                       lateness=self.hint_lateness):
            mgr.hints.take(h.key)         # unprocessed -> in-flight
            self._io_enqueue(sub, _IOReq("prefetch", h.key, h.ts,
                                         origin=h.origin))
        return 0.4e-6       # hash probe + buffer insert, no deserialization

    def _on_data(self, sub: int, tup: Tuple_) -> float:
        cache = self.caches[sub]
        tr = tup.trace
        if tr is not None:
            tr.mark_state(self.name, self.sim.t)
        state = cache.lookup(tup.key, tup.ts)
        if state is not None:
            if tr is not None and tr.hit is None:
                tr.hit = True
            if self.recorder.pending_suppressed:
                # grade a pending hint suppression for this key: the key
                # was resident, so the suppression was correct (§13)
                self.recorder.on_access(tup.key, hit=True)
            if self.mode == "prefetch":
                self.managers[sub].prefetch_hits += 1
                if self.shards is not None:
                    self.shards.prefetch_hits[
                        self.shards.shard_of(tup.key)] += 1
            return self._apply(sub, tup, state)
        wb = self.wb_pending[sub].get(tup.key)
        if wb is not None:
            # key's latest state rides an in-flight write-back: a backend
            # fetch would read STALE data — serve from the memtable
            if tr is not None and tr.hit is None:
                tr.hit = True
            if self.recorder.pending_suppressed:
                self.recorder.on_access(tup.key, hit=True)
            cache.insert(tup.key, wb.state, tup.ts, size=self.state_size)
            return self._apply(sub, tup, wb.state)
        # miss
        if tr is not None and tr.hit is None:
            tr.hit = False
        if self.recorder.pending_suppressed:
            # the suppressed hint would have prefetched this key:
            # incorrect suppression (it costs a demand fetch)
            self.recorder.on_access(tup.key, hit=False)
        if self.mode == "prefetch" and not self.managers[sub].enabled:
            la = self.managers[sub].on_cache_misses(self.sim.t)
            if la is not None:
                self.engine.set_lookahead(self.name, la)
        if self.mode == "sync":
            state, lat = self.backends[sub].fetch(tup.key, self.state_size)
            cache.insert(tup.key, state, tup.ts, size=self.state_size)
            self.managers[sub].record_access_latency(lat)
            self.blocked_time[sub] += lat
            self.pf_demand.inc()
            if tr is not None:
                tr.fetch_s += lat
            return lat + self._apply(sub, tup, state)
        # async / prefetch: park the tuple, fetch if not already in flight
        if tr is not None:
            tr.mark_park(self.sim.t)
        if tup.key not in self._park_t[sub]:
            self._park_t[sub][tup.key] = self.sim.t
        self.waiting[sub][tup.key].append(tup)
        if tup.key not in self.in_flight[sub]:
            self.pf_demand.inc()
            self._io_enqueue(sub, _IOReq("read", tup.key, tup.ts),
                             front=True)
        # completed-fetch scanning cost grows with outstanding async ops
        return IO_ISSUE * (1.0 + len(self.in_flight[sub]) / 32.0)

    # ------------------------------------------------------------------- IO
    def _io_enqueue(self, sub: int, req: _IOReq, front: bool = False) -> None:
        if req.kind in ("read", "prefetch"):
            if req.key in self.in_flight[sub]:
                return
            self.in_flight[sub].add(req.key)
        if front:
            self.io_q[sub].appendleft(req)
        else:
            self.io_q[sub].append(req)
        self._io_kick(sub)

    def _io_kick(self, sub: int) -> None:
        cache = self.caches[sub]
        while self.io_free[sub] > 0:
            if self.io_q[sub]:
                req = self.io_q[sub].popleft()
            else:
                wb = cache.pop_writeback()
                if wb is None:
                    return
                req = _IOReq("write", wb.key, entry=wb)
                self.wb_pending[sub][wb.key] = wb
            self.io_free[sub] -= 1
            if req.kind == "write":
                lat = self.backends[sub].latency(self.state_size)
            else:
                _, lat = self.backends[sub].peek_latency(req.key,
                                                         self.state_size)
            self.sim.after(lat, self._io_done, sub, req, lat)

    def _completion_dead(self, sub: int, req: _IOReq) -> bool:
        """Hook: True when the state this completion targets was PURGED
        while the I/O was in flight (fired window panes, §10) — the write
        or insert must not resurrect it.  Base operators never purge."""
        return False

    def _on_dead_parked(self, sub: int, tup: Tuple_) -> None:
        """Hook: a tuple parked on a key whose state was purged mid-fetch
        (windowed subclasses count it as late)."""

    def _io_done(self, sub: int, req: _IOReq, lat: float) -> None:
        self.io_free[sub] += 1
        cache = self.caches[sub]
        mgr = self.managers[sub]
        if req.kind == "write":
            pend = self.wb_pending[sub]
            if pend.get(req.key) is req.entry:
                del pend[req.key]         # memtable entry landed
            # a write-back in flight across a migration must land in the
            # CURRENT owner's partition (the shard's backend entries moved
            # at drain time and this lane still holds the latest state) —
            # unless the state was purged meanwhile (dead panes must not
            # be resurrected in the backend)
            if not self._completion_dead(sub, req):
                dst = sub if self.shards is None \
                    else self.shards.owner_of(req.key)
                self.backends[dst].write(req.key, req.entry.state,
                                         self.state_size)
        elif self.shards is not None and \
                self.shards.owner_of(req.key) != sub:
            # the shard migrated while this fetch was in flight: its cache
            # entries and waiting tuples already moved, so the completion
            # is dropped (the destination refetches on replay if needed)
            mgr.hints.complete(req.key)
            mgr.hints.discard(req.key)
            self.in_flight[sub].discard(req.key)
            self._park_t[sub].pop(req.key, None)
        elif self._completion_dead(sub, req):
            # the pane was purged while this fetch was in flight: drop
            # the completion, and anything parked on it is late
            mgr.hints.complete(req.key)
            mgr.hints.discard(req.key)
            self.in_flight[sub].discard(req.key)
            self._park_t[sub].pop(req.key, None)
            for tup in self.waiting[sub].pop(req.key, []):
                self._on_dead_parked(sub, tup)
        else:
            state, _ = self.backends[sub].fetch(req.key, self.state_size)
            wb = self.wb_pending[sub].get(req.key)
            if wb is not None:
                state = wb.state          # memtable is newer than backend
            hint_ts = mgr.hints.complete(req.key)
            mgr.hints.discard(req.key)    # clear any stale unprocessed entry
            self.in_flight[sub].discard(req.key)
            prefetched = req.kind == "prefetch"
            timely = prefetched and req.key not in self.waiting[sub]
            ts = hint_ts if hint_ts is not None else req.hint_ts
            cache.insert(req.key, state, ts, size=self.state_size,
                         prefetched=timely, origin=req.origin)
            if prefetched:
                self.recorder.on_stage_latency(lat)
                if not timely:
                    # a tuple parked on the key before staging completed:
                    # the hint was accurate but NOT timely — negative
                    # lead time against the first park
                    self.recorder.on_late(
                        self._park_t[sub].get(req.key, self.sim.t))
            if req.kind == "read" or req.key in self.waiting[sub]:
                mgr.record_access_latency(lat)
            # wake parked tuples
            parked = self.waiting[sub].pop(req.key, None)
            self._park_t[sub].pop(req.key, None)
            if parked:
                self.ready[sub].extend(parked)
                self._kick(sub)
        self._io_kick(sub)

    # ------------------------------------------------------------ computing
    def _apply(self, sub: int, tup: Tuple_, state: Any) -> float:
        # CONTRACT (DESIGN.md §7): an apply_fn that mutates state IN
        # PLACE and returns the SAME object skips the dirty-write below.
        # The live run stays consistent (cache and backend share the
        # object), but the key never re-enters a checkpoint delta, so a
        # restore would revert it.  Checkpointed jobs must either return
        # a new object (copy-on-write, as every shipped query does) or
        # write the mutated state back explicitly (as IntervalJoinOp
        # does, joins.py §11).
        new_state, outputs = self.apply_fn(tup, state)
        if not self.read_only and new_state is not state:
            self.caches[sub].write(tup.key, new_state, tup.ts,
                                   size=self.state_size)
            self._io_kick(sub)             # opportunistic write-back
        tr = tup.trace
        if tr is not None:
            tr.mark_apply(self.sim.t)
        for o in outputs:
            self.outputs += 1
            if tr is not None and getattr(o, "trace", None) is None:
                o.trace = tr
            self.emit(sub, o)
        if not outputs:
            self._trace_absorbed(tr)
        return self.service_time

    def _trace_absorbed(self, tr) -> None:
        """Finalize a sampled tuple CONSUMED into operator state with no
        1:1 output (windowed aggregation, unmatched join probe, late
        drop): its critical path ends at apply — a later window fire or
        join match is a different tuple's emission, not the tail of this
        one's span (DESIGN.md §12)."""
        if tr is not None:
            tr.mark_apply(self.sim.t)   # downstream = 0 for absorbed spans
            self.engine.tracer.finish(tr, self.sim.t)

    def handle_parked(self, sub: int, tup: Tuple_) -> float:
        tr = tup.trace
        if tr is not None:
            tr.mark_resume(self.sim.t)
        state = self.caches[sub].lookup(tup.key, tup.ts)
        refetch = 0.0
        if state is None:
            wb = self.wb_pending[sub].get(tup.key)
            if wb is not None:              # memtable shield (see __init__)
                self.caches[sub].insert(tup.key, wb.state, tup.ts,
                                        size=self.state_size)
                return ASYNC_RESUME + self._apply(sub, tup, wb.state)
        if state is None:                   # evicted before processing:
            # the refetch is synchronous on the tuple path, so it is charged
            # at full backend latency (presence-aware, like the sync path)
            state, refetch = self.backends[sub].fetch(tup.key,
                                                      self.state_size)
            self.caches[sub].insert(tup.key, state, tup.ts,
                                    size=self.state_size)
            self.managers[sub].record_access_latency(refetch)
            self.blocked_time[sub] += refetch
            self.pf_demand.inc()
            if tr is not None:
                tr.fetch_s += refetch
        return ASYNC_RESUME + refetch + self._apply(sub, tup, state)

    def _start(self, sub: int) -> None:
        # parked tuples resume through the ready queue with full processing
        if self.busy[sub]:
            return
        if self.ready[sub]:
            tup = self.ready[sub].popleft()
            self.busy[sub] = True
            # resumed tuples bypass handle(), so the shard-ownership guard
            # must run here too (the shard may have migrated in between)
            svc = None
            if self.shards is not None:
                svc = self._shard_guard(sub, tup)
            if svc is None:
                svc = self.handle_parked(sub, tup)
            self.busy_time[sub] += svc
            self.sim.after(svc, self._finish, sub)
            return
        if self.fused_spec is not None and self.queues[sub] \
                and isinstance(self.queues[sub][0], Tuple_):
            # fused hot path (DESIGN.md §14): the head RUN of data tuples
            # becomes one fixed-width device batch; control messages
            # (watermarks, hints, barriers, markers) stay on the
            # interpreted path above and naturally fence batches
            self.busy[sub] = True
            svc = self._fused_drain(sub)
            self.busy_time[sub] += svc
            self.sim.after(svc, self._finish, sub)
            return
        super()._start(sub)

    # ------------------------------------------------------ fused data path
    def _fused_prospect(self, sub: int, tup: Tuple_):
        """PURE preview of the state keys ``tup`` will touch and whether
        it is a window fire — drives the batch conflict check (a fire
        and an update of the same key never share a batch, §14)."""
        return (tup.key,), False

    def _fused_expand(self, sub: int, tup: Tuple_,
                      keys=None) -> List[Lane]:
        """Turn one dequeued tuple into device lanes (``keys`` is the
        prospect's precomputed key tuple, so expansion never redoes the
        window assignment).  Windowed subclasses expand to panes and
        take the late checks here — mirroring their ``_on_data``
        expansion."""
        return [Lane(tup.key, tup.ts, self.fused_spec.weight_raw(tup),
                     False, False, tup)]

    def _fused_fire(self, sub: int, lane: Lane, state: Any) -> None:
        raise RuntimeError("fire lane on a non-windowed operator")

    def _fused_late(self, sub: int, lane: Lane, state: Any) -> None:
        raise RuntimeError("late-update lane on a non-windowed operator")

    def _fused_lane_tuple(self, lane: Lane) -> Tuple_:
        """The tuple a lane parks/applies as: the source tuple itself,
        or (windowed) a pane-keyed copy — identical to the expansion the
        interpreted ``_on_data`` would have built."""
        tup = lane.tup
        if lane.key is tup.key or lane.key == tup.key:
            return tup
        return Tuple_(tup.ts, lane.key, tup.payload, tup.size,
                      tup.ingest_t, trace=tup.trace)

    def _fused_drain(self, sub: int) -> float:
        """Assemble one batch from the head run of data tuples, then run
        it through the device plane (§14).  Assembly stops at the batch
        width, at the first non-data message, or at a fire/update
        conflict (the conflicting tuple waits for the next batch, which
        preserves sequential per-key semantics)."""
        q = self.queues[sub]
        B = self.fused_batch
        lanes: List[Lane] = []
        fire_keys: set = set()
        upd_keys: set = set()
        n_tuples = 0
        while q and isinstance(q[0], Tuple_):
            tup = q[0]
            keys, is_fire = self._fused_prospect(sub, tup)
            fence = upd_keys if is_fire else fire_keys
            if fence and any(k in fence for k in keys):
                break
            if lanes and len(lanes) + len(keys) > B:
                break
            q.popleft()
            n_tuples += 1
            self.processed += 1
            new = self._fused_expand(sub, tup, keys)
            for ln in new:
                (fire_keys if ln.fire else upd_keys).add(ln.key)
            lanes.extend(new)
            if len(lanes) >= B:
                break
        svc = 5e-7 * n_tuples           # dequeue + expand, per tuple
        # a single tuple expanding wider than the batch runs chunked —
        # in-order chunks of one drain preserve per-key sequencing
        for i in range(0, len(lanes), B):
            svc += self._fused_step(sub, lanes[i:i + B])
        return svc

    def _fused_step(self, sub: int, lanes: List[Lane]) -> float:
        """One device batch + host post-step.  Device-HIT lanes finished
        on device (state read/updated/written back in the jitted
        program); every other lane is re-adjudicated IN LANE ORDER
        through the interpreted cold paths (eviction-buffer restores,
        memtable shield, sync refetch or parking) so counters, emits,
        and state stay sequential-equivalent (§14)."""
        plane = self.caches[sub]
        mgr = self.managers[sub]
        spec = self.fused_spec
        n = len(lanes)
        res = plane.batch_step(lanes)
        svc = FUSED_LAUNCH + FUSED_LANE * n
        if self.mode == "prefetch":
            mgr.prefetch_hits += int(res.hit.sum())
        # vectorized fast path: a PLAIN hit lane (update absorbed on
        # device — not a fire, not a late update, no per-lane emits)
        # needs no host work at all unless a trace or the hint-quality
        # recorder is watching.  Only the exceptional lanes get the
        # per-lane branch cascade below.
        lane_idx = range(n)
        if spec.emit_of is None and not self.recorder.pending_suppressed:
            late = np.fromiter((ln.late_update for ln in lanes), bool, n)
            plain = res.hit & ~res.fire & ~late
            if plain.any() and not any(ln.tup.trace is not None
                                       for ln in lanes):
                lane_idx = np.nonzero(~plain)[0].tolist()
        for i in lane_idx:
            ln = lanes[i]
            tup = ln.tup
            tr = tup.trace
            if tr is not None:
                tr.mark_state(self.name, self.sim.t)
            if res.hit[i]:
                if tr is not None and tr.hit is None:
                    tr.hit = True
                if self.recorder.pending_suppressed:
                    self.recorder.on_access(ln.key, hit=True)
                if ln.fire:
                    self._fused_fire(sub, ln, plane.decode_lane(res, i))
                elif ln.late_update:
                    self._fused_late(sub, ln, plane.decode_lane(res, i))
                else:
                    # per-lane emits from the composed post-lane value
                    # (read enrichment, or sum/max specs with emit_of);
                    # no emit_of = the update is absorbed on device
                    outs = spec.emit_of(tup, plane.decode_lane(res, i)) \
                        if spec.emit_of is not None else []
                    if tr is not None:
                        tr.mark_apply(self.sim.t)
                    for o in outs:
                        self.outputs += 1
                        if tr is not None and \
                                getattr(o, "trace", None) is None:
                            o.trace = tr
                        self.emit(sub, o)
                    if not outs:
                        self._trace_absorbed(tr)
                continue
            # ---- non-hit lane: interpreted adjudication, lane order
            ptup = self._fused_lane_tuple(ln)
            state = plane.lookup(ln.key, ln.ts)
            if state is not None:
                # eviction-buffer restore, or a key admitted by an
                # earlier lane's cold path in this very drain
                if tr is not None and tr.hit is None:
                    tr.hit = True
                if self.recorder.pending_suppressed:
                    self.recorder.on_access(ln.key, hit=True)
                if self.mode == "prefetch":
                    mgr.prefetch_hits += 1
                svc += self._apply(sub, ptup, state)
                continue
            wb = self.wb_pending[sub].get(ln.key)
            if wb is not None:
                if tr is not None and tr.hit is None:
                    tr.hit = True
                if self.recorder.pending_suppressed:
                    self.recorder.on_access(ln.key, hit=True)
                plane.insert(ln.key, wb.state, ln.ts,
                             size=self.state_size)
                svc += self._apply(sub, ptup, wb.state)
                continue
            if tr is not None and tr.hit is None:
                tr.hit = False
            if self.recorder.pending_suppressed:
                self.recorder.on_access(ln.key, hit=False)
            if self.mode == "prefetch" and not mgr.enabled:
                la = mgr.on_cache_misses(self.sim.t)
                if la is not None:
                    self.engine.set_lookahead(self.name, la)
            if self.mode == "sync":
                state, lat = self.backends[sub].fetch(ln.key,
                                                      self.state_size)
                plane.insert(ln.key, state, ln.ts, size=self.state_size)
                mgr.record_access_latency(lat)
                self.blocked_time[sub] += lat
                self.pf_demand.inc()
                if tr is not None:
                    tr.fetch_s += lat
                svc += lat + self._apply(sub, ptup, state)
                continue
            if tr is not None:
                tr.mark_park(self.sim.t)
            if ln.key not in self._park_t[sub]:
                self._park_t[sub][ln.key] = self.sim.t
            self.waiting[sub][ln.key].append(ptup)
            if ln.key not in self.in_flight[sub]:
                self.pf_demand.inc()
                self._io_enqueue(sub, _IOReq("read", ln.key, ln.ts),
                                 front=True)
            svc += IO_ISSUE * (1.0 + len(self.in_flight[sub]) / 32.0)
        self._io_kick(sub)          # opportunistic write-back, per batch
        return svc

    def periodic_evaluate(self) -> None:
        mgr = self.managers[0]
        if not any(m.enabled for m in self.managers):
            return
        mgr.enabled = True
        new = mgr.evaluate(self.caches, self.sim.t)
        if new is not None:
            self.engine.set_lookahead(self.name, new)

    # ---------------------------------------------------- snapshot / restore
    def snapshot_state(self, sub: int, epoch: int) -> dict:
        """Barrier-time snapshot of this subtask's durable state
        (DESIGN.md §7).  Three parts:

          * TAC dirty drain (paper §IV-E): every modified entry —
            resident or staged in the eviction buffer — is written
            through to the backend so the backend delta below covers it;
          * backend DELTA: keys written/deleted since the last epoch
            (incremental — the SnapshotStore composes full state);
          * in-flight keyed work that a restart would otherwise lose:
            tuples parked on outstanding fetches, tuples parked behind an
            in-flight shard migration, and the HintsBuffer contents.

        The export itself runs off the tuple path (like the migration
        drain, §9) and is metered as snapshot bytes, not workload reads;
        the RESTORE of these bytes is charged at backend speed
        (streaming/recovery.py) — no free bulk I/O in either direction.
        """
        import copy
        cache = self.caches[sub]
        dirty = cache.flush_dirty()
        for e in dirty:
            self.backends[sub].write(e.key, e.state, self.state_size)
        # write-backs still in flight at the cut carry pre-barrier state
        # that would otherwise land only in the NEXT epoch's delta: write
        # them through now (idempotent with the completion's own write)
        for e in self.wb_pending[sub].values():
            self.backends[sub].write(e.key, e.state, self.state_size)
        delta, deleted = self.backends[sub].snapshot_delta()
        mgr = self.managers[sub]
        # cache MANIFEST: resident keys + their TAC timestamps (no state
        # payloads — a few bytes per key).  Recovery warmup re-fetches
        # these alongside the hint WAL: the hottest keys are exactly the
        # ones CMS suppression keeps OUT of the hint stream while they
        # sit resident, so without the manifest a warmed restore would
        # stage only the cold tail (DESIGN.md §7)
        manifest = [(e.key, getattr(e, "ts", 0.0))
                    for e in getattr(cache, "entries", {}).values()]
        payload = {
            "n_flushed": len(dirty),
            "delta": delta,
            "deleted": deleted,
            "hints": dict(mgr.hints.in_flight) | dict(mgr.hints.unprocessed),
            "manifest": manifest,
            "inflight": copy.deepcopy(self._snapshot_inflight(sub)),
            "extra": self.snapshot_extra(sub),
            "bytes": len(delta) * self.state_size,
        }
        self.engine.ack_barrier(b_id=epoch, op=self.name, sub=sub,
                                n_flushed=len(dirty))
        return payload

    def _snapshot_inflight(self, sub: int) -> List[Any]:
        """Keyed messages whose state effects are NOT yet applied at the
        barrier cut and that the source will NOT replay (they were
        emitted before the epoch's offsets): parked-on-fetch tuples and
        mid-migration parked traffic.  Windowed subclasses add pending
        FIRE messages (§10)."""
        out = []
        for parked in self.waiting[sub].values():
            out.extend(parked)
        if self.shards is not None:
            for shard, msgs in self.shard_pending.items():
                if self.shards.owner[shard] == sub:
                    out.extend(msgs)
        return out

    def snapshot_extra(self, sub: int) -> Optional[dict]:
        """Operator-specific registries riding the snapshot (window
        registries §10, join retention §11).  The shard-plane owner table
        is included so recovery restores routing consistent with where
        the backend partitions were cut (§9; migrations serialize with
        epochs, so the table is stable across one epoch's cut)."""
        import copy
        if self.shards is not None:
            return {"plane_owner": copy.deepcopy(list(self.shards.owner))}
        return None

    def restore_extra(self, sub: int, extra: Optional[dict]) -> None:
        if extra and self.shards is not None and "plane_owner" in extra:
            self.shards.owner = list(extra["plane_owner"])

    def reset_volatile(self) -> None:
        """A process crash loses every cache, I/O lane, and parked tuple;
        backends are cleared too — the authoritative copy lives in the
        SnapshotStore and is re-imported by recovery (DESIGN.md §7)."""
        super().reset_volatile()
        p = self.parallelism
        self.caches = [self._new_cache() for _ in range(p)]
        self._attach_obs()
        self._park_t = [dict() for _ in range(p)]
        self.waiting = [defaultdict(list) for _ in range(p)]
        self.in_flight = [set() for _ in range(p)]
        self.wb_pending = [dict() for _ in range(p)]
        self.io_q = [deque() for _ in range(p)]
        self.io_free = [self.io_workers] * p
        self.miss_reported = [False] * p
        self.shard_pending.clear()
        if self.shards is not None:
            self.shards.migrating.clear()
        from repro.core.hints import HintsBuffer
        for m in self.managers:
            m.hints = HintsBuffer()
            m._marker_hint_t.clear()
        for b in self.backends:
            b.reset()


class SinkOp(Operator):
    def process(self, sub: int, tup: Tuple_) -> Optional[float]:
        self.engine.record_latency(self.sim.t, tup)
        return 1e-6


class Engine:
    """Dataflow driver: plan assembly, clock, markers, metrics.

    Owns the discrete-event clock (``Sim``), the operator plan, the
    centralised PrefetchingController (DESIGN.md §3), checkpoint
    coordination (§7), and the end-of-run metrics rollup — including the
    per-shard routing/migration counters of any operator on the sharded
    state plane (§9).  ``connect`` wires channels (data or hint side
    channel), ``register_prefetching`` declares the candidate lookaheads
    for one stateful operator, and ``run`` drives sources + periodic
    markers until the requested duration has elapsed.
    """

    def __init__(self, marker_interval: float = 0.100):
        self.sim = Sim()
        self.controller = PrefetchingController(marker_interval)
        self.operators: Dict[str, Operator] = {}
        self._candidate_ops: Dict[str, List[str]] = {}
        self.order: List[str] = []
        # observability plane (DESIGN.md §12): the registry is the one
        # sink for every counter/gauge/histogram; the tracer samples
        # per-tuple critical-path spans (off unless enable_tracing)
        self.registry = MetricsRegistry()
        self.tracer = Tracer(self.registry)
        self._export_path: Optional[str] = None
        self._export_interval = 0.0
        # temporal plane (DESIGN.md §16): interval time series + health
        # detectors on the logical clock, plus a bounded event log the
        # Perfetto export fuses with the sampled spans.  All off by
        # default; the hot-path cost when off is one flag check at the
        # few event sites (epoch/migration/fire/recovery)
        self.timeline: Optional[Timeline] = None
        self.health: Optional[HealthMonitor] = None
        self.events: List[Tuple[str, float, dict]] = []
        self.record_events = False
        self._event_cap = 65536
        self._event_ids = itertools.count(1)
        self._timeline_on = False
        # sink latency: percentiles come from the UNCAPPED streaming
        # sketch (no truncation bias); the bounded deques keep the most
        # RECENT samples for timeline slicing (recovery/sharding
        # benchmarks cut windows around an injected event)
        self.latency_cap = 2_000_000
        self.latencies: deque = deque(maxlen=self.latency_cap)
        self.latency_t: deque = deque(maxlen=self.latency_cap)
        self._sink_hist = self.registry.histogram("engine.sink.latency")
        self._sink_count = self.registry.counter("engine.sink.count")
        self._marker_ids = itertools.count()
        self.marker_interval = marker_interval
        self.lookahead_timeline: List[Tuple[float, str]] = []
        self.checkpoint_acks: Dict[int, List] = {}
        # fault-tolerance plane (DESIGN.md §7): a CheckpointCoordinator
        # (streaming/recovery.py) attaches itself here; the engine-level
        # alignment counters below fill regardless so legacy
        # trigger_checkpoint callers still see stall metrics
        self.coordinator = None
        # flipped (permanently) by the first trigger_checkpoint: keeps
        # the per-batch barrier scan and alignment machinery entirely
        # off the delivery hot path of non-checkpointed runs
        self.barriers_active = False
        self.snapshots_taken = 0
        self.align_stall_total = 0.0
        self.align_stall_max = 0.0
        self.align_buffered = 0

    # -------------------------------------------------------------- building
    def add(self, op: Operator) -> Operator:
        op.plan_pos = len(self.order)
        self.operators[op.name] = op
        self.order.append(op.name)
        return op

    def connect(self, src: Operator, dst: Operator,
                partition=hash_partition, kind: str = "data",
                timeout: float = BUFFER_TIMEOUT,
                codec: Optional[str] = None) -> None:
        ch = Channel(self.sim, dst, kind, partition, src.parallelism,
                     timeout, codec=codec)
        if kind == "hint":
            src.out_hint.append(ch)
        else:
            src.out_data.append(ch)
            # watermarks and checkpoint barriers flow on data edges only:
            # every (channel, src subtask) pair must report before the
            # min-of-inputs advances / the barrier alignment completes
            dst.wm_expected += src.parallelism
            dst.barrier_expected += src.parallelism

    def register_prefetching(self, stateful: StatefulOp,
                             lookaheads: List[MapOp],
                             compress_hints: bool = False) -> None:
        """Declare candidate lookaheads (ordered source -> closest) and wire
        the hint side channels.  On the sharded plane the hint channels
        partition by shard OWNERSHIP (DESIGN.md §9): each hint reaches
        exactly the subtask whose prefetcher owns the key.  With
        ``compress_hints`` the channels account bytes under the delta
        codec (§13).  Binding also points each lookahead's suppression
        verdicts at the stateful operator's recorder for grading."""
        cands = [LookaheadCandidate(op.name, op.plan_pos)
                 for op in lookaheads]
        self.controller.register(stateful.name, cands)
        self._candidate_ops[stateful.name] = [op.name for op in lookaheads]
        plane = getattr(stateful, "shards", None)
        hint_partition = plane.route_hint if plane is not None \
            else hash_partition
        for op in lookaheads:
            op.sink_recorder = stateful.recorder
            self.connect(op, stateful, partition=hint_partition,
                         kind="hint", timeout=HINT_TIMEOUT,
                         codec="delta" if compress_hints else None)

    def migrate_shard(self, op_name: str, shard: int, dst_sub: int,
                      at: Optional[float] = None) -> None:
        """Schedule (or run now) a key-range migration on a sharded
        stateful operator — the rebalance entry point for benchmarks and
        an elasticity controller.  With a CheckpointCoordinator attached,
        migrations SERIALIZE with checkpoint epochs (DESIGN.md §7): a
        migration requested while an epoch is in flight is deferred until
        the epoch completes, so one epoch's cut never straddles an
        ownership flip."""
        op = self.operators[op_name]
        if not isinstance(op, StatefulOp):
            raise TypeError(f"{op_name} is not a StatefulOp")
        if at is None:
            self._do_migrate(op_name, shard, dst_sub)
        else:
            self.sim.at(at, self._do_migrate, op_name, shard, dst_sub)

    def _do_migrate(self, op_name: str, shard: int, dst_sub: int) -> None:
        coord = self.coordinator
        if coord is not None and (coord.pending is not None
                                  or coord.in_recovery):
            coord.defer_migration(op_name, shard, dst_sub)
            return
        self.operators[op_name].migrate_shard(shard, dst_sub)

    def set_lookahead(self, stateful_name: str, lookahead_name: str) -> None:
        for name in self._candidate_ops.get(stateful_name, []):
            op = self.operators.get(name)
            if isinstance(op, MapOp):
                want = name == lookahead_name
                if op.hint_active != want:
                    op.hint_active = want
        if (not self.lookahead_timeline
                or self.lookahead_timeline[-1][1] != lookahead_name):
            self.lookahead_timeline.append((self.sim.t, lookahead_name))

    # -------------------------------------------------------------- running
    def record_latency(self, now: float, tup: Tuple_) -> None:
        lat = now - tup.ingest_t
        self.latencies.append(lat)
        self.latency_t.append(now)
        self._sink_hist.observe(lat)
        self._sink_count.inc()
        if tup.trace is not None:
            self.tracer.finish(tup.trace, now)

    # -------------------------------------------------- observability plane
    def enable_tracing(self, sample_every: int = 64) -> None:
        """Turn on per-tuple critical-path span sampling (DESIGN.md §12):
        every Nth source tuple carries a TupleTrace finalized at the
        sink.  Off by default — the disabled cost is one flag check per
        source tuple."""
        self.tracer.enable(sample_every)

    def enable_export(self, path: str, interval: float = 1.0) -> None:
        """Append a registry snapshot line to ``path`` every ``interval``
        sim seconds (JSONL: ``{"t": ..., "delta": {...}, "metrics":
        {...}}`` — see ``MetricsRegistry.export_jsonl``)."""
        self._export_path = path
        self._export_interval = interval
        self.sim.after(interval, self._export_tick)

    def _export_tick(self) -> None:
        self._sync_registry()
        self.registry.export_jsonl(self._export_path, t=self.sim.t)
        self.sim.after(self._export_interval, self._export_tick)

    def enable_timeline(self, interval: float = 0.1, capacity: int = 600,
                        detectors: bool = True, **health_kw) -> None:
        """Turn on the temporal plane (DESIGN.md §16): every
        ``interval`` sim seconds, mirror the operator counters and cut a
        timeline interval (counter deltas, gauge samples, histogram
        interval sketches) into a bounded ring; with ``detectors``, run
        the health detectors over each cut and log their alerts.  Extra
        keyword args tune ``HealthMonitor`` thresholds."""
        self.timeline = Timeline(self.registry, interval, capacity)
        if detectors:
            stateful = [n for n, op in self.operators.items()
                        if isinstance(op, StatefulOp)]
            self.health = HealthMonitor(self.timeline, stateful,
                                        **health_kw)
        self.record_events = True
        self._timeline_on = True
        self.sim.after(interval, self._timeline_tick)

    def stop_timeline(self) -> None:
        """Freeze the temporal plane: no further cuts or detector
        updates (the chaos harness calls this before its drain phase,
        where throughput legitimately falls to zero)."""
        self._timeline_on = False

    def _timeline_tick(self) -> None:
        if not self._timeline_on or self.timeline is None:
            return
        self._sync_registry()
        iv = self.timeline.tick(self.sim.t)
        if self.health is not None:
            for a in self.health.observe(iv):
                self.log_event("alert", alert_kind=a.kind, op=a.op,
                               value=a.value)
        self.sim.after(self.timeline.interval, self._timeline_tick)

    def log_event(self, kind: str, **fields) -> None:
        """Append to the bounded engine event log (epoch barriers,
        migrations, failures/recoveries, window fires, alerts) for the
        Perfetto export.  No-op unless ``record_events`` is on."""
        if not self.record_events or len(self.events) >= self._event_cap:
            return
        self.events.append((kind, self.sim.t, fields))

    def trigger_checkpoint(self, checkpoint_id: int) -> None:
        """Inject an epoch's barriers at every source subtask (each
        downstream operator aligns over all of them, DESIGN.md §7).  The
        CheckpointCoordinator drives this on an interval and records
        source offsets first; calling it directly still produces aligned
        snapshots and ``checkpoint_acks`` (but backend deltas only cover
        writes since delta tracking was switched on — attach a
        coordinator before data flows for restorable snapshots)."""
        self.barriers_active = True
        for op in self.operators.values():
            if isinstance(op, StatefulOp):
                for bk in op.backends:
                    bk.track_deltas = True
        b = CheckpointBarrier(checkpoint_id)
        for name in self.order:
            op = self.operators[name]
            if isinstance(op, SourceOp):
                for s in range(op.parallelism):
                    for ch in op.out_data:
                        ch.send(s, b)

    def ack_barrier(self, b_id: int, op: str, sub: int,
                    n_flushed: int) -> None:
        self.checkpoint_acks.setdefault(b_id, []).append(
            (self.sim.t, op, sub, n_flushed))

    def on_snapshot(self, epoch: int, op: str, sub: int,
                    payload: Optional[dict], stall: float,
                    buffered: int) -> None:
        """One (operator, subtask) reached the epoch's aligned cut."""
        self.snapshots_taken += 1
        self.align_stall_total += stall
        self.align_stall_max = max(self.align_stall_max, stall)
        self.align_buffered += buffered
        if self.coordinator is not None:
            self.coordinator.on_operator_snapshot(epoch, op, sub, payload,
                                                  stall, buffered)

    def _inject_marker(self) -> None:
        mid = next(self._marker_ids)
        m = Marker(mid)
        for name in self.order:
            op = self.operators[name]
            if isinstance(op, SourceOp):
                for ch in op.out_data:
                    ch.send(0, m)
        for name in self.order:
            op = self.operators[name]
            if isinstance(op, StatefulOp):
                op.periodic_evaluate()
        self.sim.after(self.marker_interval, self._inject_marker)

    def run(self, duration: float, warmup: float = 0.0) -> Dict[str, Any]:
        for op in self.operators.values():
            if isinstance(op, SourceOp):
                op.start()
        self.sim.after(self.marker_interval, self._inject_marker)
        if warmup > 0:
            self.sim.run_until(warmup)
            self.latencies.clear()
            self.latency_t.clear()
            # latency percentiles cover the measured window only: reset
            # the sink sketch/count and drop warmup-sampled spans (the
            # cumulative hint/cache counters intentionally keep counting
            # across warmup, exactly like before)
            self._sink_hist.sketch = QuantileSketch()
            self._sink_count.value = 0
            self.tracer.reset()
        self.sim.run_until(warmup + duration)
        for op in self.operators.values():
            if isinstance(op, StatefulOp):
                # close the suppression ledger (§13): anything still
                # pending at end of run was never accessed again
                op.recorder.flush_pending()
        return self.metrics(duration, warmup)

    # -------------------------------------------------------------- metrics
    def metrics(self, duration: float, warmup: float) -> Dict[str, Any]:
        sk = self._sink_hist.sketch
        n = self._sink_count.value
        # percentiles from the UNCAPPED streaming sketch — the bounded
        # `latencies` deque would bias long runs toward recent samples
        out = {
            "n_outputs": n,
            "throughput": n / duration,
            "p50": sk.quantile(0.50),
            "p90": sk.quantile(0.90),
            "p99": sk.quantile(0.99),
            "p999": sk.quantile(0.999),
            "max": sk.vmax if n else 0.0,
        }
        busy = sum(sum(op.busy_time) for op in self.operators.values())
        slots = sum(op.parallelism for op in self.operators.values())
        out["cpu_util"] = busy / (slots * (duration + warmup))
        # per-operator busy fraction (Flink busyTimeMsPerSecond analogue:
        # includes synchronous I/O wait, paper Table I)
        for name, op in self.operators.items():
            out[f"util_{name}"] = (sum(op.busy_time)
                                   / (op.parallelism * (duration + warmup)))
        data_bytes = hint_bytes = hint_bytes_raw = 0
        codecs_active = False
        for op in self.operators.values():
            for ch in op.out_data:
                data_bytes += ch.bytes_sent
            for ch in op.out_hint:
                hint_bytes += ch.bytes_sent
                hint_bytes_raw += ch.bytes_raw
                codecs_active = codecs_active or ch.codec is not None
        out["data_bytes"] = data_bytes
        out["hint_bytes"] = hint_bytes
        out["net_overhead"] = hint_bytes / max(1, data_bytes)
        if codecs_active:
            out["hint_bytes_raw"] = hint_bytes_raw
            out["hint_compression"] = hint_bytes_raw / max(1, hint_bytes)
        for name, op in self.operators.items():
            if isinstance(op, StatefulOp):
                out[f"{name}_hit_rate"] = sum(
                    c.hits for c in op.caches) / max(
                    1, sum(c.hits + c.misses for c in op.caches))
                out[f"{name}_queued"] = sum(len(q) for q in op.queues)
                out[f"{name}_backend_reads"] = sum(
                    b.reads for b in op.backends)
                out[f"{name}_backend_writes"] = sum(
                    b.writes for b in op.backends)
                out[f"{name}_backend_bytes_read"] = sum(
                    b.bytes_read for b in op.backends)
                out[f"{name}_backend_bytes_written"] = sum(
                    b.bytes_written for b in op.backends)
                out[f"{name}_prefetch_hits"] = sum(
                    m.prefetch_hits for m in op.managers)
                out[f"{name}_hints_received"] = sum(
                    m.hints_received for m in op.managers)
                out[f"{name}_hints_late"] = sum(
                    m.hints_late for m in op.managers)
                out[f"{name}_hints_duplicate"] = sum(
                    m.hints_duplicate for m in op.managers)
                # hint timeliness/accuracy rollup (DESIGN.md §12): the
                # per-hint outcome split, signed lead times, and the
                # precision/recall headline ratios
                out[f"{name}_hint_quality"] = op.recorder.quality_block(
                    out[f"{name}_prefetch_hits"],
                    op.pf_demand.value,
                    out[f"{name}_hints_duplicate"],
                    out[f"{name}_hints_late"])
                ev: Dict[str, int] = {}
                for c in op.caches:
                    for k, v in getattr(c, "eviction_block",
                                        lambda: {})().items():
                        ev[k] = ev.get(k, 0) + v
                if ev:
                    out[f"{name}_evictions"] = ev
                lsk = op.access_hist.sketch
                if lsk.count:
                    out[f"{name}_access_p50"] = lsk.quantile(0.50)
                    out[f"{name}_access_p99"] = lsk.quantile(0.99)
                fp = [c for c in op.caches if isinstance(c, FusedPlane)]
                if fp:
                    # fused-plane rollup (§14): device tallies + batch
                    # occupancy (underfilled batches waste launch cost)
                    out[f"{name}_fused"] = {
                        "batches": sum(c.batches for c in fp),
                        "lanes": sum(c.lanes for c in fp),
                        "fill_ratio": sum(c.lanes for c in fp) / max(
                            1, sum(c.batches * c.batch for c in fp)),
                        "device_hits": sum(c.device_hits for c in fp),
                        "device_misses": sum(c.device_misses for c in fp),
                        "device_conflicts": sum(c.device_conflicts
                                                for c in fp),
                    }
                if op.shards is not None:
                    # per-shard routed-plane counters (DESIGN.md §9), not
                    # just the global totals above
                    out[f"{name}_shard_plane"] = op.shards.snapshot()
        if self.snapshots_taken:
            # checkpoint-plane counters (DESIGN.md §7), alongside the
            # per-shard block above
            out["checkpoint"] = {
                "snapshots_taken": self.snapshots_taken,
                "align_stall_total": self.align_stall_total,
                "align_stall_max": self.align_stall_max,
                "align_stall_avg": self.align_stall_total
                / self.snapshots_taken,
                "align_buffered": self.align_buffered,
            }
            if self.coordinator is not None:
                out["checkpoint"].update(self.coordinator.metrics_block())
        if self.coordinator is not None and self.coordinator.recoveries:
            out["recovery"] = self.coordinator.recovery_block()
        for name, op in self.operators.items():
            # operator-specific counters (windowed fires/late paths, burst
            # hints, ...) without the engine importing those modules
            extra = getattr(op, "extra_metrics", None)
            if callable(extra):
                for k, v in extra().items():
                    out[f"{name}_{k}"] = v
            if any(w > float("-inf") for w in op.wm):
                out[f"{name}_watermark"] = list(op.wm)
                lag = self._wm_lag(op)
                if lag is not None:
                    out[f"{name}_watermark_lag"] = lag
        if self.tracer.active:
            # sampled critical-path breakdown (DESIGN.md §12)
            out["trace"] = self.tracer.summary()
        if self.timeline is not None:
            # temporal-plane rollup (DESIGN.md §16)
            out["timeline"] = self.timeline.block()
        if self.health is not None:
            out["health"] = self.health.block()
            out["alerts"] = [a.as_dict() for a in self.health.alerts]
        self._sync_registry()
        return out

    def _wm_lag(self, op: Operator) -> Optional[float]:
        """Event-time watermark lag: the source frontier (max emitted
        event ts) minus the operator's slowest subtask watermark."""
        frontier = max((m for s in self.operators.values()
                        if isinstance(s, SourceOp) for m in s._max_ts),
                       default=float("-inf"))
        low = min(op.wm)
        if frontier == float("-inf") or low == float("-inf"):
            return None
        return frontier - low

    def _sync_registry(self) -> None:
        """Mirror the operator-local counters into their catalogued
        registry names (DESIGN.md §12).  Hot paths keep their plain-int
        counters; this runs only at snapshot/export time, so the live
        registry view stays consistent without taxing the data path."""
        r = self.registry
        data_bytes = hint_bytes = busy = 0.0
        slots = 0
        for name, op in self.operators.items():
            for ch in op.out_data:
                data_bytes += ch.bytes_sent
            for ch in op.out_hint:
                hint_bytes += ch.bytes_sent
            busy += sum(op.busy_time)
            slots += op.parallelism
            pre = f"engine.{name}"
            r.counter(f"{pre}.processed").set(op.processed)
            elapsed = max(self.sim.t, 1e-12)
            r.gauge(f"{pre}.busy_frac").set(
                sum(op.busy_time) / (op.parallelism * elapsed))
            r.gauge(f"{pre}.queue.depth").set(
                sum(len(q) for q in op.queues)
                + sum(len(q) for q in getattr(op, "ready", [])))
            lag = self._wm_lag(op)
            if lag is not None:
                r.gauge(f"{pre}.watermark.lag").set(lag)
            if not isinstance(op, StatefulOp):
                continue
            r.counter(f"{pre}.cache.hits").set(
                sum(c.hits for c in op.caches))
            r.counter(f"{pre}.cache.misses").set(
                sum(c.misses for c in op.caches))
            r.counter(f"{pre}.backend.reads").set(
                sum(b.reads for b in op.backends))
            r.counter(f"{pre}.backend.writes").set(
                sum(b.writes for b in op.backends))
            r.counter(f"{pre}.hints.received").set(
                sum(m.hints_received for m in op.managers))
            r.counter(f"{pre}.hints.late").set(
                sum(m.hints_late for m in op.managers))
            r.counter(f"{pre}.hints.duplicate").set(
                sum(m.hints_duplicate for m in op.managers))
            r.counter(f"{pre}.prefetch.hits").set(
                sum(m.prefetch_hits for m in op.managers))
            ev: Dict[str, int] = {}
            for c in op.caches:
                for k, v in getattr(c, "eviction_block",
                                    lambda: {})().items():
                    ev[k] = ev.get(k, 0) + v
            for k, v in ev.items():
                r.counter(f"{pre}.evict.{k}").set(v)
            fp = [c for c in op.caches if isinstance(c, FusedPlane)]
            if fp:
                r.counter(f"{pre}.fused.batches").set(
                    sum(c.batches for c in fp))
                r.counter(f"{pre}.fused.lanes").set(
                    sum(c.lanes for c in fp))
                r.gauge(f"{pre}.fused.fill_ratio").set(
                    sum(c.lanes for c in fp) / max(
                        1, sum(c.batches * c.batch for c in fp)))
                r.counter(f"{pre}.fused.device_hits").set(
                    sum(c.device_hits for c in fp))
                r.counter(f"{pre}.fused.device_misses").set(
                    sum(c.device_misses for c in fp))
                r.counter(f"{pre}.fused.device_conflicts").set(
                    sum(c.device_conflicts for c in fp))
            if op.shards is not None:
                op.shards.registry_sync(r, pre, op.shard_pending)
        r.counter("engine.net.data_bytes").set(int(data_bytes))
        r.counter("engine.net.hint_bytes").set(int(hint_bytes))
        r.gauge("engine.cpu.util").set(
            busy / max(1e-12, slots * self.sim.t))
        if self.snapshots_taken:
            r.counter("checkpoint.snapshots_taken").set(self.snapshots_taken)
            r.gauge("checkpoint.align_stall_total").set(
                self.align_stall_total)
            r.gauge("checkpoint.align_stall_max").set(self.align_stall_max)
            r.counter("checkpoint.align_buffered").set(self.align_buffered)
        if self.coordinator is not None:
            self.coordinator.registry_sync(r)
