"""Fused execution mode: device-resident keyed state behind a cache-
compatible control plane (DESIGN.md §14).

The interpreted engine walks one tuple at a time through
``TimestampAwareCache`` — a Python dict + lazy heap.  In fused mode the
stateful operator instead batches runs of consecutive tuples into
fixed-width device batches and executes the whole inner loop — TAC probe
→ ``page_gather`` → operator compute → scatter write-back — as ONE
jitted program per operator config (``repro.core.tac_jax.fused_step``).
The Python layer is demoted to control plane: watermarks, barriers,
hints, parking, checkpoint cuts, and eviction POLICY stay host-side.

Two data structures cooperate:

  * the DEVICE plane — ``TACState`` directory + a payload pool
    ``pages [W + 1, 1, V + 1]`` (channel 0 = presence flag, the device
    encoding of the Python side's ``None`` state; last row = zeroed
    scratch slot that miss/padding lanes alias);
  * the HOST SHADOW — per-slot key/ts/gen/dirty/admission metadata in
    numpy.  The shadow owns eviction ORDER (fp64 timestamps + an
    insertion-generation tie-break replicating the reference heap) and
    slot assignment; the device owns membership and payloads.  Both
    change only through the entry points below, so they agree by
    construction.

``FusedPlane`` implements the full ``TimestampAwareCache`` interface
(lookup/insert/write/renew/drop/pop_writeback/flush_dirty/export/import/
eviction_block, the §12 counters, and the prefetch-quality recorder
hooks) so every cold path of the engine — parked resumes, write-back
lanes, checkpoints, recovery — runs unchanged against it; ``batch_step``
is the hot path the fused operator drives.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core.tac import Entry

# jax/device imports are deferred so stdlib-only tooling can import the
# module namespace; the plane itself requires the device stack.


@dataclass
class FusedSpec:
    """Declarative operator compute for the fused data path (§14).

    The interpreted engine accepts arbitrary Python ``apply_fn``s; a
    fused operator must instead DECLARE its state transition so it can
    compile: ``kind`` picks the device compute (``sum`` — count is a sum
    of ones —, ``max``, or ``read`` for read-only enrichment), ``width``
    the state-vector arity V, and the encode/decode pair maps the host
    state object to/from the device row (``None`` state <-> absent row).
    ``weight_of`` extracts the per-tuple update vector; ``emit_of``
    (read/sum kinds) produces per-lane outputs host-side.
    """
    kind: str                                   # sum | max | read
    width: int = 1
    weight_of: Optional[Callable[[Any], Any]] = None
    encode: Optional[Callable[[Any], Any]] = None
    decode: Optional[Callable[[np.ndarray], Any]] = None
    emit_of: Optional[Callable[[Any, Any], list]] = None

    def __post_init__(self):
        if self.kind not in ("sum", "max", "read"):
            raise ValueError(f"fused kind {self.kind!r}")

    def weight(self, tup) -> np.ndarray:
        if self.weight_of is None:
            return _ONES[:self.width]
        w = self.weight_of(tup)
        return np.atleast_1d(np.asarray(w, np.float32))

    def weight_raw(self, tup):
        """Like ``weight`` but stays in Python — a length-V sequence the
        batch staging vectorizes in one ``np.asarray`` over all lanes
        (per-lane array wrapping dominated the assembly cost)."""
        if self.weight_of is None:
            return _ONES_T[:self.width]
        w = self.weight_of(tup)
        if isinstance(w, (int, float)):
            return (w,)
        return w

    def enc(self, state) -> Tuple[np.ndarray, bool]:
        if state is None:
            return _ZEROS[:self.width], False
        if self.encode is None:
            return np.atleast_1d(np.asarray(state, np.float32)), True
        vec = self.encode(state)
        if vec is None:
            return _ZEROS[:self.width], False
        return np.atleast_1d(np.asarray(vec, np.float32)), True

    def dec(self, vec: np.ndarray, present: bool):
        if not present:
            return None
        if self.decode is None:
            return float(vec[0])
        return self.decode(np.asarray(vec))


_ONES = np.ones(16, np.float32)
_ZEROS = np.zeros(16, np.float32)
_ONES_T = (1.0,) * 16


class Lane(NamedTuple):
    """One device lane of a fused batch: a pane/key access derived from
    a queued tuple at batch-assembly time (``StatefulOp._fused_expand``).
    """
    key: Any                  # state-access key (WindowKey for panes)
    ts: float                 # event time of the access
    weight: Any               # length-V update vector (sequence or
    #                           ndarray; zeros for fire/read lanes)
    fire: bool                # window-fire read (no update)
    late_update: bool         # update on a FIRED pane (late_policy=update)
    tup: Any                  # source Tuple_ (parking, traces, emits)


class BatchResult(NamedTuple):
    hit: np.ndarray           # [n] bool — device-resident, update applied
    present: np.ndarray       # [n] bool — value present after the lane
    new_vals: np.ndarray      # [n, V]  — value after the lane (composed)
    fire: np.ndarray          # [n] bool — the staged fire flags (lets
    #                           the caller mask lanes without re-walking)


class FusedPlane:
    """Device-resident keyed-state plane with TAC-compatible semantics.

    Capacity is counted in the same size units as ``TimestampAwareCache``
    (``capacity // entry_size`` uniform slots).  Single-key operations
    (the engine's cold paths) each cost one small device call; the hot
    path is ``batch_step``.
    """

    PAD_KEY = -2              # never matches empty (-1) or interned (>=0)
    DROP_W = 32               # fixed width of the batched directory clear

    def __init__(self, capacity: int, entry_size: int, spec: FusedSpec,
                 deadline_aware: bool = False, batch: int = 64):
        import jax.numpy as jnp
        from repro.core import tac_jax
        self._tj = tac_jax
        self._jnp = jnp
        self.spec = spec
        self.batch = int(batch)
        self.capacity = capacity
        self.entry_size = max(1, int(entry_size))
        self.deadline_aware = deadline_aware
        W = max(1, capacity // self.entry_size)
        self.n_slots = W
        V = spec.width
        self.tac = tac_jax.init(1, W, 1)
        self.pages = jnp.zeros((W + 1, 1, V + 1), jnp.float32)
        # host shadow directory (fp64 eviction order, §14)
        self._sid = np.full(W, -1, np.int64)        # interned key id
        self._sts = np.full(W, -np.inf, np.float64)
        self._sgen = np.zeros(W, np.int64)
        self._sdirty = np.zeros(W, bool)
        self._spf = np.zeros(W, bool)               # admitted by prefetch
        self._spf_unused = np.zeros(W, bool)        # staged, never read
        self._sstage_t = np.zeros(W, np.float64)
        self._sorigin: List[str] = [""] * W
        self._key_by_slot: List[Any] = [None] * W
        self._slot_by_key: Dict[Any, int] = {}
        self._free: List[int] = list(range(W - 1, -1, -1))
        self._ids: Dict[Any, int] = {}
        self._gen = 0
        self._pending_drops: List[int] = []
        # deferred admissions (§14): misses arrive one completion at a
        # time from the I/O plane, but a per-admit device call costs
        # ~10x the jit argument path.  _place queues the row host-side
        # (slot-keyed, so a re-write before the flush supersedes in
        # place) and _flush_admits lands the whole backlog in chunked
        # fused_admit calls right before the next device op needs it.
        # _pending_state mirrors the encoded rows so reads of a queued
        # slot are served host-side without touching the device.
        self._pending_admits: Dict[int, list] = {}
        self._pending_state: Dict[int, tuple] = {}
        # lazy victim heaps, the same structure the interpreted TAC
        # uses: (ts, gen, slot) min-order and (-ts, gen, slot) for the
        # deadline-aware farthest-first rule.  gen is a unique version
        # per (slot, ts) assignment, so staleness is a gen mismatch.
        # Touches only note the slot; the push happens when a victim is
        # actually needed, so a slot hit N times between evictions costs
        # one push, not N.
        self._heap: List[Tuple[float, int, int]] = []
        self._fheap: List[Tuple[float, int, int]] = []
        self._touched: set = set()
        self.clock = float("-inf")
        self.evict_buffer: Dict[Any, Entry] = {}
        self.used = 0
        self.on_writeback = None
        # §12 counter block (TimestampAwareCache-compatible)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.writebacks = 0
        self.prefetch_insertions = 0
        self.prefetch_unused_evicted = 0
        self.pf_ins_by_origin: Dict[str, int] = {}
        self.pf_unused_by_origin: Dict[str, int] = {}
        self.evict_reasons: Dict[Tuple[str, str], int] = {}
        self.recorder = None
        # fused-plane telemetry (device tallies folded into §12, §14)
        self.batches = 0
        self.lanes = 0
        self.device_hits = 0
        self.device_misses = 0
        self.device_conflicts = 0

    # ------------------------------------------------------------ internals
    def _intern(self, key) -> int:
        kid = self._ids.get(key)
        if kid is None:
            kid = len(self._ids)
            self._ids[key] = kid
        return kid

    def _next_gen(self) -> int:
        self._gen += 1
        return self._gen

    def _flush_drops(self) -> None:
        if not self._pending_drops:
            return
        drops = self._pending_drops
        self._pending_drops = []
        for i in range(0, len(drops), self.DROP_W):
            chunk = drops[i:i + self.DROP_W]
            slots = np.zeros(self.DROP_W, np.int32)
            valid = np.zeros(self.DROP_W, bool)
            slots[:len(chunk)] = chunk
            valid[:len(chunk)] = True
            # np arrays go straight into the jitted call: jit's argument
            # path converts in ~us, an explicit device put costs ~100x
            self.tac = self._tj.drop_slots(self.tac, slots, valid)

    def _flush_admits(self) -> None:
        """Land the queued admissions.  Chunks pad to a few fixed widths
        (stable jit shapes) by REPEATING the first record — an
        idempotent duplicate write under the scatter's last-write-wins
        order.  Must run after ``_flush_drops``: a queued drop and a
        queued admit can target the same slot, and the admit wins."""
        if not self._pending_admits:
            return
        recs = list(self._pending_admits.items())
        self._pending_admits.clear()
        self._pending_state.clear()
        i = 0
        while i < len(recs):
            chunk = recs[i:i + 64]
            i += 64
            n = len(chunk)
            W = next(w for w in (1, 8, 16, 32, 64) if n <= w)
            if n < W:
                chunk = chunk + [chunk[0]] * (W - n)
            slots = np.asarray([c[0] for c in chunk], np.int32)
            rs = [c[1] for c in chunk]
            kids = np.asarray([r[0] for r in rs], np.int32)
            ts = np.asarray([r[1] for r in rs], np.float32)
            rows = np.asarray([r[2] for r in rs], np.float32)
            pres = np.asarray([r[3] for r in rs], bool)
            dirty = np.asarray([r[4] for r in rs], bool)
            self.tac, self.pages, _ = self._tj.fused_admit(
                self.tac, self.pages, slots, kids, ts, rows, pres,
                dirty)

    def _sync(self) -> None:
        self._flush_drops()
        self._flush_admits()

    def _touch(self, slot: int) -> None:
        self._touched.add(slot)

    def _flush_touches(self) -> None:
        """Push each touched slot's CURRENT (ts, gen) into the victim
        heaps; earlier entries lazily invalidate on gen mismatch."""
        for slot in self._touched:
            if self._sid[slot] < 0:
                continue
            t, g = float(self._sts[slot]), int(self._sgen[slot])
            heapq.heappush(self._heap, (t, g, slot))
            if self.deadline_aware:
                heapq.heappush(self._fheap, (-t, g, slot))
        self._touched.clear()

    def _live(self, g: int, slot: int) -> bool:
        return self._sgen[slot] == g and self._sid[slot] >= 0

    def _choose_victim(self) -> Tuple[int, str]:
        """Replicates ``TimestampAwareCache._evict_one``'s ORDER on the
        shadow: default = min (ts, gen); deadline_aware = stale entries
        (ts behind the watermark clock) oldest-first, else the FARTHEST
        deadline first (Belady on known fire times), gen tie-break.
        Same lazy-heap scheme as the interpreted cache: the min-heap top
        is the global (ts, gen) minimum, so if it is not stale nothing
        is."""
        self._flush_touches()
        if self.deadline_aware:
            while self._heap:
                ts, g, s = self._heap[0]
                if not self._live(g, s):
                    heapq.heappop(self._heap)
                    continue
                if ts < self.clock:
                    heapq.heappop(self._heap)
                    return s, "stale"
                break
            while True:
                _, g, s = heapq.heappop(self._fheap)
                if self._live(g, s):
                    return s, "deadline"
        while True:
            _, g, s = heapq.heappop(self._heap)
            if self._live(g, s):
                return s, "capacity"

    def _account_eviction(self, slot: int, reason: str) -> None:
        """Runs BEFORE the new occupant is queued at ``slot``.  A dirty
        victim's value comes from its own queued admission if it never
        reached the device, else from a single-row pool gather — clean
        victims (the common prefetch-churn case) touch nothing."""
        key = self._key_by_slot[slot]
        self.evictions += 1
        adm = "prefetched" if self._spf[slot] else "demand"
        self.evict_reasons[(reason, adm)] = \
            self.evict_reasons.get((reason, adm), 0) + 1
        if self._spf_unused[slot]:
            self.prefetch_unused_evicted += 1
            org = self._sorigin[slot]
            self.pf_unused_by_origin[org] = \
                self.pf_unused_by_origin.get(org, 0) + 1
            if self.recorder is not None:
                self.recorder.on_wasted()
        if self._sdirty[slot]:
            pend = self._pending_state.get(slot)
            if pend is not None:
                state = self.spec.dec(pend[0], pend[1])
            else:
                row = np.asarray(self._tj.gather_rows(
                    self.pages, np.array([slot], np.int32)))[0, 0]
                state = self.spec.dec(row[1:], row[0] > 0.5)
            e = Entry(key, state, float(self._sts[slot]), True,
                      self.entry_size)
            e.prefetched = bool(self._spf[slot])
            e.prefetched_unused = False
            e.origin = self._sorigin[slot]
            self.evict_buffer[key] = e
        # a queued admission evicted before it ever landed is cancelled;
        # the new occupant's queued row overwrites the slot at flush
        self._pending_admits.pop(slot, None)
        self._pending_state.pop(slot, None)
        del self._slot_by_key[key]
        self._key_by_slot[slot] = None
        self.used -= self.entry_size

    def _place(self, key, state, ts: float, dirty: bool,
               prefetched: bool, origin: str,
               pf_unused: bool) -> None:
        """Shared admit: resolve a slot (overwrite > free > evict) and
        QUEUE the row for the next ``_flush_admits`` (directory set +
        pool scatter land in one chunked program per device op)."""
        slot = self._slot_by_key.get(key)
        evict_reason = None
        if slot is None:
            if self._free:
                slot = self._free.pop()
            else:
                slot, evict_reason = self._choose_victim()
            self.used += self.entry_size
        if evict_reason is not None:
            self._account_eviction(slot, evict_reason)
        vec, present = self.spec.enc(state)
        self._pending_admits[slot] = [self._intern(key), float(ts), vec,
                                      present, dirty]
        self._pending_state[slot] = (vec, present)
        self._sid[slot] = self._ids[key]
        self._sts[slot] = ts
        self._sgen[slot] = self._next_gen()
        self._sdirty[slot] = dirty
        self._spf[slot] = prefetched
        self._spf_unused[slot] = pf_unused
        self._sorigin[slot] = origin
        self._key_by_slot[slot] = key
        self._slot_by_key[key] = slot
        self._touch(slot)
        if prefetched and self.recorder is not None:
            self._sstage_t[slot] = self.recorder.now()

    def _read_slot(self, slot: int):
        pend = self._pending_state.get(slot)
        if pend is not None:
            return self.spec.dec(pend[0], pend[1])
        row = np.asarray(self._tj.gather_rows(
            self.pages, np.array([slot], np.int32)))[0, 0]
        return self.spec.dec(row[1:], row[0] > 0.5)

    def _restore(self, staged: Entry, ts: float) -> None:
        """Eviction-buffer restore (the paper's staged-entry move-back):
        re-admit preserving admission metadata, NO insert counters."""
        self._place(staged.key, staged.state, max(staged.ts, ts),
                    staged.dirty, getattr(staged, "prefetched", False),
                    getattr(staged, "origin", ""), pf_unused=False)

    # ----------------------------------------------------------- cache API
    def lookup(self, key, now_ts: float):
        slot = self._slot_by_key.get(key)
        if slot is None:
            staged = self.evict_buffer.pop(key, None)
            if staged is not None:
                self._restore(staged, now_ts)
                self.hits += 1
                return staged.state
            self.misses += 1
            return None
        self.hits += 1
        if now_ts > self._sts[slot]:
            self._sts[slot] = now_ts
            self._sgen[slot] = self._next_gen()
            self._touch(slot)
        if self._spf_unused[slot] and self.recorder is not None:
            self.recorder.on_used(float(self._sstage_t[slot]))
        self._spf_unused[slot] = False
        return self._read_slot(slot)

    def contains(self, key) -> bool:
        return key in self._slot_by_key or key in self.evict_buffer

    def insert(self, key, state, ts: float, dirty: bool = False,
               size: int = 1, prefetched: bool = False,
               origin: str = "") -> None:
        self.evict_buffer.pop(key, None)
        self._place(key, state, ts, dirty, prefetched, origin,
                    pf_unused=prefetched)
        if prefetched:
            self.prefetch_insertions += 1
            self.pf_ins_by_origin[origin] = \
                self.pf_ins_by_origin.get(origin, 0) + 1
            if self.recorder is not None:
                self.recorder.on_staged()

    def write(self, key, state, now_ts: float, size: int = 1) -> None:
        slot = self._slot_by_key.get(key)
        if slot is None:
            self.insert(key, state, now_ts, dirty=True, size=size)
            return
        ts = max(float(self._sts[slot]), now_ts)
        self._place(key, state, ts, True,
                    self._spf[slot], self._sorigin[slot], pf_unused=False)

    def renew(self, key, hint_ts: float) -> bool:
        slot = self._slot_by_key.get(key)
        if slot is None:
            staged = self.evict_buffer.pop(key, None)
            if staged is None:
                return False
            self._restore(staged, hint_ts)
            return True
        if hint_ts > self._sts[slot]:
            self._sts[slot] = hint_ts
            self._sgen[slot] = self._next_gen()
            self._touch(slot)
        return True

    def drop(self, key) -> bool:
        slot = self._slot_by_key.pop(key, None)
        if slot is not None:
            self._pending_admits.pop(slot, None)
            self._pending_state.pop(slot, None)
            self._sid[slot] = -1
            self._sts[slot] = -np.inf
            self._sdirty[slot] = False
            self._spf[slot] = self._spf_unused[slot] = False
            self._key_by_slot[slot] = None
            self._free.append(slot)
            self._pending_drops.append(slot)
            self.used -= self.entry_size
            return True
        return self.evict_buffer.pop(key, None) is not None

    def set_clock(self, watermark: float) -> None:
        if watermark > self.clock:
            self.clock = watermark

    def pop_writeback(self) -> Optional[Entry]:
        if not self.evict_buffer:
            return None
        key = next(iter(self.evict_buffer))
        e = self.evict_buffer.pop(key)
        self.writebacks += 1
        return e

    # ------------------------------------------------------- bulk/cold ops
    def _pool_host(self) -> np.ndarray:
        self._flush_admits()
        return np.asarray(self.pages)

    def _entry_at(self, slot: int, pool: np.ndarray) -> Entry:
        row = pool[slot, 0]
        e = Entry(self._key_by_slot[slot],
                  self.spec.dec(row[1:], row[0] > 0.5),
                  float(self._sts[slot]), bool(self._sdirty[slot]),
                  self.entry_size)
        e.prefetched = bool(self._spf[slot])
        e.prefetched_unused = bool(self._spf_unused[slot])
        e.origin = self._sorigin[slot]
        return e

    @property
    def entries(self) -> Dict[Any, Entry]:
        """Decoded resident view (checkpoint manifest; cold path)."""
        pool = self._pool_host()
        return {k: self._entry_at(s, pool)
                for k, s in self._slot_by_key.items()}

    def flush_dirty(self) -> List[Entry]:
        jnp = self._jnp
        pool = self._pool_host()
        out = [self._entry_at(s, pool)
               for s in sorted(self._slot_by_key.values())
               if self._sdirty[s]]
        self._sdirty[:] = False
        out += list(self.evict_buffer.values())
        for e in out:
            e.dirty = False
        self.evict_buffer.clear()
        self.tac = self.tac._replace(
            dirty=jnp.zeros_like(self.tac.dirty))
        return out

    def export_entries(self, pred) -> List[Entry]:
        pool = self._pool_host()
        out = []
        for key in [k for k in self._slot_by_key if pred(k)]:
            out.append(self._entry_at(self._slot_by_key[key], pool))
            self.drop(key)
        for key in [k for k in self.evict_buffer if pred(k)]:
            out.append(self.evict_buffer.pop(key))
        return out

    def import_entries(self, entries: List[Entry],
                       now_ts: float = 0.0) -> int:
        for e in entries:
            self.insert(e.key, e.state, getattr(e, "ts", now_ts),
                        dirty=e.dirty, size=e.size)
        return len(entries)

    def eviction_block(self) -> Dict[str, int]:
        return {f"{r}.{a}": n
                for (r, a), n in sorted(self.evict_reasons.items())}

    def __len__(self) -> int:
        return len(self._slot_by_key)

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    @property
    def fill_ratio(self) -> float:
        """Mean device-batch occupancy: lanes / (batches * width) —
        underfilled batches mean the launch overhead is amortized over
        too few tuples (surfaced by tools/obs_report.py, §14)."""
        return self.lanes / (self.batches * self.batch) \
            if self.batches else 0.0

    # -------------------------------------------------------- fused hot path
    def batch_step(self, lanes: List[Lane]) -> BatchResult:
        """Run one fused device batch over ``lanes`` (≤ ``self.batch``).

        Device-HIT lanes have their update fully applied on device (the
        one jitted program); the caller finishes them host-side (emits,
        fires) from the returned per-lane values.  Device-MISS lanes are
        untouched — the caller adjudicates them through ``lookup`` in
        lane order (eviction-buffer restores, keys admitted earlier in
        the same drain, true misses to park), which keeps the §12
        hit/miss counters exactly sequential-equivalent.  Device tallies
        fold into ``device_hits``/``device_misses``.
        """
        self._sync()
        n = len(lanes)
        B = self.batch
        if n > B:
            raise ValueError(f"batch of {n} lanes exceeds width {B}")
        V = self.spec.width
        # bulk staging: one fromiter/asarray per field beats per-lane
        # numpy scalar writes by ~50x at B=64
        keys = np.full(B, self.PAD_KEY, np.int32)
        keys[:n] = np.fromiter((self._intern(ln.key) for ln in lanes),
                               np.int64, n)
        ts64 = np.fromiter((ln.ts for ln in lanes), np.float64, n)
        ts32 = np.zeros(B, np.float32)
        ts32[:n] = ts64
        weights = np.zeros((B, V), np.float32)
        weights[:n] = np.asarray([ln.weight for ln in lanes],
                                 np.float32).reshape(n, V)
        fire = np.zeros(B, bool)
        fire[:n] = np.fromiter((ln.fire for ln in lanes), bool, n)
        valid = np.zeros(B, bool)
        valid[:n] = True
        out = self._tj.fused_step(self.tac, self.pages, keys, ts32,
                                  weights, fire, valid,
                                  kind=self.spec.kind)
        self.tac, self.pages = out.state, out.pages
        hit = np.asarray(out.hit)[:n]
        slots = np.asarray(out.slots)[:n]
        new_vals = np.asarray(out.new_vals)[:n]
        present = np.asarray(out.present)[:n]
        tallies = np.asarray(out.tallies)
        self.batches += 1
        self.lanes += n
        misses = int(tallies[1])
        self.device_hits += int(tallies[0])
        self.device_misses += misses
        # conflict tally (§12): misses in excess of the slots free (or
        # already queued to free) when the batch was adjudicated — each
        # one forces an eviction to admit, the streaming analogue of the
        # serving plane's full-bucket probe conflicts
        free_now = len(self._free) + len(self._pending_drops)
        if misses > free_now:
            self.device_conflicts += misses - free_now
        self.hits += int(tallies[0])
        # shadow advance for hit lanes, vectorized (fp64 order + dirty)
        if hit.any():
            hs = slots[hit]
            hts = ts64[hit]
            cur = self._sts[hs]
            np.maximum.at(self._sts, hs, hts)
            # slots whose ts actually advanced get a fresh generation
            # (unique-slot order, as the sequential loop this replaces)
            adv = np.unique(hs[hts > cur])
            if len(adv):
                self._sgen[adv] = np.arange(
                    self._gen + 1, self._gen + 1 + len(adv))
                self._gen += len(adv)
                self._touched.update(adv.tolist())
            if self.spec.kind != "read":
                upd = hit & ~fire[:n]
                self._sdirty[slots[upd]] = True
            # first read of staged entries: signed lead time (§12)
            first = hs[self._spf_unused[hs]]
            if len(first) and self.recorder is not None:
                for s in np.unique(first):
                    self.recorder.on_used(float(self._sstage_t[s]))
            self._spf_unused[hs] = False
        return BatchResult(hit, present, new_vals, fire[:n])

    def decode_lane(self, res: BatchResult, i: int):
        return self.spec.dec(res.new_vals[i], bool(res.present[i]))
