"""Sharded keyed-state plane for the dataflow engine (DESIGN.md §9).

Keyed state is partitioned into ``n_shards`` hash shards (Flink key groups
/ Megaphone bins): ``shard = hash_partition(key, n_shards)``, and an owner
table maps each shard to the stateful subtask holding its cache + backend
partition.  Channels partition by OWNERSHIP, not by ``hash(key) % p`` —
the routed plane is what lets the upstream hint side channel deliver each
hint to the one subtask whose prefetcher can act on it (a hint landing
anywhere else stages state into a cache no tuple for that key will ever
probe).

Migration (``StatefulOp.migrate_shard``) reassigns a shard between
subtasks with Megaphone-style fluidity: ownership flips immediately (new
traffic routes to the destination and PARKS), the source drains its cache
entries and backend partition, the hot entries ride a modelled bulk
transfer, and the destination re-admits them with preserved timestamps
before replaying everything parked.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

# calibrated migration constants (DESIGN.md §8): one RTT to set up the
# transfer plus state bytes at backbone bandwidth
MIGRATE_RTT = 500e-6
MIGRATE_BANDWIDTH = 1.2e9


def hash_partition(key: Any, n: int) -> int:
    """Canonical key partitioner (also the engine's channel default).

    Window-pane keys (``WindowKey`` — anything exposing ``.base``) hash by
    their BASE key: every pane of a key, and every hint for one, must land
    on the subtask that owns the key itself (DESIGN.md §10)."""
    key = getattr(key, "base", key)
    return hash(key) % n if key is not None else 0


class ShardPlane:
    """Shard ownership + routing state for one stateful operator.

    ``owner[shard]`` is the subtask currently owning the shard; shards in
    ``migrating`` have flipped ownership but their state is still in
    transit, so the new owner parks traffic for them.  Counters are
    per-shard and surfaced by ``Engine.metrics``.
    """

    def __init__(self, n_shards: int, n_owners: int,
                 owners: Optional[List[int]] = None):
        if n_shards < n_owners:
            raise ValueError(f"n_shards={n_shards} < n_owners={n_owners}")
        self.n_shards = n_shards
        self.n_owners = n_owners
        self.owner = list(owners) if owners is not None \
            else [s % n_owners for s in range(n_shards)]
        if len(self.owner) != n_shards or \
                not all(0 <= o < n_owners for o in self.owner):
            raise ValueError("owners must map every shard to a subtask")
        self.migrating: Dict[int, int] = {}     # shard -> destination sub
        # when the last migration LANDED: the checkpoint coordinator keeps
        # deferring triggers for a short quiesce after this, so the tail
        # of stale-partitioned in-flight traffic (forwarded around the
        # flip with no channel origin) drains before any barrier cut
        # (DESIGN.md §7 ∩ §9)
        self.last_finish_t = float("-inf")
        # per-shard counters
        self.hints_routed = [0] * n_shards
        self.tuples_routed = [0] * n_shards
        self.prefetch_hits = [0] * n_shards
        self.migrations = 0
        self.misroutes = 0
        self.parked_in_migration = 0

    # -------------------------------------------------------------- routing
    def shard_of(self, key: Any) -> int:
        return hash_partition(key, self.n_shards)

    def owner_of(self, key: Any) -> int:
        return self.owner[self.shard_of(key)]

    def route_data(self, key: Any, n: int) -> int:
        """Channel partition fn for the data edge into the stateful op."""
        s = self.shard_of(key)
        self.tuples_routed[s] += 1
        return self.owner[s]

    def route_hint(self, key: Any, n: int) -> int:
        """Channel partition fn for the hint side channel: each hint goes
        to the owning shard's prefetcher, never broadcast."""
        s = self.shard_of(key)
        self.hints_routed[s] += 1
        return self.owner[s]

    # ------------------------------------------------------------ migration
    def begin_migration(self, shard: int, dst: int) -> int:
        """Flip ownership (new traffic routes to ``dst`` and parks there);
        returns the previous owner."""
        src = self.owner[shard]
        self.owner[shard] = dst
        self.migrating[shard] = dst
        return src

    def finish_migration(self, shard: int) -> None:
        self.migrating.pop(shard, None)
        self.migrations += 1

    # -------------------------------------------------------------- metrics
    def snapshot(self) -> Dict[str, Any]:
        return {
            "n_shards": self.n_shards,
            "owner": list(self.owner),
            "hints_routed": list(self.hints_routed),
            "tuples_routed": list(self.tuples_routed),
            "prefetch_hits": list(self.prefetch_hits),
            "migrations": self.migrations,
            "misroutes": self.misroutes,
            "parked_in_migration": self.parked_in_migration,
        }

    def registry_sync(self, registry, prefix: str,
                      pending: Optional[Dict[int, list]] = None) -> None:
        """Mirror the routed-plane counters into the metrics registry
        under ``<prefix>.shard.<i>.*`` / ``<prefix>.shards.*``
        (DESIGN.md §12); called by ``Engine._sync_registry`` at
        snapshot/export time."""
        for i in range(self.n_shards):
            registry.counter(f"{prefix}.shard.{i}.hints_routed").set(
                self.hints_routed[i])
            registry.counter(f"{prefix}.shard.{i}.prefetch_hits").set(
                self.prefetch_hits[i])
            registry.gauge(f"{prefix}.shard.{i}.pending").set(
                len(pending.get(i, [])) if pending else 0)
        registry.counter(f"{prefix}.shards.misroutes").set(self.misroutes)
        registry.counter(f"{prefix}.shards.migrations").set(self.migrations)
