"""Sharded checkpointing with async write-out and atomic publication.

Layout per checkpoint:  <dir>/step_<N>/
    manifest.json   tree structure, dtypes/shapes, step, data-pipeline step
    shard_<i>.npz   flattened leaves (one shard per host in multi-host runs;
                    one shard here)

Writes happen on a background thread (the training loop never blocks on
storage — the same off-critical-path discipline as the TAC eviction buffer),
and a checkpoint becomes visible only via atomic rename, so a crash
mid-write can never corrupt the restore point.  ``keep`` bounds retention.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = [(f"leaf_{i}", np.asarray(x)) for i, x in enumerate(leaves)]
    return flat, treedef


class AsyncAtomicWriter:
    """The write discipline shared by the training CheckpointManager and
    the streaming SnapshotStore (DESIGN.md §7): at most ONE background
    write in flight at a time, each write lands in a hidden temp dir and
    is published only via atomic rename — a crash mid-write can never
    corrupt a restore point."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.writes = 0

    def submit(self, final_name: str, write_fn, blocking: bool = False,
               after=None) -> None:
        """``write_fn(tmp_dir)`` fills a temp dir; it is renamed to
        ``final_name`` on success; ``after()`` runs post-publication
        (retention GC hooks)."""
        self.wait()                       # one in-flight write at a time

        def _run():
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
            try:
                write_fn(tmp)
                final = os.path.join(self.dir, final_name)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                if after is not None:
                    after()
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)

        self.writes += 1
        if blocking:
            _run()
        else:
            self._thread = threading.Thread(target=_run, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._writer = AsyncAtomicWriter(directory)
        self.saves = 0

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        # snapshot to host BEFORE handing to the writer thread
        flat, treedef = _flatten(state)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(flat),
            "dtypes": [str(v.dtype) for _, v in flat],
            "extra": extra or {},
        }

        def _write(tmp):
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{k: v for k, v in flat})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)

        self.saves += 1
        self._writer.submit(f"step_{step:08d}", _write, blocking=blocking,
                            after=self._gc)

    def wait(self) -> None:
        self._writer.wait()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[int, Any, Dict]:
        """Restore into the structure of ``template`` (shapes must match).
        Returns (step, state, extra)."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step = steps[-1] if step is None else step
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "shard_0.npz"))
        leaves, treedef = jax.tree_util.tree_flatten(template)
        assert len(leaves) == manifest["n_leaves"], "structure mismatch"
        import jax.numpy as jnp
        import ml_dtypes  # noqa: F401 (registers bfloat16 et al. with numpy)
        dtypes = manifest.get("dtypes")
        new_leaves = []
        for i in range(len(leaves)):
            arr = data[f"leaf_{i}"]
            if dtypes and arr.dtype.kind == "V":
                arr = arr.view(np.dtype(dtypes[i]))   # bf16 roundtrips as V2
            new_leaves.append(jnp.asarray(arr))
        state = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return step, state, manifest.get("extra", {})

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None
