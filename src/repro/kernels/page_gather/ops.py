"""jit'd wrappers: slot-indirect page gather / scatter."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.page_gather.page_gather import (page_gather_kernel,
                                                   page_scatter_kernel)


@partial(jax.jit, static_argnames=("interpret",))
def page_gather(slots, pages, *, interpret: bool = True):
    """slots [N]; pages [n_slots, page, d] -> [N, page, d]."""
    return page_gather_kernel(slots.astype(jnp.int32), pages,
                              interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def page_scatter(slots, blocks, pages, *, interpret: bool = True):
    """pages[slots[i]] = blocks[i]; returns the updated pool."""
    return page_scatter_kernel(slots.astype(jnp.int32), blocks, pages,
                               interpret=interpret)
