"""Pure-jnp oracles for the page gather/scatter kernels."""
import jax.numpy as jnp


def page_gather_ref(slots, pages):
    return pages[slots]


def page_scatter_ref(slots, blocks, pages):
    # .at[].set with duplicate indices is unspecified; enforce last-write-
    # wins explicitly to match the kernel's grid order
    out = pages
    for i in range(slots.shape[0]):
        out = out.at[slots[i]].set(blocks[i])
    return out
