"""Batched page gather/scatter between logical order and physical slots.

The serving arena keeps K/V (or raveled session-state) pages in fixed
physical slots chosen by the device TAC.  Staging N prefetched pages in, or
pulling N eviction victims out for write-back, is one kernel launch each:
the slot ids ride in scalar-prefetch memory and every grid step's BlockSpec
index_map dereferences them, so the copy engine walks the slots without any
per-page Python loop (the same indirection idiom as ``decode_attention``).

Scatter aliases the pool input to its output: untouched slots keep their
bytes, touched slots are overwritten in place.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(slots_ref, pages_ref, out_ref):
    del slots_ref                      # consumed by the index_map
    out_ref[0] = pages_ref[0]


def page_gather_kernel(slots: jax.Array, pages: jax.Array, *,
                       interpret: bool = False) -> jax.Array:
    """slots [N] int32; pages [n_slots, page, d].  Returns [N, page, d]."""
    N = slots.shape[0]
    _, page, d = pages.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, page, d), lambda i, s: (s[i], 0, 0))],
        out_specs=pl.BlockSpec((1, page, d), lambda i, s: (i, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, page, d), pages.dtype),
        interpret=interpret,
    )(slots, pages)


def _scatter_kernel(slots_ref, blocks_ref, pages_ref, out_ref):
    del slots_ref, pages_ref           # pool arrives via the output alias
    out_ref[0] = blocks_ref[0]


def page_scatter_kernel(slots: jax.Array, blocks: jax.Array,
                        pages: jax.Array, *,
                        interpret: bool = False) -> jax.Array:
    """slots [N] int32; blocks [N, page, d]; pages [n_slots, page, d].
    Returns the pool with ``pages[slots[i]] = blocks[i]`` (last write wins
    on duplicate slots, matching grid order)."""
    N = slots.shape[0]
    n_slots, page, d = pages.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, page, d), lambda i, s: (i, 0, 0)),
            pl.BlockSpec((1, page, d), lambda i, s: (s[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, page, d), lambda i, s: (s[i], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_slots, page, d), pages.dtype),
        input_output_aliases={2: 0},   # pool (post-prefetch input 1) -> out
        interpret=interpret,
    )(slots, blocks, pages)
