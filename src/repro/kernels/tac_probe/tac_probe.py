"""Batched TAC probe+gather as a Pallas TPU kernel.

The device-resident Timestamp-Aware Cache stores state rows in fixed slots
organised as (n_buckets x ways); a batch of state-access keys is probed in
one kernel launch: each grid step loads ONE bucket (ways keys + the ways x D
value block) into VMEM via a scalar-prefetched bucket index, compares the
ways keys on the VPU, and emits (value_row, hit, way).  This is the
serving-side analogue of the paper's hash-map + gather hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(buckets_ref, qkeys_ref, bkeys_ref, bvals_ref,
            out_ref, hit_ref, way_ref, *, ways: int, D: int):
    b = pl.program_id(0)
    qk = qkeys_ref[b]
    keys = bkeys_ref[0]                                  # [ways]
    match = keys == qk                                   # [ways] bool
    hit = jnp.any(match)
    way = jnp.argmax(match)                              # first match
    vals = bvals_ref[0]                                  # [ways, D]
    sel = jnp.where(match[:, None], vals.astype(jnp.float32), 0.0)
    row = sel.sum(axis=0)                                # matched row or 0
    out_ref[0] = row.astype(out_ref.dtype)
    hit_ref[0] = hit.astype(jnp.int32)
    way_ref[0] = jnp.where(hit, way, -1).astype(jnp.int32)


def tac_probe_kernel(qkeys: jax.Array, buckets: jax.Array,
                     bucket_keys: jax.Array, bucket_vals: jax.Array, *,
                     interpret: bool = False):
    """qkeys [B] int32; buckets [B] int32 (hash(qkey) % n_buckets, computed
    by the caller); bucket_keys [n_buckets, ways] int32 (-1 = empty);
    bucket_vals [n_buckets, ways, D].  Returns (values [B, D], hit [B],
    way [B])."""
    B = qkeys.shape[0]
    n_buckets, ways = bucket_keys.shape
    D = bucket_vals.shape[-1]

    kern = functools.partial(_kernel, ways=ways, D=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, ways), lambda b, bk, qk: (bk[b], 0)),
            pl.BlockSpec((1, ways, D), lambda b, bk, qk: (bk[b], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, D), lambda b, bk, qk: (b, 0)),
            pl.BlockSpec((1,), lambda b, bk, qk: (b,)),
            pl.BlockSpec((1,), lambda b, bk, qk: (b,)),
        ],
        scratch_shapes=[],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, D), bucket_vals.dtype),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(buckets, qkeys, bucket_keys, bucket_vals)
