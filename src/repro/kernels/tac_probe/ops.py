"""jit'd wrapper: hash, probe, gather."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.page_gather.page_gather import page_gather_kernel
from repro.kernels.tac_probe.tac_probe import tac_probe_kernel

_A, _B, _P = 2654435761, 40503, 2 ** 31 - 1


def bucket_of(keys: jax.Array, n_buckets: int) -> jax.Array:
    h = (keys.astype(jnp.uint32) * jnp.uint32(_A)) ^ jnp.uint32(_B)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def tac_probe(qkeys, bucket_keys, bucket_vals, *, interpret: bool = True):
    buckets = bucket_of(qkeys, bucket_keys.shape[0])
    return tac_probe_kernel(qkeys.astype(jnp.int32), buckets,
                            bucket_keys, bucket_vals, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def tac_probe_counted(qkeys, bucket_keys, bucket_vals, *,
                      interpret: bool = True):
    """Probe + device-side tallies for the observability plane
    (DESIGN.md §12): returns ``(values, hit, way, counts)`` where
    ``counts`` is an int32 ``[2]`` vector of (n_hit, n_conflict) reduced
    on device in the same launch — a CONFLICT is a miss whose bucket is
    already full, i.e. admitting the key would evict.  One device->host
    transfer surfaces both tallies instead of a host-side scan of the
    per-query hit vector."""
    buckets = bucket_of(qkeys, bucket_keys.shape[0])
    vals, hit, way = tac_probe_kernel(qkeys.astype(jnp.int32), buckets,
                                      bucket_keys, bucket_vals,
                                      interpret=interpret)
    full = jnp.all(bucket_keys[buckets] != -1, axis=1)
    miss = hit == 0
    counts = jnp.stack([hit.sum(), (miss & full).sum()]).astype(jnp.int32)
    return vals, hit, way, counts


@partial(jax.jit, static_argnames=("interpret",))
def tac_probe_gather(qkeys, bucket_keys, bucket_vals, pages, *,
                     interpret: bool = True):
    """Composed probe -> page gather (DESIGN.md §14): the directory probe
    and the payload pull run in ONE traced program instead of two island
    launches — the probe's (bucket, way) resolves to a flat slot id that
    feeds ``page_gather_kernel``'s scalar-prefetch index_map directly.

    ``pages`` is ``[n_slots + 1, page, d]``: the LAST row is a zeroed
    scratch slot that miss lanes alias, so their gathered rows decode as
    "absent" without any host-side masking.  Returns
    ``(rows [B, page, d], hit [B] bool, slots [B] int32 flat)``.
    """
    n_buckets, ways = bucket_keys.shape
    buckets = bucket_of(qkeys, n_buckets)
    _, hit, way = tac_probe_kernel(qkeys.astype(jnp.int32), buckets,
                                   bucket_keys, bucket_vals,
                                   interpret=interpret)
    hit = hit.astype(bool)
    trash = pages.shape[0] - 1
    slots = jnp.where(hit, buckets * ways + jnp.maximum(way, 0),
                      trash).astype(jnp.int32)
    rows = page_gather_kernel(slots, pages, interpret=interpret)
    return rows, hit, slots
