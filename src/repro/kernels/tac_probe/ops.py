"""jit'd wrapper: hash, probe, gather."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.tac_probe.tac_probe import tac_probe_kernel

_A, _B, _P = 2654435761, 40503, 2 ** 31 - 1


def bucket_of(keys: jax.Array, n_buckets: int) -> jax.Array:
    h = (keys.astype(jnp.uint32) * jnp.uint32(_A)) ^ jnp.uint32(_B)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("interpret",))
def tac_probe(qkeys, bucket_keys, bucket_vals, *, interpret: bool = True):
    buckets = bucket_of(qkeys, bucket_keys.shape[0])
    return tac_probe_kernel(qkeys.astype(jnp.int32), buckets,
                            bucket_keys, bucket_vals, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def tac_probe_counted(qkeys, bucket_keys, bucket_vals, *,
                      interpret: bool = True):
    """Probe + device-side tallies for the observability plane
    (DESIGN.md §12): returns ``(values, hit, way, counts)`` where
    ``counts`` is an int32 ``[2]`` vector of (n_hit, n_conflict) reduced
    on device in the same launch — a CONFLICT is a miss whose bucket is
    already full, i.e. admitting the key would evict.  One device->host
    transfer surfaces both tallies instead of a host-side scan of the
    per-query hit vector."""
    buckets = bucket_of(qkeys, bucket_keys.shape[0])
    vals, hit, way = tac_probe_kernel(qkeys.astype(jnp.int32), buckets,
                                      bucket_keys, bucket_vals,
                                      interpret=interpret)
    full = jnp.all(bucket_keys[buckets] != -1, axis=1)
    miss = hit == 0
    counts = jnp.stack([hit.sum(), (miss & full).sum()]).astype(jnp.int32)
    return vals, hit, way, counts
