"""Pure-jnp oracle for the TAC probe kernel."""
import jax.numpy as jnp


def tac_probe_ref(qkeys, buckets, bucket_keys, bucket_vals):
    keys = bucket_keys[buckets]                    # [B, ways]
    vals = bucket_vals[buckets]                    # [B, ways, D]
    match = keys == qkeys[:, None]
    hit = match.any(axis=1)
    way = jnp.where(hit, jnp.argmax(match, axis=1), -1)
    out = jnp.where(match[..., None], vals.astype(jnp.float32), 0.0) \
        .sum(axis=1)
    return out.astype(bucket_vals.dtype), hit.astype(jnp.int32), \
        way.astype(jnp.int32)
