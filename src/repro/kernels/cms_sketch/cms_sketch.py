"""Batched Count-Min Sketch update/estimate as a Pallas TPU kernel.

The lookahead operator's hint extractor classifies a BATCH of keys per step
on device: the counter matrix row lives in VMEM, the per-key column indices
(hashes, computed on the VPU outside) arrive via scalar prefetch, and the
sequential in-batch loop preserves exact duplicate-key accumulation —
matching the streaming oracle bit-for-bit (saturating counters included).

Grid: one step per sketch row; the row's [1, w] counter block is updated in
place via input/output aliasing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cols_ref, counters_ref, out_counters_ref, est_ref, *,
            batch: int, max_count: int):
    r = pl.program_id(0)
    out_counters_ref[...] = counters_ref[...]

    def body(i, _):
        c = cols_ref[r, i]
        v = out_counters_ref[0, c]
        v_new = jnp.minimum(v + 1, max_count)
        out_counters_ref[0, c] = v_new
        est_ref[0, i] = v_new
        return 0

    jax.lax.fori_loop(0, batch, body, 0)


def cms_update_kernel(cols: jax.Array, counters: jax.Array, *,
                      max_count: int = 255, interpret: bool = False):
    """cols [d, B] int32 (precomputed hash columns per row); counters [d, w]
    int32.  Returns (new_counters [d, w], est [d, B]) where est is each
    key's counter value AFTER its increment (min over rows done outside)."""
    d, B = cols.shape
    _, w = counters.shape
    kern = functools.partial(_kernel, batch=B, max_count=max_count)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(d,),
        in_specs=[pl.BlockSpec((1, w), lambda r, cols_p: (r, 0))],
        out_specs=[
            pl.BlockSpec((1, w), lambda r, cols_p: (r, 0)),
            pl.BlockSpec((1, B), lambda r, cols_p: (r, 0)),
        ],
        scratch_shapes=[],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((d, w), counters.dtype),
            jax.ShapeDtypeStruct((d, B), jnp.int32),
        ],
        interpret=interpret,
    )(cols, counters)
