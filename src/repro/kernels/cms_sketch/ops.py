"""jit'd wrapper: hash keys -> columns, run kernel, classify hot keys.

Device hashing uses natural uint32 multiply-shift wraparound (x64 is
unavailable on device by default); the host CountMinFilter uses prime-mod
hashing — the two sketches share SEMANTICS (saturating counters, aging,
all-rows >= T classification), not hash values, and each is validated
against its own oracle."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.cms_sketch.cms_sketch import cms_update_kernel


def columns_for(keys: jax.Array, a: jax.Array, b: jax.Array,
                width: int) -> jax.Array:
    """keys [B] -> cols [d, B] via uint32 multiply-shift wraparound."""
    k = keys.astype(jnp.uint32)
    h = a[:, None].astype(jnp.uint32) * k[None, :] \
        + b[:, None].astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    return (h % jnp.uint32(width)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("threshold", "max_count", "interpret"))
def cms_update_and_classify(keys, counters, a, b, *, threshold: int = 20,
                            max_count: int = 255, interpret: bool = True):
    """Batched equivalent of CountMinFilter.update_and_classify (no aging;
    the caller right-shifts ``counters`` every aging interval).
    Returns (new_counters, hot [B] bool)."""
    cols = columns_for(keys, a, b, counters.shape[1])
    new_counters, est = cms_update_kernel(cols, counters,
                                          max_count=max_count,
                                          interpret=interpret)
    hot = (est >= threshold).all(axis=0)
    return new_counters, hot
