"""Pure-jnp/numpy oracle for the CMS kernel (sequential semantics)."""
import numpy as np


def cms_update_ref(cols, counters, max_count=255):
    """cols [d,B]; counters [d,w].  Sequential per-row accumulation with
    saturating counters; returns (new_counters, est [d,B])."""
    counters = np.array(counters, copy=True)
    d, B = cols.shape
    est = np.zeros((d, B), dtype=np.int32)
    for r in range(d):
        for i in range(B):
            c = cols[r, i]
            counters[r, c] = min(counters[r, c] + 1, max_count)
            est[r, i] = counters[r, c]
    return counters, est
