"""Sequential-oracle for the SSD chunk scan: plain per-step recurrence."""
import jax.numpy as jnp


def mamba2_scan_ref(x, dt, A, Bm, Cm):
    """x [BH,S,P]; dt [BH,S]; A [BH]; Bm/Cm [BH,S,N].  y[t] = C_t . S_t with
    S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T (outer)."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * A.astype(jnp.float32))       # [BH]
        state = state * decay[:, None, None] \
            + (dtt[:, None] * bt)[..., :, None] * xt[..., None, :]
        y = jnp.einsum("bn,bnp->bp", ct, state)
        return state, y

    s0 = jnp.zeros((BH, N, P), jnp.float32)
    _, ys = jnp.swapaxes(xf, 0, 1), None
    import jax
    _, ys = jax.lax.scan(
        step, s0, (jnp.swapaxes(xf, 0, 1), jnp.swapaxes(dtf, 0, 1),
                   jnp.swapaxes(Bf, 0, 1), jnp.swapaxes(Cf, 0, 1)))
    return jnp.swapaxes(ys, 0, 1).astype(x.dtype)
