"""jit'd wrapper for the SSD chunk-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba2_scan.mamba2_scan import mamba2_scan_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_scan(x, dt, A, Bm, Cm, *, chunk: int = 64,
                interpret: bool = True):
    return mamba2_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk,
                              interpret=interpret)
