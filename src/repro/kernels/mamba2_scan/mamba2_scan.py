"""Chunked Mamba2 SSD scan as a Pallas TPU kernel.

Hot spot of zamba2's ``train_4k``/``prefill_32k`` cells: chunk-local
quadratic work runs on the MXU while the [N, P] recurrent state stays in
VMEM scratch across the sequential chunk grid dimension (the pure-JAX
version writes it to HBM every chunk).

Grid: (B*H, n_chunks).  Per head the decay A[h] arrives via scalar
prefetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *,
            Q: int, N: int, P: int):
    bh = pl.program_id(0)
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    A = a_ref[bh]                                        # scalar (negative)
    x = x_ref[0].astype(jnp.float32)                     # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)                   # [Q, 1] -> [Q]
    dt = dt[:, 0]
    Bm = b_ref[0].astype(jnp.float32)                    # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                    # [Q, N]

    dA = dt * A                                          # [Q] <= 0
    cum = jnp.cumsum(dA)                                 # [Q]
    dtx = dt[:, None] * x                                # [Q, P]

    # intra-chunk quadratic part
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    diff = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    ldec = jnp.where(iota_i >= iota_j, diff, -jnp.inf)
    M = CB * jnp.exp(ldec)
    y = jax.lax.dot_general(M, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk contribution from the carried state
    y += jax.lax.dot_general(Cm, state_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S <- S * exp(sum dA) + sum_j exp(cum_Q - cum_j) B_j dtx_j
    w = jnp.exp(cum[-1] - cum)                           # [Q]
    s_loc = jax.lax.dot_general(Bm * w[:, None], dtx,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [N, P]
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + s_loc


def mamba2_scan_kernel(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bm: jax.Array, Cm: jax.Array, *, chunk: int = 64,
                       interpret: bool = False) -> jax.Array:
    """x [BH, S, P]; dt [BH, S]; A [BH] (negative); Bm/Cm [BH, S, N]
    (groups already broadcast to heads).  Returns y [BH, S, P]."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    dt2 = dt[..., None]                                  # [BH, S, 1]

    kern = functools.partial(_kernel, Q=Q, N=N, P=P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda b, c, a: (b, c, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, c, a: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c, a: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c, a: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, P), lambda b, c, a: (b, c, 0)),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        interpret=interpret,
    )(A.astype(jnp.float32), x, dt2, Bm, Cm)
