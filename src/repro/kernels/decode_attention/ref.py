"""Pure-jnp oracle for paged decode attention."""
import math

import jax.numpy as jnp


def paged_decode_ref(q, k_pages, v_pages, page_table, seq_lens):
    """q [B,H,d]; pages [n_slots,page,d*]; page_table [B,P]; seq_lens [B]."""
    B, H, d = q.shape
    page = k_pages.shape[1]
    P = page_table.shape[1]
    # gather logical KV [B, P*page, d]
    k = k_pages[page_table].reshape(B, P * page, -1).astype(jnp.float32)
    v = v_pages[page_table].reshape(B, P * page, -1).astype(jnp.float32)
    s = jnp.einsum("bhd,btd->bht", q.astype(jnp.float32), k) / math.sqrt(d)
    valid = jnp.arange(P * page)[None, :] < seq_lens[:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bht,btd->bhd", p, v).astype(q.dtype)
