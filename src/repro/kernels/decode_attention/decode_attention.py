"""Paged single-token GQA decode attention (PagedAttention adapted to TPU).

The serving-side hot spot for ``decode_32k`` / ``long_500k``: one query token
attends over a long KV history stored as fixed-size PAGES whose physical
slots are assigned by the Timestamp-Aware Cache (repro.core.tac_jax).  The
page table rides in scalar-prefetch memory so each grid step's BlockSpec
index_map dereferences it — the kernel reads only resident pages, in page
order, with online-softmax accumulation in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table_ref, lens_ref, q_ref, kp_ref, vp_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, pages_per_seq: int):
    b = pl.program_id(0)
    pi = pl.program_id(1)
    npg = pl.num_programs(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lens_ref[b]

    @pl.when(pi * page < seq_len)
    def _body():
        q = q_ref[0].astype(jnp.float32)                 # [H, d]
        k = kp_ref[0].astype(jnp.float32)                # [page, d]
        v = vp_ref[0].astype(jnp.float32)                # [page, dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s / math.sqrt(q.shape[-1])                   # [H, page]
        pos = pi * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(pi == npg - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_kernel(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array, page_table: jax.Array,
                                  seq_lens: jax.Array, *,
                                  interpret: bool = False) -> jax.Array:
    """q [B, H, d]; k_pages/v_pages [n_slots, page, d*]; page_table
    [B, pages_per_seq] physical slot ids; seq_lens [B].  Returns [B, H, dv].
    """
    B, H, d = q.shape
    n_slots, page, _ = k_pages.shape
    dv = v_pages.shape[-1]
    pages_per_seq = page_table.shape[1]

    kern = functools.partial(_kernel, page=page, pages_per_seq=pages_per_seq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, H, d), lambda b, pi, pt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page, d), lambda b, pi, pt, ln: (pt[b, pi],
                                                              0, 0)),
            pl.BlockSpec((1, page, dv), lambda b, pi, pt, ln: (pt[b, pi],
                                                               0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, dv), lambda b, pi, pt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dv), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)
