"""jit'd wrapper: GQA paged decode attention with head broadcasting."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import \
    paged_decode_attention_kernel


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, seq_lens, *,
                           interpret: bool = True):
    """q [B,H,d] (single token per sequence); pages [slots, page, d*]."""
    return paged_decode_attention_kernel(
        q, k_pages, v_pages, page_table.astype(jnp.int32),
        seq_lens.astype(jnp.int32), interpret=interpret)
