"""RWKV6 (Finch) data-dependent-decay recurrence as a Pallas TPU kernel.

Hot spot of rwkv6-3b's train/prefill cells: the [N, N] per-head state stays
in VMEM scratch across the sequential chunk grid while each timestep's rank-1
update and readout run on the VPU/MXU.

Grid: (B*H, n_chunks); per-head bonus u arrives as a [BH, N] input block.
Recurrence per step t (head dim N):
    y_t = r_t . (S + (u * k_t) v_t^T)
    S   = diag(w_t) S + k_t v_t^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_ref, *,
            Q: int, N: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)                     # [Q, N]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)                     # decay in (0,1)
    u = u_ref[0].astype(jnp.float32)                     # [N]

    def step(t, carry):
        S = carry
        rt = jax.lax.dynamic_slice(r, (t, 0), (1, N))    # [1, N]
        kt = jax.lax.dynamic_slice(k, (t, 0), (1, N))
        vt = jax.lax.dynamic_slice(v, (t, 0), (1, N))
        wt = jax.lax.dynamic_slice(w, (t, 0), (1, N))
        kv = kt.T * vt                                   # [N, N] rank-1
        y = jax.lax.dot_general(rt, S + u[:, None] * kv,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [1,N]
        y_ref[0, t, :] = y[0].astype(y_ref.dtype)
        return wt.T * S + kv

    state_ref[...] = jax.lax.fori_loop(0, Q, step, state_ref[...])


def rwkv6_scan_kernel(r: jax.Array, k: jax.Array, v: jax.Array,
                      w: jax.Array, u: jax.Array, *, chunk: int = 64,
                      interpret: bool = False) -> jax.Array:
    """r/k/v/w [BH, S, N]; u [BH, N].  Returns y [BH, S, N]."""
    BH, S, N = r.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    kern = functools.partial(_kernel, Q=Q, N=N)
    return pl.pallas_call(
        kern,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, N), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, N), r.dtype),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
