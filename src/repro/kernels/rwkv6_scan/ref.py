"""Sequential oracle for the RWKV6 recurrence (mirrors repro.models.ssm)."""
import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u):
    """r/k/v/w [BH,S,N]; u [BH,N] -> y [BH,S,N]."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                             # [BH, N]
        kv = kt[..., :, None] * vt[..., None, :]         # [BH, N, N]
        y = jnp.einsum("bi,bij->bj", rt, S + uf[..., :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    BH, S, N = r.shape
    s0 = jnp.zeros((BH, N, N), jnp.float32)
    _, ys = jax.lax.scan(step, s0, tuple(jnp.swapaxes(t, 0, 1)
                                         for t in (rf, kf, vf, wf)))
    return jnp.swapaxes(ys, 0, 1).astype(r.dtype)
