"""jit'd wrapper for the RWKV6 scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6_scan.rwkv6_scan import rwkv6_scan_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    return rwkv6_scan_kernel(r, k, v, w, u, chunk=chunk,
                             interpret=interpret)
