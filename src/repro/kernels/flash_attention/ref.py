"""Pure-jnp oracle for the flash attention kernel."""
import math

import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    """q [BH,S,d]; k/v [BH,T,d*] -> [BH,S,dv] (fp32 math)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
