"""Blocked online-softmax attention (FlashAttention) as a Pallas TPU kernel.

This is the compute hot spot of the ``prefill_32k`` / ``train_4k`` cells: the
pure-JAX blocked attention in ``repro.models.layers`` spills its (m, l, o)
accumulators to HBM every KV block (visible as the dominant fusion traffic in
the dry-run §Roofline); this kernel keeps them in VMEM scratch.

Grid: (batch*heads, n_q_blocks, n_kv_blocks); the innermost KV dimension is
sequential on TPU, so the scratch accumulators persist across the KV blocks
of one (head, q_block).  Causal blocks above the diagonal are skipped with
pl.when (no MXU work issued for them).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0].astype(jnp.float32)                 # [bq, d]
        k = k_ref[0].astype(jnp.float32)                 # [bk, d]
        v = v_ref[0].astype(jnp.float32)                 # [bk, dv]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip blocks entirely above the causal diagonal
        pl.when((qi + 1) * bq - 1 >= ki * bk)(body)
    else:
        body()

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = 256, bk: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q [BH, S, d]; k/v [BH, T, d*] (kv heads already broadcast to q heads).
    Returns [BH, S, dv]."""
    BH, S, d = q.shape
    T = k.shape[1]
    dv = v.shape[2]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(d)

    kern = functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                             bk=bk)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
