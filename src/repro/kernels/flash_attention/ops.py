"""jit'd public wrapper: GQA-aware flash attention.

``flash_attention(q, k, v)`` with q [B,S,H,d], k/v [B,T,KV,d*] broadcasts KV
heads to query heads, flattens (B, H) into the kernel's grid dim and restores
the layout.  On non-TPU backends (or interpret=True) the kernel body runs in
interpret mode — same code path the tests validate.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, bq: int = 256, bk: int = 256,
                    interpret: bool = True) -> jax.Array:
    B, S, H, d = q.shape
    T, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, dv)
    o = flash_attention_kernel(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)
    return o.reshape(B, H, S, dv).transpose(0, 2, 1, 3)
