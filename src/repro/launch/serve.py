"""Serving driver: batched multi-turn LM serving with Keyed Prefetching of
session state (the paper's technique adapted to the TPU serving stack,
DESIGN.md §2).

Sessions' KV caches live in a slow SESSION STORE (disaggregated, modelled
latency).  Requests queue at the worker; the INGEST stage (the lookahead
operator) sees each request's session key the moment it is enqueued and
hints the prefetcher, which stages the session state into the device-side
cache (Timestamp-Aware policy) while the request waits — so when the worker
picks it up, decode starts immediately.  The baseline stages on demand
(state I/O on the critical path).

    PYTHONPATH=src python -m repro.launch.serve --requests 48
"""
from __future__ import annotations

import argparse
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.tac import TimestampAwareCache
from repro.models.lm import build_model


@dataclass
class ServeConfig:
    arch: str = "gemma-7b"
    n_sessions: int = 24
    n_requests: int = 48
    prompt_len: int = 32
    decode_tokens: int = 4
    store_latency: float = 0.050      # session restore from remote store
    cache_sessions: int = 8           # device cache capacity (sessions)
    arrival_gap: float = 0.010


class SessionStore:
    """Disaggregated session-state store with modelled restore latency."""

    def __init__(self, latency: float):
        self.data: Dict[int, Any] = {}
        self.latency = latency
        self.reads = 0

    def load(self, sid: int):
        time.sleep(self.latency)
        self.reads += 1
        return self.data.get(sid)

    def store(self, sid: int, state) -> None:
        self.data[sid] = state


class Prefetcher:
    """State thread pool: drains the hint queue with N workers, staging
    sessions into the TAC (the paper's asynchronous State Thread Pool)."""

    def __init__(self, store: SessionStore, cache: TimestampAwareCache,
                 workers: int = 4):
        self.store = store
        self.cache = cache
        self.hints = deque()
        self.lock = threading.Lock()
        self.in_flight = set()
        self.stop_flag = False
        self.prefetched = 0
        self.threads = [threading.Thread(target=self._run, daemon=True)
                        for _ in range(workers)]

    def start(self) -> None:
        for t in self.threads:
            t.start()

    def hint(self, sid: int, ts: float) -> None:
        with self.lock:
            self.hints.append((sid, ts))

    def _run(self) -> None:
        while not self.stop_flag:
            with self.lock:
                item = self.hints.popleft() if self.hints else None
                if item is not None:
                    sid, ts = item
                    if sid in self.in_flight:
                        item = None
                    else:
                        self.in_flight.add(sid)
            if item is None:
                time.sleep(0.0005)
                continue
            sid, ts = item
            if self.cache.contains(sid):
                self.cache.renew(sid, ts)
                with self.lock:
                    self.in_flight.discard(sid)
                continue
            state = self.store.load(sid)
            with self.lock:
                if state is not None:
                    self.cache.insert(sid, state, ts, prefetched=True)
                    self.prefetched += 1
                self.in_flight.discard(sid)


def run_serving(cfg: ServeConfig, prefetch: bool, seed: int = 0
                ) -> Dict[str, float]:
    scfg = get_smoke_config(cfg.arch)
    model = build_model(scfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)
    rng = np.random.RandomState(seed)

    store = SessionStore(cfg.store_latency)
    cache = TimestampAwareCache(capacity=cfg.cache_sessions)
    pf = Prefetcher(store, cache)
    if prefetch:
        pf.start()

    # seed sessions: each has a history KV cache persisted in the store
    T = cfg.prompt_len + cfg.decode_tokens + 8
    for sid in range(cfg.n_sessions):
        toks = jnp.asarray(rng.randint(0, scfg.vocab_size,
                                       (1, cfg.prompt_len)), jnp.int32)
        _, kv = prefill(params, {"tokens": toks})

        def grow(a):
            # pad the KV time axis (== prompt_len) up to T decode slots
            if hasattr(a, "ndim") and a.ndim >= 3 and a.dtype != jnp.int32:
                for ax in range(a.ndim):
                    if a.shape[ax] == cfg.prompt_len:
                        pw = [(0, 0)] * a.ndim
                        pw[ax] = (0, T - cfg.prompt_len)
                        return jnp.pad(a, pw)
            return a

        store.store(sid, jax.tree.map(grow, kv))

    # warm the jitted decode path (compile outside the measurement)
    warm_kv = store.data[0]
    decode(params, warm_kv,
           {"tokens": jnp.asarray([[1]], jnp.int32),
            "pos": jnp.int32(cfg.prompt_len)})[0].block_until_ready()

    # request stream
    requests = [(i, int(rng.randint(0, cfg.n_sessions)))
                for i in range(cfg.n_requests)]
    queue: deque = deque()
    ttfts: List[float] = []
    t_arrive: Dict[int, float] = {}

    def worker_step():
        rid, sid = queue.popleft()
        kv = cache.lookup(sid, time.time())
        if kv is None:                      # demand staging (critical path)
            kv = store.load(sid)
            cache.insert(sid, kv, time.time())
        pos = jnp.int32(cfg.prompt_len)
        tok = jnp.asarray([[1]], jnp.int32)
        logits, kv = decode(params, kv, {"tokens": tok, "pos": pos})
        logits.block_until_ready()
        ttfts.append(time.time() - t_arrive[rid])
        cache.write(sid, kv, time.time())

    for rid, sid in requests:
        t_arrive[rid] = time.time()
        queue.append((rid, sid))
        if prefetch:                        # ingest = lookahead operator
            pf.hint(sid, time.time() + 1.0)
        time.sleep(cfg.arrival_gap)
        while len(queue) > 2:               # worker drains under backlog
            worker_step()
    while queue:
        worker_step()

    pf.stop_flag = True
    lat = np.asarray(ttfts)
    return {"p50": float(np.percentile(lat, 50)),
            "p99": float(np.percentile(lat, 99)),
            "mean": float(lat.mean()),
            "store_reads": store.reads,
            "prefetched": pf.prefetched,
            "hit_rate": cache.hit_rate}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--sessions", type=int, default=24)
    args = ap.parse_args()
    cfg = ServeConfig(arch=args.arch, n_requests=args.requests,
                      n_sessions=args.sessions)
    base = run_serving(cfg, prefetch=False)
    kp = run_serving(cfg, prefetch=True)
    print(f"[serve] baseline   p50={base['p50']*1e3:.1f}ms "
          f"p99={base['p99']*1e3:.1f}ms hit={base['hit_rate']:.2f}")
    print(f"[serve] prefetch   p50={kp['p50']*1e3:.1f}ms "
          f"p99={kp['p99']*1e3:.1f}ms hit={kp['hit_rate']:.2f} "
          f"(prefetched {kp['prefetched']})")
    print(f"[serve] TTFT p50 speedup {base['p50']/kp['p50']:.2f}x, "
          f"p99 {base['p99']/kp['p99']:.2f}x")


if __name__ == "__main__":
    main()
