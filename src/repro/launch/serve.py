"""Serving driver: continuous-batching LM serving over the paged
session-state subsystem (``repro.serving``, DESIGN.md §2/§6).

Sessions' KV caches are RAVELED INTO FIXED-SIZE PAGES and persisted in the
tiered session store; the device-resident arena (TAC page table + physical
page pool) holds the working set.  The scheduler's ingest stage sees each
request's session key at enqueue time — the paper's upstream-lookahead role
— and in ``prefetch`` mode hints the store, which stages the session's
pages toward the arena while the request queues.  The ``sync`` baseline
stages on demand (state I/O on the critical path); ``async`` overlaps I/O
but has no lookahead window.

Decode compute is REAL (jitted smoke model); store I/O is modelled by the
calibrated backend latencies on a virtual clock that the measured compute
also advances — so TTFT/TPOT mix real compute with modelled staging, and a
full sweep runs in seconds (pass ``--wall-clock`` for live timing).

    PYTHONPATH=src python -m repro.launch.serve --requests 48
"""
from __future__ import annotations

import argparse
import math
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.lm import build_model
from repro.serving import (ContinuousBatchingScheduler, PagedStateArena,
                           Request, ServingMetrics, SimClock, TieredStore,
                           WallClock)
from repro.streaming.backend import BackendModel

PAGE_KEY_STRIDE = 4096     # page key = sid * stride + page_idx + 1


@dataclass
class ServeConfig:
    arch: str = "gemma-7b"
    n_sessions: int = 24
    n_requests: int = 48
    prompt_len: int = 32
    decode_tokens: int = 4
    cache_sessions: int = 8            # arena capacity (sessions)
    page_elems: int = 8192             # fp32 elements per state page
    arrival_rate: float = 400.0        # offered load, requests/s
    max_batch: int = 4
    store_latency: float = 0.012       # backing-tier base latency (s)
    store_bandwidth: float = 1.2e9
    wall_clock: bool = False


class StatePager:
    """Ravel the float leaves of a KV-cache pytree into fixed-size pages
    (and back).  Non-float leaves (decode position) ride as aux state."""

    def __init__(self, example: Any, page_elems: int):
        leaves, self.treedef = jax.tree.flatten(example)
        self.is_float = [jnp.issubdtype(l.dtype, jnp.floating)
                         for l in leaves]
        self.shapes = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.sizes = [int(np.prod(s)) if f else 0
                      for s, f in zip(self.shapes, self.is_float)]
        self.total = sum(self.sizes)
        self.page_elems = page_elems
        self.n_pages = max(1, math.ceil(self.total / page_elems))

    def to_pages(self, kv: Any) -> Tuple[jax.Array, List[jax.Array]]:
        leaves = jax.tree.leaves(kv)
        flat = jnp.concatenate(
            [l.astype(jnp.float32).ravel()
             for l, f in zip(leaves, self.is_float) if f])
        flat = jnp.pad(flat, (0, self.n_pages * self.page_elems - self.total))
        pages = flat.reshape(self.n_pages, self.page_elems, 1)
        aux = [l for l, f in zip(leaves, self.is_float) if not f]
        return pages, aux

    def from_pages(self, pages: jax.Array, aux: List[jax.Array]) -> Any:
        flat = pages.reshape(-1)[:self.total]
        leaves, off, ai = [], 0, 0
        for f, shape, dtype, size in zip(self.is_float, self.shapes,
                                         self.dtypes, self.sizes):
            if f:
                leaves.append(flat[off:off + size].reshape(shape)
                              .astype(dtype))
                off += size
            else:
                leaves.append(aux[ai])
                ai += 1
        return jax.tree.unflatten(self.treedef, leaves)


def page_keys(sid: int, n_pages: int) -> np.ndarray:
    assert n_pages < PAGE_KEY_STRIDE
    return np.asarray([sid * PAGE_KEY_STRIDE + p + 1
                       for p in range(n_pages)], np.int32)


def _grow_kv(kv: Any, prompt_len: int, T: int) -> Any:
    """Pad the KV time axis (== prompt_len) up to T decode slots."""
    def grow(a):
        if hasattr(a, "ndim") and a.ndim >= 3 and a.dtype != jnp.int32:
            for ax in range(a.ndim):
                if a.shape[ax] == prompt_len:
                    pw = [(0, 0)] * a.ndim
                    pw[ax] = (0, T - prompt_len)
                    return jnp.pad(a, pw)
        return a
    return jax.tree.map(grow, kv)


def run_serving(cfg: ServeConfig, mode: str, seed: int = 0
                ) -> Dict[str, float]:
    """Serve ``n_requests`` multi-turn requests in the given mode and return
    the metrics summary.  The arrival schedule is derived from (seed,
    arrival_rate) only, so different modes face EQUAL offered load."""
    scfg = get_smoke_config(cfg.arch)
    model = build_model(scfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    prefill = jax.jit(model.prefill)
    rng = np.random.RandomState(seed)

    T = cfg.prompt_len + cfg.decode_tokens + 8

    # ---- session histories -> pages in the backing tier
    toks = jnp.asarray(rng.randint(0, scfg.vocab_size,
                                   (1, cfg.prompt_len)), jnp.int32)
    _, kv0 = prefill(params, {"tokens": toks})
    kv0 = _grow_kv(kv0, cfg.prompt_len, T)
    pager = StatePager(kv0, cfg.page_elems)

    backing = BackendModel("session-store", cfg.store_latency,
                           cfg.store_bandwidth, parallelism=32)
    store = TieredStore(backing_model=backing,
                        page_bytes=cfg.page_elems * 4, workers=8)
    session_aux: Dict[int, List[jax.Array]] = {}
    for sid in range(cfg.n_sessions):
        toks = jnp.asarray(rng.randint(0, scfg.vocab_size,
                                       (1, cfg.prompt_len)), jnp.int32)
        _, kv = prefill(params, {"tokens": toks})
        pages, aux = pager.to_pages(_grow_kv(kv, cfg.prompt_len, T))
        session_aux[sid] = aux
        for p, key in enumerate(page_keys(sid, pager.n_pages)):
            store.seed(int(key), {"state": pages[p]})

    # ---- arena sized for cache_sessions resident sessions
    ways = 4
    n_buckets = max(1, math.ceil(cfg.cache_sessions * pager.n_pages / ways))
    arena = PagedStateArena(n_buckets, ways,
                            {"state": ((cfg.page_elems, 1), jnp.float32)})

    clock = WallClock() if cfg.wall_clock else SimClock()
    sched = ContinuousBatchingScheduler(arena, store, mode=mode,
                                        max_batch=cfg.max_batch, clock=clock,
                                        metrics=ServingMetrics())

    # ---- one fused device step: pages -> KV -> decode -> pages
    def _step(params, pages, aux, tok, pos):
        kv = pager.from_pages(pages, aux)
        kv["pos"] = pos
        logits, kv2 = model.decode(params, kv, {"tokens": tok, "pos": pos})
        pages2, aux2 = pager.to_pages(kv2)
        return logits, pages2, aux2

    step = jax.jit(_step)
    # compile outside the measurement
    warm_pages, warm_aux = pager.to_pages(kv0)
    step(params, warm_pages, warm_aux,
         jnp.asarray([[1]], jnp.int32),
         jnp.int32(cfg.prompt_len))[0].block_until_ready()

    # ---- request stream (equal offered load across modes)
    arrivals = np.cumsum(rng.exponential(1.0 / cfg.arrival_rate,
                                         cfg.n_requests))
    sessions = rng.randint(0, cfg.n_sessions, cfg.n_requests)
    t0 = clock.now()
    pending: List[Request] = [
        Request(rid=i, session=int(sessions[i]),
                page_keys=page_keys(int(sessions[i]), pager.n_pages),
                n_tokens=cfg.decode_tokens,
                meta={"pos": cfg.prompt_len})
        for i in range(cfg.n_requests)]

    i = 0
    while i < cfg.n_requests or sched.pending:
        now = clock.now() - t0
        while i < cfg.n_requests and arrivals[i] <= now:
            sched.submit(pending[i])
            i += 1
        batch = sched.schedule()
        if not batch:
            if sched.wait_for_progress():
                continue
            if i < cfg.n_requests:       # idle until the next arrival
                clock.sleep(max(1e-6, arrivals[i] - (clock.now() - t0)))
                continue
            break                        # queue drained, nothing in flight
        for req in batch:
            sid = req.session
            hit, slots = arena.probe(req.page_keys, count=False)
            if not hit.all():
                # evicted between scheduling and execution (sync staging for
                # a later batch member can displace an earlier member's
                # page); the request stays queued and is retried next round
                req.state = "queued"
                continue
            pages = arena.gather(jnp.asarray(slots))["state"]
            pos = jnp.int32(req.meta["pos"])
            tok = jnp.asarray([[1]], jnp.int32)
            tw = time.perf_counter()
            logits, pages2, aux2 = step(params, pages, session_aux[sid],
                                        tok, pos)
            logits.block_until_ready()
            clock.advance(time.perf_counter() - tw)
            arena.stage(jnp.asarray(slots), {"state": pages2})
            session_aux[sid] = aux2
            req.meta["pos"] += 1
            sched.complete_token(req, dirty_keys=req.page_keys)

    sched.drain_dirty()
    out = sched.stats()
    out["n_pages_per_session"] = pager.n_pages
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--sessions", type=int, default=24)
    ap.add_argument("--rate", type=float, default=400.0)
    ap.add_argument("--modes", default="sync,async,prefetch")
    ap.add_argument("--wall-clock", action="store_true")
    args = ap.parse_args()
    modes = args.modes.split(",")
    bad = [m for m in modes if m not in ("sync", "async", "prefetch")]
    if bad:
        ap.error(f"unknown mode(s) {bad}; choose from sync,async,prefetch")
    cfg = ServeConfig(arch=args.arch, n_requests=args.requests,
                      n_sessions=args.sessions, arrival_rate=args.rate,
                      wall_clock=args.wall_clock)
    res = {m: run_serving(cfg, m) for m in modes}
    for m, r in res.items():
        print(f"[serve] {m:8s} ttft p50={r['ttft_p50']*1e3:7.2f}ms "
              f"p99={r['ttft_p99']*1e3:7.2f}ms "
              f"hit={r['arena_hit_rate']:.2f} "
              f"overlap={r['staging_overlap']:.2f} "
              f"wb={r['store_writebacks']}")
    if "sync" in res and "prefetch" in res:
        print(f"[serve] prefetch TTFT speedup "
              f"p50 {res['sync']['ttft_p50']/res['prefetch']['ttft_p50']:.2f}x"
              f", p99 "
              f"{res['sync']['ttft_p99']/res['prefetch']['ttft_p99']:.2f}x")


if __name__ == "__main__":
    main()
