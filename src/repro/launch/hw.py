"""TPU v5e hardware model used by the roofline analysis (targets, not the
CPU runtime of this container)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3    # 16 GiB per chip

CHIPS_PER_POD = 256


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, chips: int) -> dict:
    """The three §Roofline terms, in seconds."""
    return {
        "compute_s": hlo_flops / PEAK_FLOPS_BF16,
        "memory_s": hlo_bytes / HBM_BW,
        "collective_s": collective_bytes / (ICI_BW),
    }
