"""Loop-aware analysis of post-partitioning HLO text.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE (verified
empirically: a scan of 10 matmuls reports the flops of one), so for scanned
layer stacks it undercounts by ~L times.  This module re-derives, from
``compiled.as_text()``:

  * flops        — dot/convolution flops (exact, from shapes + dnums) plus a
                   1-flop/element charge for elementwise/reduce ops, with
                   while-loop bodies multiplied by their trip counts
                   (``backend_config known_trip_count``, else parsed from the
                   loop condition, else 1 + warning);
  * bytes        — operand+result bytes at fusion boundaries and for non-fused
                   top-level ops (fusion internals are free — the HBM traffic
                   model);
  * collectives  — operand bytes of all-gather / all-reduce / reduce-scatter /
                   all-to-all / collective-permute (+ -start forms), loop-aware,
                   broken down by kind.

All numbers are PER DEVICE (the partitioned module is the per-device program).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "exponential", "log", "negate", "power", "rsqrt", "sqrt", "tanh",
    "select", "compare", "and", "or", "xor", "not", "sine", "cosine",
    "floor", "ceil", "round-nearest-afz", "clamp", "sign", "atan2",
    "logistic", "cbrt", "erf", "remainder", "exponential-minus-one",
    "log-plus-one", "shift-right-logical", "shift-left",
    "shift-right-arithmetic", "reduce",
}
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota",
         "copy-start", "copy-done", "optimization-barrier", "domain",
         "rng-bit-generator", "rng-get-and-update-state"}
_DATA_MOVE = {"dot", "convolution", "sort", "copy", "transpose",
              "reshape", "broadcast", "concatenate", "pad",
              "convert", "select-and-scatter", "reverse", "cholesky",
              "triangular-solve"}
# ops that touch only a slice of their operands: bytes ~ slice, not buffer
_SLICING = {"dynamic-slice", "slice", "gather"}


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operand_names: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)


_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]*n["\s:]*"?(\d+)')


def _operand_names(rest: str) -> List[str]:
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w\.\-]+)", rest[:end])


def _parse_instr(ls: str) -> Optional[Tuple[str, str, str, str]]:
    """Parse 'name = type opcode(operands), attrs'.  Types may be tuples
    containing /*index=N*/ comments, so the type is matched by paren
    balancing, not regex."""
    m = _NAME_RE.match(ls)
    if not m:
        return None
    rest = ls[m.end():]
    if rest.startswith("("):
        depth = 0
        rtype = None
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rtype = rest[:i + 1]
                    rest = rest[i + 1:]
                    break
        if rtype is None:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype = rest[:sp]
        rest = rest[sp:]
    m2 = _OPCODE_RE.match(rest)
    if not m2:
        return None
    return m.group(1), rtype, m2.group(1), rest[m2.end():]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        ls = line.strip()
        if not ls or ls.startswith(("//", "#")):
            continue
        if ") -> " in ls and ls.endswith("{") and "=" not in ls.split("(")[0]:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", ls)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if ls.startswith("ENTRY"):
                    entry = cur.name
                continue
        if cur is None:
            continue
        parsed = _parse_instr(ls)
        if parsed:
            name, rtype, opcode, rest = parsed
            ins = Instr(name, opcode, rtype, _operand_names(rest), ls)
            cur.instrs.append(ins)
            cur.types[name] = rtype
    return comps, entry


def _operand_types(comp: Computation, ins: Instr) -> List[str]:
    return [comp.types.get(n, "") for n in ins.operand_names]


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = shape_elems(ins.result_type)
    ops = _operand_types(comp, ins)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    if not m or not ops:
        return 2.0 * out_elems
    lhs_m = _SHAPE_RE.search(ops[0])
    if not lhs_m:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in lhs_m.group(2).split(",") if d]
    contracted = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(lhs_dims):
            contracted *= lhs_dims[i]
    return 2.0 * out_elems * contracted


def _conv_flops(comp: Computation, ins: Instr) -> float:
    out_elems = shape_elems(ins.result_type)
    ops = _operand_types(comp, ins)
    if len(ops) < 2:
        return 2.0 * out_elems
    km = _SHAPE_RE.search(ops[1])
    kdims = [int(d) for d in km.group(2).split(",") if d] if km else []
    kernel = math.prod(kdims) if kdims else 1
    gm = re.search(r"feature_group_count=(\d+)", ins.raw)
    groups = int(gm.group(1)) if gm else 1
    # dim_labels tells which kernel dim is the output-feature dim; divide it
    # out of the kernel product: flops = 2*out_elems*(kernel/out_feat)/groups
    out_feat = max(kdims) if kdims else 1
    lm = re.search(r"dim_labels=[^ ,]*_([\dio]+)->", ins.raw)
    if lm and kdims:
        spec = lm.group(1)          # e.g. '01io'
        if "o" in spec:
            out_feat = kdims[spec.index("o")]
    return 2.0 * out_elems * max(1.0, kernel / max(1, out_feat)) / groups


def _fusion_bytes(comp: Computation, ins: Instr,
                  fused: Optional[Computation]) -> float:
    """HBM bytes at a fusion boundary.  Parameters that are only sliced
    inside the fusion contribute their slice sizes (the scan-over-layers /
    KV-cache pattern); a DUS root contributes its update size, not the whole
    aliased buffer."""
    op_types = _operand_types(comp, ins)
    if fused is None:
        return sum(shape_bytes(t) for t in op_types) \
            + shape_bytes(ins.result_type)
    total = 0.0
    # map parameter index -> instr
    params: Dict[int, Instr] = {}
    for fi in fused.instrs:
        if fi.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", fi.raw)
            if m:
                params[int(m.group(1))] = fi
    for j, t in enumerate(op_types):
        pin = params.get(j)
        if pin is not None:
            consumers = [x for x in fused.instrs
                         if pin.name in x.operand_names]
            slicers = [x for x in consumers if x.opcode in _SLICING]
            if consumers and len(slicers) == len(consumers):
                total += sum(shape_bytes(x.result_type) for x in slicers)
                continue
        total += shape_bytes(t)
    root = fused.instrs[-1] if fused.instrs else None
    for fi in reversed(fused.instrs):
        if "ROOT" in fi.raw:
            root = fi
            break
    if root is not None and root.opcode == "dynamic-update-slice" \
            and len(root.operand_names) > 1:
        total += shape_bytes(fused.types.get(root.operand_names[1], ""))
    else:
        total += shape_bytes(ins.result_type)
    return total


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: Dict[str, float] = field(default_factory=dict)
    trip_warnings: List[str] = field(default_factory=list)
    n_collectives: int = 0
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    top_collectives: List[Tuple[float, str]] = field(default_factory=list)

    def note_bytes(self, op: str, b: float) -> None:
        self.bytes += b
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0.0) + b

    def note_collective(self, kind: str, b: float, raw: str) -> None:
        self.collective_bytes += b
        self.by_collective[kind] = self.by_collective.get(kind, 0.0) + b
        self.top_collectives.append((b, raw[:220]))
        self.top_collectives.sort(key=lambda x: -x[0])
        del self.top_collectives[12:]


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> Optional[int]:
    m = _TRIP_RE.search(ins.raw)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=\s*%?([\w\.\-]+)", ins.raw)
    cond = comps.get(cm.group(1)) if cm else None
    if cond is None:
        return None
    consts = {}
    for i in cond.instrs:
        if i.opcode == "constant":
            c = _CONST_RE.search(i.raw)
            if c:
                consts[i.name] = int(c.group(1))
    if len(consts) == 1:
        return next(iter(consts.values()))
    if consts:
        return max(consts.values())
    return None


def analyze(text: str) -> Totals:
    comps, entry = parse_hlo(text)
    if entry is None and comps:
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    totals = Totals()

    def comp_cost(cname: str, mult: float, depth: int,
                  in_fusion: bool) -> None:
        comp = comps.get(cname)
        if comp is None or depth > 64:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op in _FREE:
                continue
            if op == "while":
                trips = _trip_count(ins, comps)
                if trips is None:
                    trips = 1
                    totals.trip_warnings.append(f"{cname}:{ins.name}")
                bm = re.search(r"body=\s*%?([\w\.\-]+)", ins.raw)
                if bm:
                    comp_cost(bm.group(1), mult * trips, depth + 1, False)
                continue
            if op in ("call", "conditional", "async-start"):
                for cm2 in re.finditer(
                        r"(?:to_apply|calls|called_computation)="
                        r"\s*\{?%?([\w\.\-]+)", ins.raw):
                    comp_cost(cm2.group(1), mult, depth + 1, in_fusion)
                continue
            if op == "fusion":
                fm = re.search(r"calls=\s*%?([\w\.\-]+)", ins.raw)
                if fm:
                    comp_cost(fm.group(1), mult, depth + 1, True)
                if not in_fusion:
                    totals.note_bytes("fusion", mult * _fusion_bytes(
                        comp, ins, comps.get(fm.group(1)) if fm else None))
                continue
            coll = next((c for c in _COLLECTIVES
                         if op == c or op == c + "-start"), None)
            if coll:
                b = mult * sum(shape_bytes(t)
                               for t in _operand_types(comp, ins))
                totals.note_collective(coll, b, f"x{int(mult)} {ins.raw}")
                totals.n_collectives += int(mult)
                continue
            if op.endswith("-done") or op == "custom-call":
                continue
            if op == "dot":
                totals.flops += mult * _dot_flops(comp, ins)
            elif op == "convolution":
                totals.flops += mult * _conv_flops(comp, ins)
            elif op in _ELEMENTWISE:
                totals.flops += mult * shape_elems(ins.result_type)
            if in_fusion:
                continue
            if op in _SLICING:
                totals.note_bytes(op, mult * 2 * shape_bytes(ins.result_type))
            elif op == "dynamic-update-slice":
                upd = (comp.types.get(ins.operand_names[1], "")
                       if len(ins.operand_names) > 1 else ins.result_type)
                totals.note_bytes(op, mult * 2 * shape_bytes(upd))
            elif op == "scatter":
                upd = (comp.types.get(ins.operand_names[-1], "")
                       if ins.operand_names else ins.result_type)
                totals.note_bytes(op, mult * 3 * shape_bytes(upd))
            elif op in _DATA_MOVE or op in _ELEMENTWISE:
                totals.note_bytes(op if op in _DATA_MOVE else "elementwise",
                                  mult * (
                    sum(shape_bytes(t) for t in _operand_types(comp, ins))
                    + shape_bytes(ins.result_type)))

    if entry:
        comp_cost(entry, 1.0, 0, False)
    return totals
