"""Step builders: train (grad-accum microbatching + AdamW) and serve steps.

These are the functions the launcher jits with explicit shardings; the
dry-run lowers exactly these, so what we roofline is what we would run.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.lm import Model
from repro.optim import adamw


def microbatch_reshape(batch: Dict[str, jax.Array], n: int) -> Dict[str, Any]:
    out = {}
    for k, v in batch.items():
        if getattr(v, "ndim", 0) >= 1 and v.shape and v.shape[0] % n == 0:
            out[k] = v.reshape((n, v.shape[0] // n) + v.shape[1:])
        else:
            out[k] = v
    return out


def make_train_step(model: Model, acfg: adamw.AdamWConfig,
                    n_micro: int = 1,
                    grad_transform: Optional[Callable] = None,
                    grad_shardings: Any = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With n_micro > 1 the global batch is split along dim 0 and gradients are
    accumulated in fp32 across a lax.scan — the compute/comm overlap knob:
    GSPMD moves the gradient reduce-scatter of microbatch i under the compute
    of microbatch i+1.  ``grad_shardings`` (a pytree of NamedSharding
    matching params) pins the fp32 accumulator to the parameter layout so the
    per-microbatch reduction is a reduce-scatter, not an all-reduce.
    ``grad_transform`` hooks gradient compression."""

    def loss_fn(p, b):
        loss, metrics = model.train_loss(p, b)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if n_micro <= 1:
            (loss, _metrics), grads = grad_fn(params, batch)
            grads = _constrain(grads)
        else:
            mb = microbatch_reshape(batch, n_micro)

            def acc_fn(carry, b):
                gacc, lacc = carry
                (loss, _m), g = grad_fn(params, b)
                gacc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g)
                return (_constrain(gacc), lacc + loss), None

            zeros = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (gsum, lsum), _ = lax.scan(acc_fn, (zeros, jnp.float32(0)), mb)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, om = adamw.update(acfg, params, opt_state, grads)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, batch):
        return model.decode(params, cache, batch)
    return decode_step
