"""Logical-axis sharding: model code annotates tensors with *logical* axis
names; a context-installed rule set maps them to mesh axes (or drops them).

Keeping the mapping out of model code lets the same model lower on a laptop
(no mesh: everything is a no-op), the 16x16 single-pod mesh, and the
2x16x16 multi-pod mesh, and lets the hillclimb loop swap sharding schemes
without touching the model.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_state = threading.local()


def _current() -> Tuple[Optional[Mesh], Dict[str, Axis]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", {})


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Dict[str, Axis]):
    """Install logical->mesh axis rules for the enclosed trace."""
    old = _current()
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = old


# Default logical->mesh mapping used by the launcher.  ``data`` composes the
# pod axis so multi-pod is batch-parallel across pods by default.
def default_rules(multi_pod: bool) -> Dict[str, Axis]:
    data = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": data,            # batch dim of activations
        "seq": None,              # sequence (train/prefill activations)
        "kv_seq": None,           # KV-cache sequence dim (decode)
        "embed": None,            # d_model
        "heads": "model",         # attention heads / q heads
        "kv_heads": "model",
        "mlp": "model",           # ffn hidden
        "vocab": "model",         # embedding/vocab-parallel
        "experts": "model",       # MoE expert dim
        "experts_data": data,     # expert dim on the data axis (serve EP)
        "expert_fsdp": data,      # expert-weight E dim on data (serve EP)
        "expert_mlp": None,       # per-expert hidden (already expert-sharded)
        "ssm_inner": "model",     # mamba/rwkv channel dim
        "kv_lora": None,          # MLA latent cache dim
        "tp": "model",            # parameter tensor-parallel dim
        "fsdp": data,             # parameter FSDP dim (policy-gated)
        "opt_shard": data,        # ZeRO-1 optimizer-state sharding
        "state_shard": data,      # sharded keyed-state plane: leading shard
        #                           dim of stacked per-shard arenas (§9)
    }


# ------------------------------------------------------ keyed-state shards
# Placement for the sharded keyed-state plane (DESIGN.md §9): shards (hash
# bins of the key space) are assigned to owners — engine subtasks or mesh
# devices — round-robin, so consecutive shards land on distinct owners and
# a contiguous shard range migrates with maximum source fan-out.

def shard_owner_map(n_shards: int, n_owners: int) -> list:
    """Round-robin shard->owner table.  ``ShardRouter`` builds its default
    bin table from this; ``ShardPlane`` (streaming side, deliberately
    jax-free) keeps an identical inline copy — change both together."""
    if n_shards < n_owners:
        raise ValueError(f"n_shards={n_shards} < n_owners={n_owners}")
    return [s % n_owners for s in range(n_shards)]


def mesh_shard_owners(mesh: Mesh, n_shards: int,
                      axis: Axis = "data") -> list:
    """Shard->owner table sized to one mesh axis (or axis tuple): owner i
    is the i-th device coordinate along ``axis``, so per-shard arenas
    co-locate with the mesh's data-parallel shards and a ``state_shard``-
    annotated pool stack places its rows on their owners."""
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    n_owners = 1
    for a in axes:
        n_owners *= mesh.shape[a]
    return shard_owner_map(n_shards, n_owners)


def resolve_spec(logical: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh, rules: Dict[str, Axis]) -> P:
    """Map logical names to a PartitionSpec, dropping axes that do not divide
    the corresponding dimension (divisibility-aware fallback)."""
    used = set()
    out = []
    for dim, name in zip(shape, logical):
        axis = rules.get(name) if name else None
        if axis is None:
            out.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        if any(a in used for a in axes):
            out.append(None)
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if size == 0 or dim % size != 0:
            # try a prefix of the axis tuple that divides
            ok = None
            for cut in range(len(axes) - 1, 0, -1):
                s = 1
                for a in axes[:cut]:
                    s *= mesh.shape[a]
                if dim % s == 0:
                    ok = axes[:cut]
                    break
            if ok is None:
                out.append(None)
                continue
            axes = ok
        used.update(axes)
        # preserve the rule's tuple-ness: current PartitionSpec no longer
        # treats 'data' and ('data',) as equal
        out.append(axes[0] if isinstance(axis, str) else tuple(axes))
    return P(*out)


def constraint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh, rules = _current()
    if mesh is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"constraint({logical}) vs rank-{x.ndim} tensor")
    spec = resolve_spec(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, shape: Sequence[int],
                   logical: Sequence[Optional[str]],
                   rules: Dict[str, Axis]) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical, shape, mesh, rules))
