"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run process
forces 512 host devices via XLA_FLAGS before any jax import; everything else
(smoke tests, benches) sees the real single device.
"""
from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} (dryrun.py "
            f"sets this automatically)")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_mesh(shape, axes):
    """Generic helper for elastic re-meshing (runtime.elastic)."""
    import jax
    from jax.sharding import Mesh
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)
