"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
record memory/cost/collective analysis for the roofline report.

MUST set the host-device override before ANY other import (jax locks device
count on first init):
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (LM_SHAPES, active_params, count_params,  # noqa: E402
                           get_config, shape_applicable, shape_by_name,
                           ARCH_IDS)
from repro.launch import hlo_analysis, hw  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.sharding import axis_rules, default_rules  # noqa: E402
from repro.launch.specs import (batch_pspec, cache_pspec_tree,  # noqa: E402
                                opt_pspec_tree, param_pspec_tree, policy_for,
                                serving_rules)
from repro.launch.steps import (make_decode_step, make_prefill_step,  # noqa: E402
                                make_train_step)
from repro.models.lm import build_model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


def _with_shardings(shape_tree, spec_tree, mesh):
    def f(s, p):
        return jax.ShapeDtypeStruct(s.shape, s.dtype,
                                    sharding=NamedSharding(mesh, p))
    return jax.tree.map(f, shape_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: str,
             attn_impl: str = None, note: str = "") -> dict:
    multi = mesh_kind == "multi"
    shape = shape_by_name(shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "note": note}
    if not shape_applicable(arch, shape_name):
        rec.update(ok=True, skipped=True,
                   reason="long_500k restricted to sub-quadratic archs "
                          "(see DESIGN.md §4)")
        return rec

    cfg = get_config(arch)
    if shape.kind == "train":
        cfg = cfg.replace(remat="block")
    if attn_impl:
        cfg = cfg.replace(attn_impl=attn_impl)
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    rules = default_rules(multi)
    if shape.kind == "decode":
        rules = serving_rules(cfg, rules, mesh)
    policy = policy_for(cfg, shape.kind)
    if policy.expert_scheme != "ep_model":
        cfg = cfg.replace(expert_scheme=policy.expert_scheme)
        rec["expert_scheme"] = policy.expert_scheme
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)

    params_shape = jax.eval_shape(model.init_params, rng)
    pspec = param_pspec_tree(params_shape, mesh, rules, policy)
    params_in = _with_shardings(params_shape, pspec, mesh)
    batch_shape = model.input_specs(shape)
    bspec = batch_pspec(batch_shape, mesh, rules)
    batch_in = _with_shardings(batch_shape, bspec, mesh)

    t0 = time.time()
    with axis_rules(mesh, rules):
        if shape.kind == "train":
            acfg = adamw.AdamWConfig(moment_dtype="bfloat16")
            opt_shape = jax.eval_shape(partial(adamw.init, acfg),
                                       params_shape)
            ospec = adamw.AdamWState(
                step=P(),
                mu=opt_pspec_tree(params_shape, mesh, rules, policy),
                nu=opt_pspec_tree(params_shape, mesh, rules, policy))
            opt_in = _with_shardings(opt_shape, ospec, mesh)
            data_size = chips // int(mesh.shape.get("model", 1))
            n_micro = max(1, shape.global_batch // data_size)
            rec["n_micro"] = n_micro
            grad_sh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspec,
                                   is_leaf=lambda x: isinstance(x, P))
            step = make_train_step(model, acfg, n_micro=n_micro,
                                   grad_shardings=grad_sh)
            jitted = jax.jit(
                step, donate_argnums=(0, 1),
                out_shardings=(
                    jax.tree.map(lambda p: NamedSharding(mesh, p), pspec,
                                 is_leaf=lambda x: isinstance(x, P)),
                    jax.tree.map(lambda p: NamedSharding(mesh, p), ospec,
                                 is_leaf=lambda x: isinstance(x, P)),
                    None))
            lowered = jitted.lower(params_in, opt_in, batch_in)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            jitted = jax.jit(step)
            lowered = jitted.lower(params_in, batch_in)
        else:
            cache_shape = model.cache_spec(shape.global_batch, shape.seq_len)
            cspec = cache_pspec_tree(cfg, cache_shape, mesh, rules)
            cache_in = _with_shardings(cache_shape, cspec, mesh)
            step = make_decode_step(model)
            jitted = jax.jit(step, donate_argnums=(1,))
            lowered = jitted.lower(params_in, cache_in, batch_in)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    # ---- memory ----
    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "peak_memory_in_bytes", "generated_code_size_in_bytes"):
        mem[f] = int(getattr(ma, f, 0) or 0)
    live = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
    mem["live_bytes"] = live
    # strict: CPU-reported temp included.  args: weights+caches+inputs only —
    # the CPU backend double-buffers read-only loop carries that the TPU
    # backend aliases, so decode temps are overstated (see EXPERIMENTS.md).
    mem["fits_16g_strict"] = bool(live <= hw.HBM_BYTES)
    mem["fits_16g_args"] = bool(
        mem["argument_size_in_bytes"] <= hw.HBM_BYTES)
    rec["memory"] = mem

    # ---- XLA cost analysis (loop bodies counted once; for reference) ----
    ca = compiled.cost_analysis() or {}
    rec["xla_cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0))}

    # ---- loop-aware HLO analysis (per device) ----
    t2 = time.time()
    totals = hlo_analysis.analyze(compiled.as_text())
    rec["analyze_s"] = round(time.time() - t2, 2)
    rec["hlo"] = {
        "flops_per_device": totals.flops,
        "bytes_per_device": totals.bytes,
        "collective_bytes_per_device": totals.collective_bytes,
        "by_collective": totals.by_collective,
        "n_collectives": totals.n_collectives,
        "trip_warnings": totals.trip_warnings[:8],
        "bytes_by_op": dict(sorted(totals.bytes_by_op.items(),
                                   key=lambda kv: -kv[1])[:12]),
        "top_collectives": totals.top_collectives[:8],
    }

    # ---- roofline ----
    n_act = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_act * tokens
    terms = hw.roofline_terms(totals.flops, totals.bytes,
                              totals.collective_bytes, chips)
    dominant = max(terms, key=terms.get)
    hlo_global = totals.flops * chips
    rec["roofline"] = {
        **terms,
        "dominant": dominant,
        "chips": chips,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "params": count_params(cfg),
        "active_params": n_act,
        "tokens_per_step": tokens,
        # fraction of roofline: useful work time at peak / achievable step time
        "roofline_fraction": (model_flops / chips / hw.PEAK_FLOPS_BF16)
        / max(max(terms.values()), 1e-12),
    }
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--note", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for mesh_kind in ("single", "multi"):
            for arch in ARCH_IDS:
                for sh in LM_SHAPES:
                    cells.append((arch, sh.name, mesh_kind))
    else:
        cells.append((args.arch, args.shape, args.mesh))

    for arch, shape_name, mesh_kind in cells:
        tag = f"{arch}__{shape_name}__{mesh_kind}"
        if args.note:
            tag += f"__{args.note}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {tag}: exists, skipping")
            continue
        t0 = time.time()
        try:
            rec = run_cell(arch, shape_name, mesh_kind, args.out,
                           attn_impl=args.attn_impl, note=args.note)
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        rec["wall_s"] = round(time.time() - t0, 2)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = ("SKIP" if rec.get("skipped")
                  else "OK" if rec.get("ok") else "FAIL")
        extra = ""
        if rec.get("ok") and not rec.get("skipped"):
            r = rec["roofline"]
            extra = (f" dominant={r['dominant']} frac={r['roofline_fraction']:.3f}"
                     f" live={rec['memory']['live_bytes']/2**30:.2f}GiB")
        print(f"[dryrun] {tag}: {status} ({rec['wall_s']}s){extra}",
              flush=True)


if __name__ == "__main__":
    main()
