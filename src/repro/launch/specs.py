"""Parameter / optimizer / cache / batch PartitionSpecs.

Policy knobs (hillclimbable without touching models):
  * tensor-parallel ('model' axis) on the conventional col/row dims,
  * FSDP-style 2D weight sharding over the data axis for big archs,
  * ZeRO-1 optimizer-state sharding over data,
  * KV caches: kv-heads on 'model' when divisible, else kv-seq on 'model'.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.sharding import Axis, resolve_spec

# stacked pytree prefixes whose leading dim is the scanned layer index
_STACKED = ("layers", "enc_layers", "dec_layers")

# leaf name -> logical axes of the *last* dims (leading dims -> None)
# NOTE: w_Bm/w_Cm (mamba2 B/C, state dim N=64) stay REPLICATED: column
# sharding them makes every SSD C.B^T einsum a [B,nc,Q,Q] fp32 all-reduce
# (hillclimb: zamba2 train_4k, EXPERIMENTS.md §Perf)
_COL = ("wq", "wk", "wv", "w_uq", "w_ukv", "w_z", "w_x",
        "w_dt", "cm_wk", "wr", "wg", "cm_wr")
_ROW = ("wo", "w_out", "cm_wv")
_VEC_TP = ("bq", "bk", "bv", "conv_bx", "A_log", "D_skip", "dt_bias", "norm")
_CONV_TP = ("conv_x",)


@dataclass(frozen=True)
class ShardingPolicy:
    fsdp_params: bool = False       # 2D weight sharding over data axis
    fsdp_min_dim: int = 1024        # only fsdp-shard dims at least this big
    zero1: bool = True              # shard optimizer moments over data
    tp_seq_for_oddheads: bool = False  # (hillclimb) seq-shard attention acts
    # expert-weight scheme: "ep_model" shards E over the model axis (default;
    # train-friendly), "ep_data_tp_ffn" shards E over data and the expert FFN
    # hidden over model — weights stay RESIDENT at serve time (no per-step
    # fsdp all-gather); tokens all-to-all instead (hillclimb: deepseek decode)
    expert_scheme: str = "ep_model"


def policy_for(cfg: ModelConfig, kind: str) -> ShardingPolicy:
    from repro.configs.base import count_params
    big = count_params(cfg) * 2 > 12 * 2 ** 30 * 16   # > ~12GB/chip at TP16
    if kind == "train":
        return ShardingPolicy(fsdp_params=True, zero1=True)
    # serving: big MoE archs keep expert weights RESIDENT (E over data, FFN
    # hidden over model) instead of re-gathering fsdp shards every step
    scheme = "ep_data_tp_ffn" if (big and cfg.moe) else "ep_model"
    return ShardingPolicy(fsdp_params=big, zero1=False,
                          expert_scheme=scheme)


def _effective_dims(path: Tuple[str, ...], shape: Tuple[int, ...]
                    ) -> Tuple[int, Tuple[int, ...]]:
    """Number of leading stacked dims to skip, remaining shape."""
    skip = 1 if path and path[0] in _STACKED else 0
    return skip, shape[skip:]


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return tuple(names)


def param_logical(path: Tuple[str, ...], shape: Tuple[int, ...],
                  policy: ShardingPolicy) -> Tuple[Optional[str], ...]:
    """Logical axes for one parameter (full rank, including stacked dims)."""
    name = path[-1]
    skip, dims = _effective_dims(path, shape)
    rank = len(dims)
    fsdp = "fsdp" if policy.fsdp_params else None

    def pad(spec: Sequence[Optional[str]]) -> Tuple[Optional[str], ...]:
        return (None,) * skip + tuple(spec)

    if name == "embed":
        return pad(("tp", fsdp))
    if name == "lm_head":
        return pad((fsdp, "tp"))
    if name in ("w_gate", "w_up"):
        if rank == 3:                       # MoE experts [E, D, F]
            if policy.expert_scheme == "ep_data_tp_ffn":
                return pad(("expert_fsdp", None, "tp"))
            return pad(("tp", fsdp, None))
        return pad((fsdp, "tp"))
    if name == "w_down":
        if rank == 3:                       # MoE experts [E, F, D]
            if policy.expert_scheme == "ep_data_tp_ffn":
                return pad(("expert_fsdp", "tp", None))
            return pad(("tp", None, fsdp))
        return pad(("tp", fsdp))
    if name in _COL:
        return pad((fsdp, "tp"))
    if name in _ROW:
        return pad(("tp", fsdp))
    if name in _VEC_TP and rank == 1:
        return pad(("tp",))
    if name in _CONV_TP:
        return pad((None, "tp"))
    if rank >= 2 and fsdp:
        # leftover matrices (MLA down-projections, routers, loras, frontend
        # projectors): FSDP-shard dim0 so their gradients reduce-scatter
        # instead of all-reducing at full size every microbatch
        return pad((fsdp,) + (None,) * (rank - 1))
    # norms, scalars, tiny vectors: replicated
    return pad((None,) * rank)


def _respect_min_dim(logical: Tuple[Optional[str], ...],
                     shape: Tuple[int, ...],
                     policy: ShardingPolicy) -> Tuple[Optional[str], ...]:
    out = []
    for name, dim in zip(logical, shape):
        if name == "fsdp" and dim < policy.fsdp_min_dim:
            out.append(None)
        else:
            out.append(name)
    return tuple(out)


def param_pspec_tree(params_shape: Any, mesh: Mesh, rules: Dict[str, Axis],
                     policy: ShardingPolicy) -> Any:
    """Pytree of PartitionSpec matching params (a tree of ShapeDtypeStruct
    or arrays)."""
    def f(path, leaf):
        names = _path_names(path)
        logical = param_logical(names, tuple(leaf.shape), policy)
        logical = _respect_min_dim(logical, tuple(leaf.shape), policy)
        return resolve_spec(logical, leaf.shape, mesh, rules)
    return jax.tree_util.tree_map_with_path(f, params_shape)


def opt_pspec_tree(params_shape: Any, mesh: Mesh, rules: Dict[str, Axis],
                   policy: ShardingPolicy) -> Any:
    """ZeRO-1: moments get the param spec plus a data shard on the first
    still-unsharded divisible dim."""
    base = param_pspec_tree(params_shape, mesh, rules, policy)
    if not policy.zero1:
        return base
    data_axes = rules.get("opt_shard") or rules.get("batch")
    if data_axes is None:
        return base
    axes = (data_axes,) if isinstance(data_axes, str) else tuple(data_axes)
    size = int(np.prod([mesh.shape[a] for a in axes]))

    def f(spec, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for s in parts:
            if s is None:
                continue
            for a in ((s,) if isinstance(s, str) else s):
                used.add(a)
        if any(a in used for a in axes):
            return P(*parts)
        for i, (s, dim) in enumerate(zip(parts, leaf.shape)):
            if s is None and dim % size == 0 and dim >= size:
                parts[i] = axes[0] if len(axes) == 1 else tuple(axes)
                return P(*parts)
        return P(*parts)

    return jax.tree.map(f, base, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------- batch/cache
_BATCH_LOGICAL = {
    "tokens": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "frames": ("batch", "seq", None),
    "frontend_embeds": ("batch", None, None),
    "pos": (),
}


def batch_pspec(specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh,
                rules: Dict[str, Axis]) -> Dict[str, P]:
    out = {}
    for k, v in specs.items():
        logical = _BATCH_LOGICAL.get(k, (None,) * len(v.shape))
        logical = tuple(logical[:len(v.shape)])
        out[k] = resolve_spec(logical, v.shape, mesh, rules)
    return out


def cache_logical(cfg: ModelConfig, leaf_path: Tuple[str, ...],
                  shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    """Logical axes for one cache leaf, by family and leaf name."""
    name = leaf_path[0] if leaf_path else ""
    rank = len(shape)
    if name == "pos":
        return ()
    if cfg.ssm and cfg.ssm.kind == "rwkv6":
        # [L,B,D] shifts / [L,B,H,N,N] wkv state
        if rank == 3:
            return (None, "batch", "ssm_inner")
        return (None, "batch", "heads", None, None)
    if cfg.ssm and cfg.ssm.kind == "mamba2":
        if name == "conv":                      # [L,B,K-1,conv_dim]
            return (None, "batch", None, "ssm_inner")
        if name == "ssd":                       # [L,B,H,N,P]
            return (None, "batch", "heads", None, None)
        if name == "x0_last":
            return ("batch", "embed")
        if name == "shared_kv":                 # [B,T,KV,hd] per invocation
            return ("batch", "kv_seq", "kv_heads", None)
    if cfg.mla:
        # ("kv",0): ckv [L,B,T,r]; ("kv",1): rope [L,B,T,rd]
        if rank == 4:
            return (None, "batch", "kv_seq", "kv_lora")
        if rank == 3:
            return ("batch", "kv_seq", "kv_lora")
    # dense KV caches: [L,B,T,KV,hd] (stacked) or [B,T,KV,hd] (prefix/shared)
    if rank == 5:
        return (None, "batch", "kv_seq", "kv_heads", None)
    if rank == 4:
        return ("batch", "kv_seq", "kv_heads", None)
    if rank == 2:
        return ("batch", None)
    return (None,) * rank


def cache_pspec_tree(cfg: ModelConfig, cache_shape: Any, mesh: Mesh,
                     rules: Dict[str, Axis]) -> Any:
    def f(path, leaf):
        names = _path_names(path)
        logical = cache_logical(cfg, names, tuple(leaf.shape))
        return resolve_spec(logical, leaf.shape, mesh, rules)
    return jax.tree_util.tree_map_with_path(f, cache_shape)


def serving_rules(cfg: ModelConfig, rules: Dict[str, Axis],
                  mesh: Mesh) -> Dict[str, Axis]:
    """Adjust logical rules for decode: prefer kv-head sharding when the head
    count divides the model axis, else shard the KV sequence."""
    r = dict(rules)
    r.setdefault("kv_lora", None)
    model = int(mesh.shape.get("model", 1))
    if cfg.mla:
        # latent cache is per-token small but 128x32k contexts still need
        # sequence sharding (batch-only leaves ~18GiB/chip at decode_32k)
        r["kv_seq"] = "model"
        r["kv_lora"] = None
    elif cfg.num_kv_heads % model == 0:
        r["kv_seq"] = None
    else:
        r["kv_seq"] = "model"
        r["kv_heads"] = None
    return r
