"""Training launcher: data pipeline -> model -> AdamW, with checkpointing,
fault-tolerant supervision, optional gradient compression, and mesh-aware
sharding.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \\
        --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

On the CPU container the ``--smoke`` reduced configs train for real (loss
decreases); full configs are exercised via dryrun.py.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.steps import make_train_step
from repro.models.lm import build_model
from repro.optim import adamw
from repro.runtime.compression import make_compressor
from repro.runtime.supervisor import (SupervisorConfig, TrainSupervisor,
                                      inject_failure_at)


def build_training(arch: str, smoke: bool, batch: int, seq: int,
                   n_micro: int = 1, compress: bool = False,
                   lr: float = 1e-3, seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    model = build_model(cfg)
    acfg = adamw.AdamWConfig(lr_peak=lr, lr_min=lr * 0.1, warmup_steps=10,
                             decay_steps=10_000)
    params = model.init_params(jax.random.PRNGKey(seed))
    opt_state = adamw.init(acfg, params)

    fe = cfg.frontend
    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch,
        seed=seed,
        frontend_tokens=fe.num_tokens if fe and fe.kind == "vision" else 0,
        frontend_dim=fe.embed_dim if fe else 0,
        encoder_decoder=cfg.encoder_decoder)

    err_state = None
    if compress:
        init_err, transform = make_compressor()
        err_holder = {"err": init_err(params)}

        def grad_transform(grads):
            g, err_holder["err"] = transform(grads, err_holder["err"])
            return g
    else:
        grad_transform = None

    step_fn_raw = jax.jit(make_train_step(model, acfg, n_micro=n_micro,
                                          grad_transform=grad_transform),
                          donate_argnums=(0, 1))

    def step_fn(state, step):
        params, opt_state = state
        b = batch_at(dcfg, step)
        params, opt_state, metrics = step_fn_raw(params, opt_state, b)
        return (params, opt_state), metrics

    return (params, opt_state), step_fn, model, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    state, step_fn, model, cfg = build_training(
        args.arch, args.smoke, args.batch, args.seq, args.micro,
        args.compress_grads, args.lr)
    ckpt = CheckpointManager(args.ckpt, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, state, extra = ckpt.restore(state)
        print(f"[train] resumed from step {start}")
    sup = TrainSupervisor(SupervisorConfig(
        checkpoint_every=args.ckpt_every), ckpt)
    injector = (inject_failure_at({args.inject_failure_at})
                if args.inject_failure_at is not None else None)
    t0 = time.time()
    rep = sup.run(state, step_fn, args.steps, start_step=start,
                  failure_injector=injector)
    dt = time.time() - t0
    first = rep.losses[0] if rep.losses else float("nan")
    last = rep.losses[-1] if rep.losses else float("nan")
    print(f"[train] arch={args.arch} steps={rep.steps_run} "
          f"restarts={rep.restarts} stragglers={rep.stragglers} "
          f"loss {first:.3f} -> {last:.3f} ({dt:.1f}s)")


if __name__ == "__main__":
    main()
