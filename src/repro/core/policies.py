"""Baseline cache policies (paper §VI baselines): LRU and Clock.

Same interface as the Timestamp-Aware Cache (``core/tac.py``,
DESIGN.md §3) so the stateful operator is policy-agnostic: ``lookup`` /
``insert`` / ``write`` / ``contains``, the dirty/eviction-buffer
protocol (``pop_writeback`` / ``flush_dirty``, §3 and §7) so the
Async-I/O baseline can also write back off the critical path (as
Flink's RocksDB cache does via the memtable), the migration drain
``export_entries`` (§9), and the purge ``drop`` (§10, §11).  Timestamp
arguments are accepted and ignored — LRU/Clock order is positional, so
hint ``renew`` degenerates to a residency check.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional


@dataclass
class _E:
    key: Any
    state: Any
    dirty: bool = False
    size: int = 1
    ref: bool = True          # clock reference bit


class _BaseCache:
    def __init__(self, capacity: int):
        self.capacity = capacity
        self.used = 0
        self.evict_buffer: Dict[Any, _E] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.writebacks = 0
        self.prefetch_insertions = 0
        self.pf_ins_by_origin = {}
        self.pf_unused_by_origin = {}

    def pop_writeback(self):
        if not self.evict_buffer:
            return None
        key = next(iter(self.evict_buffer))
        e = self.evict_buffer.pop(key)
        self.writebacks += 1
        return e

    def export_entries(self, pred) -> List[_E]:
        """Shard migration drain (DESIGN.md §9): pop every entry
        (resident + eviction buffer) whose key satisfies ``pred``.
        ``_E`` carries no timestamp (LRU/Clock order is positional), so
        the destination re-inserts at migration time — the TAC keeps
        true timestamps (core/tac.py)."""
        out = []
        for key in [k for k in self.entries if pred(k)]:
            e = self.entries.pop(key)
            self.used -= e.size
            out.append(e)
        for key in [k for k in self.evict_buffer if pred(k)]:
            out.append(self.evict_buffer.pop(key))
        if hasattr(self, "_hand"):
            self._hand = []               # clock hand invalidated by removal
        return out

    def import_entries(self, entries, now_ts=0.0) -> int:
        """Inverse of ``export_entries`` (migration re-admit §9, snapshot
        restore roundtrips §7 — DESIGN.md).  ``_E`` carries no timestamp;
        LRU/Clock order is positional, and ``export_entries`` drains in
        recency order (oldest first), so re-inserting in export order
        reproduces the relative eviction order.  Dirty bits ride along."""
        for e in entries:
            self.insert(e.key, e.state, getattr(e, "ts", now_ts),
                        dirty=e.dirty, size=e.size)
        return len(entries)

    def flush_dirty(self) -> List[_E]:
        out = [e for e in self._iter_entries() if e.dirty]
        out += list(self.evict_buffer.values())
        for e in out:
            e.dirty = False
        self.evict_buffer.clear()
        return out

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    # entries iterator provided by subclasses
    def _iter_entries(self):
        raise NotImplementedError

    def drop(self, key) -> bool:
        """Remove an entry outright (window-pane purge §10, interval-key
        expiry §11 — DESIGN.md): no write-back, no eviction
        accounting."""
        e = self.entries.pop(key, None)
        if e is not None:
            self.used -= e.size
            if hasattr(self, "_hand"):
                self._hand = []           # clock hand invalidated by removal
            return True
        return self.evict_buffer.pop(key, None) is not None

    # TAC-compat no-ops
    def renew(self, key, hint_ts) -> bool:
        return self.contains(key)


class LRUCache(_BaseCache):
    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.entries: "OrderedDict[Any, _E]" = OrderedDict()

    def _iter_entries(self):
        return self.entries.values()

    def contains(self, key) -> bool:
        return key in self.entries or key in self.evict_buffer

    def _make_room(self, size: int) -> None:
        while self.used + size > self.capacity and self.entries:
            _, e = self.entries.popitem(last=False)
            self.used -= e.size
            self.evictions += 1
            if e.dirty:
                self.evict_buffer[e.key] = e

    def lookup(self, key, now_ts=None):
        e = self.entries.get(key)
        if e is None:
            staged = self.evict_buffer.pop(key, None)
            if staged is not None:
                self._make_room(staged.size)
                self.entries[staged.key] = staged
                self.used += staged.size
                self.hits += 1
                return staged.state
            self.misses += 1
            return None
        self.entries.move_to_end(key)
        self.hits += 1
        return e.state

    def insert(self, key, state, ts=None, dirty=False, size=1,
               prefetched=False, origin=""):
        old = self.entries.pop(key, None)
        if old is not None:
            self.used -= old.size
        self.evict_buffer.pop(key, None)
        self._make_room(size)
        self.entries[key] = _E(key, state, dirty, size)
        self.used += size
        if prefetched:
            self.prefetch_insertions += 1
            self.pf_ins_by_origin[origin] = \
                self.pf_ins_by_origin.get(origin, 0) + 1

    def write(self, key, state, now_ts=None, size=1):
        e = self.entries.get(key)
        if e is not None:
            e.state = state
            e.dirty = True
            self.entries.move_to_end(key)
            return
        self.insert(key, state, dirty=True, size=size)

    def __len__(self):
        return len(self.entries)


class ClockCache(_BaseCache):
    def __init__(self, capacity: int):
        super().__init__(capacity)
        self.entries: "OrderedDict[Any, _E]" = OrderedDict()
        self._hand: List[Any] = []
        self._hand_idx = 0

    def _iter_entries(self):
        return self.entries.values()

    def contains(self, key) -> bool:
        return key in self.entries or key in self.evict_buffer

    def _make_room(self, size: int) -> None:
        while self.used + size > self.capacity and self.entries:
            if not self._hand:
                self._hand = list(self.entries.keys())
                self._hand_idx = 0
            scanned = 0
            victim = None
            n = len(self._hand)
            while scanned < 2 * n:
                k = self._hand[self._hand_idx % n]
                self._hand_idx += 1
                scanned += 1
                e = self.entries.get(k)
                if e is None:
                    continue
                if e.ref:
                    e.ref = False
                else:
                    victim = e
                    break
            if victim is None:
                # all referenced: take current position
                for k in self.entries:
                    victim = self.entries[k]
                    break
            del self.entries[victim.key]
            self.used -= victim.size
            self.evictions += 1
            self._hand = []
            if victim.dirty:
                self.evict_buffer[victim.key] = victim

    def lookup(self, key, now_ts=None):
        e = self.entries.get(key)
        if e is None:
            staged = self.evict_buffer.pop(key, None)
            if staged is not None:
                self._make_room(staged.size)
                staged.ref = True
                self.entries[staged.key] = staged
                self.used += staged.size
                self.hits += 1
                return staged.state
            self.misses += 1
            return None
        e.ref = True
        self.hits += 1
        return e.state

    def insert(self, key, state, ts=None, dirty=False, size=1,
               prefetched=False, origin=""):
        old = self.entries.pop(key, None)
        if old is not None:
            self.used -= old.size
        self.evict_buffer.pop(key, None)
        self._make_room(size)
        self.entries[key] = _E(key, state, dirty, size)
        self.used += size
        self._hand = []
        if prefetched:
            self.prefetch_insertions += 1
            self.pf_ins_by_origin[origin] = \
                self.pf_ins_by_origin.get(origin, 0) + 1

    def write(self, key, state, now_ts=None, size=1):
        e = self.entries.get(key)
        if e is not None:
            e.state = state
            e.dirty = True
            e.ref = True
            return
        self.insert(key, state, dirty=True, size=size)

    def __len__(self):
        return len(self.entries)
