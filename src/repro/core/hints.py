"""Two-stage hints buffer (paper §IV-C): per-key dedup with max-timestamp
merge; ``unprocessed`` -> ``in_flight`` as the state thread pool picks keys.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple


class HintsBuffer:
    def __init__(self, max_size: int = 100_000):
        self.unprocessed: "OrderedDict[Any, float]" = OrderedDict()
        self.in_flight: Dict[Any, float] = {}
        self.max_size = max_size
        self.dropped = 0

    def add(self, key: Any, ts: float) -> None:
        if key in self.in_flight:
            self.in_flight[key] = max(self.in_flight[key], ts)
            return
        old = self.unprocessed.get(key)
        if old is not None:
            self.unprocessed[key] = max(old, ts)
            return
        if len(self.unprocessed) >= self.max_size:
            self.dropped += 1
            return
        self.unprocessed[key] = ts

    def next_fetch(self) -> Optional[Tuple[Any, float]]:
        """Move the oldest unprocessed hint to in-flight and return it."""
        if not self.unprocessed:
            return None
        key, ts = self.unprocessed.popitem(last=False)
        self.in_flight[key] = ts
        return key, ts

    def take(self, key: Any) -> Optional[float]:
        """Move a specific key to in-flight (fetch being issued for it)."""
        ts = self.unprocessed.pop(key, None)
        if ts is not None:
            self.in_flight[key] = ts
        return ts

    def complete(self, key: Any) -> Optional[float]:
        """Fetch done: drop from the buffer, returning the (latest) ts."""
        return self.in_flight.pop(key, None)

    def discard(self, key: Any) -> None:
        self.unprocessed.pop(key, None)

    def pending(self, key: Any) -> bool:
        return key in self.unprocessed or key in self.in_flight

    def __len__(self) -> int:
        return len(self.unprocessed) + len(self.in_flight)
