"""Timestamp-Aware Cache (paper §IV-D).

One cache for both previously-accessed and prefetched entries, ordered by a
single signal — event timestamps:

  * accessed entry:  t_k = event time of last access (LRU-like among those);
  * prefetched entry: t_k = hint timestamp (in the future => protected);
  * renewing hint for a cached key bumps t_k to the hint timestamp.

Eviction removes the smallest-timestamp entry.  Dirty victims go to the
EVICTION BUFFER and are written back asynchronously by the state thread
pool, so writes never block the data path; a read or hint for a key staged
in the eviction buffer moves it back.

The paper implements the order as a timestamp-sorted doubly-linked list;
this implementation keeps the identical eviction ORDER with a lazy min-heap
(O(log n) ops regardless of hint-timestamp interleaving).  The TPU-side twin
(``repro.core.tac_jax`` + ``repro.kernels.tac_probe``) is a fixed-slot
argmin-timestamp variant validated for order-equivalence in tests.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass
class Entry:
    key: Any
    state: Any
    ts: float
    dirty: bool = False
    size: int = 1


class TimestampAwareCache:
    def __init__(self, capacity: int,
                 on_writeback: Optional[Callable[[Any, Any], None]] = None,
                 deadline_aware: bool = False):
        """capacity counts entry ``size`` units (bytes or slots).

        ``deadline_aware`` changes the eviction ORDER for workloads whose
        timestamps are far-future access DEADLINES (window panes,
        DESIGN.md §10): stale entries (ts behind the clock of observed
        accesses) still evict oldest-first, but among future-deadline
        entries the FARTHEST deadline goes first — Belady's rule on known
        access times.  The paper's min-ts order (default) is right when
        hints run only milliseconds ahead; with deadlines seconds ahead
        it would evict exactly the panes that fire next.
        """
        self.capacity = capacity
        self.entries: Dict[Any, Entry] = {}
        self.evict_buffer: Dict[Any, Entry] = {}
        self._heap: List[Tuple[float, int, Any]] = []   # (ts, gen, key) lazy
        self.deadline_aware = deadline_aware
        self._fheap: List[Tuple[float, int, Any]] = []  # (-ts, gen, key)
        # staleness boundary for deadline_aware eviction: the owner's
        # event-time WATERMARK (set_clock) — an entry whose deadline lies
        # behind it can no longer be accessed by an on-time fire.  Using
        # anything faster (e.g. max observed event ts) would misclassify
        # windows awaiting fire as stale during the watermark lag.
        self.clock = float("-inf")
        self._gen = 0
        self.used = 0
        self.on_writeback = on_writeback
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.writebacks = 0
        self.prefetch_insertions = 0
        self.prefetch_unused_evicted = 0
        # per-lookahead-origin accounting for mismatch attribution
        self.pf_ins_by_origin: Dict[str, int] = {}
        self.pf_unused_by_origin: Dict[str, int] = {}
        # eviction-reason breakdown (DESIGN.md §12): (reason, admission)
        # -> count, reason in {capacity, deadline, stale}, admission in
        # {prefetched, demand} by how the victim was admitted
        self.evict_reasons: Dict[Tuple[str, str], int] = {}
        # optional prefetch-quality recorder (repro.obs.quality); when set,
        # staged/used/wasted outcomes and signed lead times flow to the
        # metrics registry
        self.recorder = None

    # ------------------------------------------------------------- internals
    def _push(self, e: Entry) -> None:
        self._gen += 1
        heapq.heappush(self._heap, (e.ts, self._gen, e.key))
        if self.deadline_aware:
            heapq.heappush(self._fheap, (-e.ts, self._gen, e.key))

    def _remove_victim(self, e: Entry, reason: str = "capacity") -> None:
        del self.entries[e.key]
        self.used -= e.size
        self.evictions += 1
        pf = getattr(e, "prefetched_unused", False)
        adm = "prefetched" if getattr(e, "prefetched", False) else "demand"
        self.evict_reasons[(reason, adm)] = \
            self.evict_reasons.get((reason, adm), 0) + 1
        if pf:
            self.prefetch_unused_evicted += 1
            org = getattr(e, "origin", "")
            self.pf_unused_by_origin[org] = \
                self.pf_unused_by_origin.get(org, 0) + 1
            if self.recorder is not None:
                self.recorder.on_wasted()
        if e.dirty:
            self.evict_buffer[e.key] = e                   # async write-back

    def _evict_one(self) -> None:
        if self.deadline_aware:
            # stale first (oldest observed-access ts), skipping lazy
            # records; stop at the first entry whose ts is a live deadline
            while self._heap:
                ts, _, key = self._heap[0]
                e = self.entries.get(key)
                if e is None or e.ts != ts:
                    heapq.heappop(self._heap)
                    continue
                if ts >= self.clock:
                    break                   # only future deadlines remain
                heapq.heappop(self._heap)
                self._remove_victim(e, reason="stale")
                return
            # all live: farthest deadline goes first (Belady on deadlines)
            while self._fheap:
                nts, _, key = heapq.heappop(self._fheap)
                e = self.entries.get(key)
                if e is None or e.ts != -nts:
                    continue
                self._remove_victim(e, reason="deadline")
                return
        while self._heap:
            ts, _, key = heapq.heappop(self._heap)
            e = self.entries.get(key)
            if e is None or e.ts != ts:
                continue                                   # stale heap record
            self._remove_victim(e, reason="capacity")
            return
        return

    def _make_room(self, size: int) -> None:
        while self.used + size > self.capacity and (self._heap or self.entries):
            before = self.used
            self._evict_one()
            if self.used == before:
                break

    def set_clock(self, watermark: float) -> None:
        """Advance the deadline_aware staleness boundary (the consuming
        operator's event-time watermark)."""
        if watermark > self.clock:
            self.clock = watermark

    # ------------------------------------------------------------ public API
    def lookup(self, key: Any, now_ts: float) -> Optional[Any]:
        """Read by key at event time now_ts.  Refreshes the timestamp.
        Checks the eviction buffer (paper: staged entries move back)."""
        e = self.entries.get(key)
        if e is None:
            staged = self.evict_buffer.pop(key, None)
            if staged is not None:
                self._make_room(staged.size)
                staged.ts = max(staged.ts, now_ts)
                staged.prefetched_unused = False
                self.entries[key] = staged
                self.used += staged.size
                self._push(staged)
                self.hits += 1
                return staged.state
            self.misses += 1
            return None
        self.hits += 1
        if now_ts > e.ts:
            e.ts = now_ts
            self._push(e)
        if getattr(e, "prefetched_unused", False) and \
                self.recorder is not None:
            # first read of a staged entry: signed lead time (now minus
            # stage-complete) flows to the registry
            self.recorder.on_used(getattr(e, "stage_t", 0.0))
        e.prefetched_unused = False
        return e.state

    def contains(self, key: Any) -> bool:
        return key in self.entries or key in self.evict_buffer

    def insert(self, key: Any, state: Any, ts: float, dirty: bool = False,
               size: int = 1, prefetched: bool = False,
               origin: str = "") -> None:
        """Insert/overwrite an entry (after an access or a completed fetch)."""
        old = self.entries.get(key)
        if old is not None:
            self.used -= old.size
        self.evict_buffer.pop(key, None)
        self._make_room(size)
        e = Entry(key, state, ts, dirty, size)
        e.prefetched_unused = prefetched
        e.prefetched = prefetched          # admission path, for evict split
        e.origin = origin
        self.entries[key] = e
        self.used += size
        self._push(e)
        if prefetched:
            self.prefetch_insertions += 1
            self.pf_ins_by_origin[origin] = \
                self.pf_ins_by_origin.get(origin, 0) + 1
            if self.recorder is not None:
                e.stage_t = self.recorder.now()
                self.recorder.on_staged()

    def write(self, key: Any, state: Any, now_ts: float, size: int = 1
              ) -> None:
        """Update state in cache (read-modify-write ops); marks dirty."""
        e = self.entries.get(key)
        if e is not None:
            e.state = state
            e.dirty = True
            e.prefetched_unused = False
            if now_ts > e.ts:
                e.ts = now_ts
                self._push(e)
            return
        self.insert(key, state, now_ts, dirty=True, size=size)

    def renew(self, key: Any, hint_ts: float) -> bool:
        """A hint arrived for a cached key: bump its predicted relevance."""
        e = self.entries.get(key)
        if e is None:
            staged = self.evict_buffer.pop(key, None)
            if staged is None:
                return False
            self._make_room(staged.size)
            staged.ts = max(staged.ts, hint_ts)
            self.entries[key] = staged
            self.used += staged.size
            self._push(staged)
            return True
        if hint_ts > e.ts:
            e.ts = hint_ts
            self._push(e)
        return True

    def drop(self, key: Any) -> bool:
        """Remove an entry outright — resident or staged — with NO
        write-back and no unused-prefetch accounting.  The window purge
        path (DESIGN.md §10): once a pane has fired and its lateness
        horizon passed, its state is dead and must not cost a backend
        write.  Heap records left behind go stale and are skipped lazily."""
        e = self.entries.pop(key, None)
        if e is not None:
            self.used -= e.size
            return True
        return self.evict_buffer.pop(key, None) is not None

    def export_entries(self, pred: Callable[[Any], bool]) -> List[Entry]:
        """Shard migration drain (DESIGN.md §9): pop every entry — resident
        or staged in the eviction buffer — whose key satisfies ``pred``.
        Timestamps and dirty bits ride along so the destination subtask
        re-inserts with the SAME eviction priority; heap records left behind
        go stale and are skipped lazily."""
        out = []
        for key in [k for k in self.entries if pred(k)]:
            e = self.entries.pop(key)
            self.used -= e.size
            out.append(e)
        for key in [k for k in self.evict_buffer if pred(k)]:
            out.append(self.evict_buffer.pop(key))
        return out

    def import_entries(self, entries: List[Entry],
                       now_ts: float = 0.0) -> int:
        """Inverse of ``export_entries`` (migration re-admit §9, snapshot
        restore roundtrips §7 — DESIGN.md): re-insert exported entries
        preserving their timestamps and dirty bits, so the destination
        cache reproduces the SAME eviction order (including the
        deadline-aware order — ordering is a pure function of entry
        timestamps and the clock).  Entries without a timestamp (LRU/
        Clock exports crossing policies) enter at ``now_ts``."""
        for e in entries:
            self.insert(e.key, e.state, getattr(e, "ts", now_ts),
                        dirty=e.dirty, size=e.size)
        return len(entries)

    def pop_writeback(self) -> Optional[Entry]:
        """State thread pool: take one dirty entry to write to the backend."""
        if not self.evict_buffer:
            return None
        key = next(iter(self.evict_buffer))
        e = self.evict_buffer.pop(key)
        self.writebacks += 1
        return e

    def flush_dirty(self) -> List[Entry]:
        """Checkpoint barrier: all dirty state (resident + staged) to persist
        (paper §IV-E)."""
        out = [e for e in self.entries.values() if e.dirty]
        out += list(self.evict_buffer.values())
        for e in out:
            e.dirty = False
        self.evict_buffer.clear()
        return out

    def eviction_block(self) -> Dict[str, int]:
        """Flat ``"<reason>.<admission>" -> count`` rollup of the
        eviction-reason breakdown (DESIGN.md §12)."""
        return {f"{r}.{a}": n
                for (r, a), n in sorted(self.evict_reasons.items())}

    def __len__(self) -> int:
        return len(self.entries)

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0
