"""Selective hint admission for lookahead operators (DESIGN.md §13).

Every lookahead used to run one fixed rule: suppress the hint iff the
CMS classifies the key hot (paper §IV-B — hot keys are presumed
cache-resident).  This module generalises that into a per-subtask
``HintFilter`` with three modes:

  * ``all`` — admit everything (the ablation baseline; the CMS still
    counts so estimates stay comparable across modes).
  * ``hot`` — the legacy rule, bit-identical to the old inline
    ``update_and_classify`` call (the repo-wide default: existing
    benchmarks and their gates keep their behaviour).
  * ``selective`` — layered admission (decision table in §13):

      1. *residency* — a key hinted within ``resident_ttl`` was staged
         moments ago and is still resident or in flight; re-hinting is a
         duplicate (the PrefetchingManager would only renew it).  Only
         applied when the CMS estimate is >= ``resident_min_est``: a
         recently-hinted COLD key may already have been evicted (its
         staged entry loses every capacity fight), so "recently hinted"
         implies "still resident" only for keys hot enough to win
         renewals — suppressing below that estimate trades misses for
         saved duplicates at a bad rate.
      2. *cold* — CMS estimate <= ``cold_threshold``: the key is too
         cold for its staged entry to survive until a second access;
         under cache pressure such stagings end ``wasted``.  Off by
         default (0): suppressing first-occurrence keys trades recall
         for precision and must be an explicit choice.
      3. *budget* — a token bucket of ``budget_per_s`` admissions;
         when the bucket is dry only keys with estimate >=
         ``priority_threshold`` pass (hot-key prioritisation under
         hint-channel saturation).  Off by default (0 = unlimited).

Frequency vs identity: ``admit(key, now, freq_key=...)`` separates the
key being hinted (a ``WindowKey`` pane, say) from the key whose
FREQUENCY predicts its future (the pane's base key, stable across
windows).  ``hot`` mode ignores ``freq_key`` — the legacy rule counted
the full pane key, so suppression reset each window, and that behaviour
is preserved exactly.

Speculation (§13): the filter also decides which keys are worth hinting
*before* they appear upstream — ``speculate_ok`` gates next-pane window
pre-hints and join-partner frontier hints on the frequency estimate, and
``note_emit`` marks speculated keys resident so the later data-driven
hint is suppressed as a correct duplicate.

``classify_batch`` is the device twin: it feeds a key batch through the
``cms_sketch`` Pallas kernel (its own multiply-shift hashes and counter
state — same SEMANTICS as the host sketch, not the same hash values; see
repro/kernels/cms_sketch).  The tuple-at-a-time engine stays on the host
path; the batched path serves the device-resident fused pipeline and is
validated against the host semantics in tests.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.cms import CountMinFilter

MODES = ("all", "hot", "selective")

# admission verdicts (counter keys; "emitted" is the admit outcome)
EMIT = "emitted"
SUPPRESS_HOT = "suppressed_hot"
SUPPRESS_RESIDENT = "suppressed_resident"
SUPPRESS_COLD = "suppressed_cold"
SUPPRESS_BUDGET = "suppressed_budget"


class HintFilter:
    def __init__(self, mode: str = "hot",
                 cms_conf: Optional[dict] = None,
                 resident_ttl: float = 0.050,
                 resident_min_est: int = 0,
                 cold_threshold: int = 0,
                 budget_per_s: float = 0.0,
                 priority_threshold: Optional[int] = None,
                 speculative: bool = False,
                 spec_width: int = 2,
                 spec_min_est: Optional[int] = None,
                 sweep_every: int = 4096):
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        self.mode = mode
        self.cms = CountMinFilter(**(cms_conf or {}))
        self.resident_ttl = float(resident_ttl)
        self.resident_min_est = int(resident_min_est)
        self.cold_threshold = int(cold_threshold)
        self.budget_per_s = float(budget_per_s)
        self.priority_threshold = int(
            self.cms.threshold if priority_threshold is None
            else priority_threshold)
        self.speculative = bool(speculative)
        self.spec_width = int(spec_width)
        # a key is worth speculating on once its frequency estimate says
        # it is trending hot (half the hot threshold by default)
        self.spec_min_est = int(
            max(1, self.cms.threshold // 2) if spec_min_est is None
            else spec_min_est)
        self.counters: Dict[str, int] = {
            EMIT: 0, SUPPRESS_HOT: 0, SUPPRESS_RESIDENT: 0,
            SUPPRESS_COLD: 0, SUPPRESS_BUDGET: 0}
        self.last_verdict = EMIT
        # residency model: key -> last admit time, swept lazily
        self._last_emit: Dict[Any, float] = {}
        self._sweep_every = int(sweep_every)
        self._since_sweep = 0
        # token bucket (admissions); 20 ms of burst headroom
        self._tokens = max(1.0, self.budget_per_s * 0.020)
        self._bucket_cap = self._tokens
        self._last_refill = 0.0
        # device-twin state for classify_batch, built lazily on first use
        self._dev = None

    # -------------------------------------------------------------- admission
    def admit(self, key: Any, now: float, freq_key: Any = None) -> bool:
        """One hint-extraction decision; True = emit the hint.  The CMS
        counts on every call in every mode, so switching modes mid-run
        (or comparing modes across runs) keeps the frequency state
        comparable."""
        if self.mode == "hot":
            # legacy rule, counter-for-counter identical to the old
            # inline path (freq_key deliberately ignored — see module
            # docstring)
            if self.cms.update_and_classify(key):
                self.counters[SUPPRESS_HOT] += 1
                self.last_verdict = SUPPRESS_HOT
                return False
            self.counters[EMIT] += 1
            self.last_verdict = EMIT
            return True
        est, _hot = self.cms.update(key if freq_key is None else freq_key)
        if self.mode == "all":
            self.counters[EMIT] += 1
            self.last_verdict = EMIT
            return True
        # selective: residency -> cold -> budget
        if est >= self.resident_min_est:
            last = self._last_emit.get(key)
            if last is not None and now - last < self.resident_ttl:
                self.counters[SUPPRESS_RESIDENT] += 1
                self.last_verdict = SUPPRESS_RESIDENT
                return False
        if est <= self.cold_threshold:
            self.counters[SUPPRESS_COLD] += 1
            self.last_verdict = SUPPRESS_COLD
            return False
        if self.budget_per_s > 0:
            self._refill(now)
            if self._tokens >= 1.0:
                self._tokens -= 1.0
            elif est < self.priority_threshold:
                # bucket dry: only hot-key hints pass (prioritisation
                # under hint-channel saturation)
                self.counters[SUPPRESS_BUDGET] += 1
                self.last_verdict = SUPPRESS_BUDGET
                return False
        self.counters[EMIT] += 1
        self.last_verdict = EMIT
        self.note_emit(key, now)
        return True

    def _refill(self, now: float) -> None:
        dt = now - self._last_refill
        self._last_refill = now
        if dt > 0:
            self._tokens = min(self._bucket_cap,
                               self._tokens + dt * self.budget_per_s)

    def note_emit(self, key: Any, now: float) -> None:
        """Record that a hint for ``key`` went out at ``now`` (also
        called for speculative hints, so the later data-driven hint for
        the same key is suppressed as resident — a correct duplicate)."""
        self._last_emit[key] = now
        self._since_sweep += 1
        if self._since_sweep >= self._sweep_every:
            self._since_sweep = 0
            cut = now - self.resident_ttl
            self._last_emit = {k: t for k, t in self._last_emit.items()
                               if t >= cut}

    # ------------------------------------------------------------ speculation
    def speculate_ok(self, freq_key: Any) -> bool:
        """Is ``freq_key`` hot enough to justify a speculative hint for
        a key PREDICTED from it (next window pane, next join partner)?"""
        return (self.speculative
                and self.cms.estimate(freq_key) >= self.spec_min_est)

    # ---------------------------------------------------------------- rollup
    def metrics_block(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"mode": self.mode}
        out.update(self.counters)
        return out

    def reset(self) -> None:
        """Crash semantics (DESIGN.md §7): filter state is soft —
        frequency counters, residency map, and bucket all re-learn."""
        self.cms.reset()
        self._last_emit.clear()
        self._since_sweep = 0
        self._tokens = self._bucket_cap
        self._dev = None

    # ------------------------------------------------------------ device twin
    def classify_batch(self, keys):
        """Batched hot/cold classification through the ``cms_sketch``
        Pallas kernel (interpret mode on CPU).  Maintains a SEPARATE
        counter/hash state from the host sketch — the two share
        semantics, not hash values — and applies the same aging rule
        (halve every ``aging_interval`` updates).  Returns a bool[B]
        hot mask."""
        import numpy as np
        from repro.kernels.cms_sketch.ops import cms_update_and_classify
        cms = self.cms
        if self._dev is None:
            rng = np.random.RandomState(1)
            self._dev = {
                "counters": np.zeros((cms.d, cms.w), dtype=np.int32),
                "a": (rng.randint(1, 2 ** 31 - 1, size=cms.d)
                      .astype(np.uint32) | 1),
                "b": rng.randint(0, 2 ** 31 - 1,
                                 size=cms.d).astype(np.uint32),
                "since_aging": 0,
            }
        dev = self._dev
        keys = np.asarray(keys, dtype=np.int32)
        new_counters, hot = cms_update_and_classify(
            keys, dev["counters"], dev["a"], dev["b"],
            threshold=cms.threshold, max_count=cms.max_count,
            interpret=True)
        counters = np.asarray(new_counters)
        dev["since_aging"] += int(keys.shape[0])
        if dev["since_aging"] >= cms.aging_interval:
            counters = counters >> 1
            dev["since_aging"] = 0
        dev["counters"] = counters
        return np.asarray(hot)
