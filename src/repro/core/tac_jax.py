"""Device-side Timestamp-Aware Cache: fixed-slot, functional, jittable.

The accelerator twin of ``repro.core.tac``: state rows live in
(n_buckets x ways) slots; eviction picks the min-timestamp way within the
key's bucket (set-associative; with n_buckets=1 it is exactly the paper's
fully-associative min-ts policy — the equivalence test in
tests/test_tac_jax.py checks eviction-order agreement with the Python TAC).
Lookups go through the ``tac_probe`` Pallas kernel; admissions are a scan
(duplicate keys in one batch must see each other's effects).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.tac_probe.ops import bucket_of, tac_probe


class TACState(NamedTuple):
    keys: jax.Array        # [n_buckets, ways] int32, -1 = empty
    ts: jax.Array          # [n_buckets, ways] fp32
    vals: jax.Array        # [n_buckets, ways, D]
    dirty: jax.Array       # [n_buckets, ways] bool


def init(n_buckets: int, ways: int, d: int,
         dtype=jnp.float32) -> TACState:
    return TACState(
        keys=jnp.full((n_buckets, ways), -1, jnp.int32),
        ts=jnp.full((n_buckets, ways), -jnp.inf, jnp.float32),
        vals=jnp.zeros((n_buckets, ways, d), dtype),
        dirty=jnp.zeros((n_buckets, ways), bool))


def lookup(state: TACState, qkeys: jax.Array, now_ts: jax.Array,
           interpret: bool = True
           ) -> Tuple[jax.Array, jax.Array, TACState]:
    """Batched probe+gather; refreshes timestamps of hits (max with now)."""
    vals, hit, way = tac_probe(qkeys, state.keys, state.vals,
                               interpret=interpret)
    b = bucket_of(qkeys, state.keys.shape[0])
    safe_way = jnp.maximum(way, 0)
    cur = state.ts[b, safe_way]
    new_ts = state.ts.at[b, safe_way].max(
        jnp.where(hit.astype(bool), now_ts, cur))
    return vals, hit.astype(bool), state._replace(ts=new_ts)


def renew(state: TACState, keys: jax.Array, hint_ts: jax.Array) -> TACState:
    """Bump predicted relevance of cached keys (hint for a cached entry)."""
    _, hit, way = tac_probe(keys, state.keys, state.vals, interpret=True)
    b = bucket_of(keys, state.keys.shape[0])
    safe = jnp.maximum(way, 0)
    cur = state.ts[b, safe]
    new_ts = state.ts.at[b, safe].max(
        jnp.where(hit.astype(bool), hint_ts, cur))
    return state._replace(ts=new_ts)


def admit(state: TACState, keys: jax.Array, ts: jax.Array,
          vals: jax.Array, dirty: jax.Array = None) -> TACState:
    """Insert a batch (prefetched or freshly computed state).  Sequential
    over the batch so duplicate buckets compose; each insert overwrites a
    matching key if present, else evicts the bucket's min-ts way."""
    if dirty is None:
        dirty = jnp.zeros(keys.shape, bool)
    n_buckets = state.keys.shape[0]

    def one(st: TACState, inp):
        k, t, v, d = inp
        b = bucket_of(k[None], n_buckets)[0]
        bkeys = st.keys[b]
        bts = st.ts[b]
        match = bkeys == k
        way = jnp.where(match.any(), jnp.argmax(match), jnp.argmin(bts))
        # overwrite semantics match TimestampAwareCache.insert (ts replaced)
        new_ts = t
        return TACState(
            keys=st.keys.at[b, way].set(k),
            ts=st.ts.at[b, way].set(new_ts),
            vals=st.vals.at[b, way].set(v.astype(st.vals.dtype)),
            dirty=st.dirty.at[b, way].set(d)), None

    state, _ = jax.lax.scan(one, state, (keys, ts, vals, dirty))
    return state
