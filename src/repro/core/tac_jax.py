"""Device-side Timestamp-Aware Cache: fixed-slot, functional, jittable.

The accelerator twin of ``repro.core.tac``: state rows live in
(n_buckets x ways) slots; eviction picks the min-timestamp way within the
key's bucket (set-associative; with n_buckets=1 it is exactly the paper's
fully-associative min-ts policy — the equivalence test in
tests/test_tac_jax.py checks eviction-order agreement with the Python TAC).
Lookups go through the ``tac_probe`` Pallas kernel.  Admissions come in two
flavours: ``admit`` scans the batch sequentially (reference semantics:
duplicate keys in one batch must see each other's effects), and
``admit_batch`` vectorizes — keys in distinct buckets land in ONE fused
update, same-bucket collisions resolve in batch order over conflict rounds,
and the chosen slot + displaced key/dirty bit are reported per key (the
serving arena's write-back path needs them).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.page_gather.page_gather import (page_gather_kernel,
                                                   page_scatter_kernel)
from repro.kernels.page_gather.ref import page_gather_ref
from repro.kernels.tac_probe.ops import bucket_of, tac_probe, \
    tac_probe_gather
from repro.kernels.tac_probe.ref import tac_probe_ref


class TACState(NamedTuple):
    keys: jax.Array        # [n_buckets, ways] int32, -1 = empty
    ts: jax.Array          # [n_buckets, ways] fp32
    vals: jax.Array        # [n_buckets, ways, D]
    dirty: jax.Array       # [n_buckets, ways] bool


def init(n_buckets: int, ways: int, d: int,
         dtype=jnp.float32) -> TACState:
    return TACState(
        keys=jnp.full((n_buckets, ways), -1, jnp.int32),
        ts=jnp.full((n_buckets, ways), -jnp.inf, jnp.float32),
        vals=jnp.zeros((n_buckets, ways, d), dtype),
        dirty=jnp.zeros((n_buckets, ways), bool))


def lookup(state: TACState, qkeys: jax.Array, now_ts: jax.Array,
           interpret: bool = True
           ) -> Tuple[jax.Array, jax.Array, TACState]:
    """Batched probe+gather; refreshes timestamps of hits (max with now)."""
    vals, hit, way = tac_probe(qkeys, state.keys, state.vals,
                               interpret=interpret)
    b = bucket_of(qkeys, state.keys.shape[0])
    safe_way = jnp.maximum(way, 0)
    cur = state.ts[b, safe_way]
    new_ts = state.ts.at[b, safe_way].max(
        jnp.where(hit.astype(bool), now_ts, cur))
    return vals, hit.astype(bool), state._replace(ts=new_ts)


def renew(state: TACState, keys: jax.Array, hint_ts: jax.Array) -> TACState:
    """Bump predicted relevance of cached keys (hint for a cached entry)."""
    _, hit, way = tac_probe(keys, state.keys, state.vals, interpret=True)
    b = bucket_of(keys, state.keys.shape[0])
    safe = jnp.maximum(way, 0)
    cur = state.ts[b, safe]
    new_ts = state.ts.at[b, safe].max(
        jnp.where(hit.astype(bool), hint_ts, cur))
    return state._replace(ts=new_ts)


def admit(state: TACState, keys: jax.Array, ts: jax.Array,
          vals: jax.Array, dirty: jax.Array = None) -> TACState:
    """Insert a batch (prefetched or freshly computed state).  Sequential
    over the batch so duplicate buckets compose; each insert overwrites a
    matching key if present, else evicts the bucket's min-ts way."""
    if dirty is None:
        dirty = jnp.zeros(keys.shape, bool)
    n_buckets = state.keys.shape[0]

    def one(st: TACState, inp):
        k, t, v, d = inp
        b = bucket_of(k[None], n_buckets)[0]
        bkeys = st.keys[b]
        bts = st.ts[b]
        match = bkeys == k
        way = jnp.where(match.any(), jnp.argmax(match), jnp.argmin(bts))
        # overwrite semantics match TimestampAwareCache.insert (ts replaced)
        new_ts = t
        return TACState(
            keys=st.keys.at[b, way].set(k),
            ts=st.ts.at[b, way].set(new_ts),
            vals=st.vals.at[b, way].set(v.astype(st.vals.dtype)),
            dirty=st.dirty.at[b, way].set(d)), None

    state, _ = jax.lax.scan(one, state, (keys, ts, vals, dirty))
    return state


class AdmitResult(NamedTuple):
    state: TACState
    slots: jax.Array          # [B] int32 flat slot (bucket * ways + way)
    evicted_keys: jax.Array   # [B] int32 displaced key, -1 = none/overwrite
    evicted_dirty: jax.Array  # [B] bool  dirty bit of the displaced key


@jax.jit
def admit_batch(state: TACState, keys: jax.Array, ts: jax.Array,
                vals: jax.Array = None, dirty: jax.Array = None
                ) -> AdmitResult:
    """Vectorized multi-key admit.

    Keys hashing to DISTINCT buckets are admitted in one fused update (no
    ``lax.scan`` over the batch); keys colliding in a bucket are resolved in
    batch order over conflict rounds (``lax.while_loop``, trip count = max
    same-bucket multiplicity, 1 for collision-free batches).  Semantics are
    exactly sequential ``admit``: overwrite a matching key, else evict the
    bucket's min-ts way.

    Returns the new state plus, per admitted key, the flat slot it landed in
    and the key/dirty-bit it displaced (-1/False when the way was empty or
    held the same key) — callers owning slot-addressed payloads (the paged
    arena) use these to write dirty victims back before re-staging.
    """
    B = keys.shape[0]
    n_buckets, ways = state.keys.shape
    if vals is None:
        vals = jnp.zeros((B, state.vals.shape[-1]), state.vals.dtype)
    if dirty is None:
        dirty = jnp.zeros((B,), bool)
    b = bucket_of(keys, n_buckets)
    # occurrence rank within each bucket, in batch order
    same_before = (b[:, None] == b[None, :]) & \
        jnp.tril(jnp.ones((B, B), bool), k=-1)
    rank = same_before.sum(axis=1).astype(jnp.int32)
    n_rounds = rank.max() + 1

    def round_body(carry):
        r, st, slots, ev_k, ev_d = carry
        active = rank == r
        bkeys = st.keys[b]                               # [B, ways]
        bts = st.ts[b]
        match = bkeys == keys[:, None]
        hit = match.any(axis=1)
        way = jnp.where(hit, jnp.argmax(match, axis=1),
                        jnp.argmin(bts, axis=1)).astype(jnp.int32)
        old_key = jnp.take_along_axis(bkeys, way[:, None], 1)[:, 0]
        old_dirty = st.dirty[b, way]
        # masked scatter: active lanes have unique buckets this round, so a
        # one-hot add is an exact set and duplicate-index order never matters
        act_i = active.astype(jnp.int32)
        cnt = jnp.zeros((n_buckets, ways), jnp.int32).at[b, way].add(act_i)
        mask = cnt > 0
        grid_k = jnp.zeros((n_buckets, ways), jnp.int32) \
            .at[b, way].add(jnp.where(active, keys, 0))
        grid_t = jnp.zeros((n_buckets, ways), jnp.float32) \
            .at[b, way].add(jnp.where(active, ts, 0.0))
        grid_d = jnp.zeros((n_buckets, ways), jnp.int32) \
            .at[b, way].add(jnp.where(active, dirty.astype(jnp.int32), 0))
        grid_v = jnp.zeros_like(st.vals).at[b, way].add(
            jnp.where(active[:, None], vals.astype(st.vals.dtype), 0))
        st = TACState(
            keys=jnp.where(mask, grid_k, st.keys),
            ts=jnp.where(mask, grid_t, st.ts),
            vals=jnp.where(mask[..., None], grid_v, st.vals),
            dirty=jnp.where(mask, grid_d > 0, st.dirty))
        slots = jnp.where(active, b * ways + way, slots)
        displaced = active & ~hit & (old_key >= 0)
        ev_k = jnp.where(displaced, old_key, ev_k)
        ev_d = jnp.where(displaced, old_dirty, ev_d)
        return r + 1, st, slots, ev_k, ev_d

    init = (jnp.int32(0), state, jnp.zeros((B,), jnp.int32),
            jnp.full((B,), -1, jnp.int32), jnp.zeros((B,), bool))
    _, state, slots, ev_k, ev_d = jax.lax.while_loop(
        lambda c: c[0] < n_rounds, round_body, init)
    return AdmitResult(state, slots, ev_k, ev_d)


# ----------------------------------------------------------- sharded plane
# Key ownership in the sharded state plane (DESIGN.md §9): non-negative
# int32 keys are assigned to shards by modulo, which agrees with the
# engine-side ``hash_partition`` for ints (CPython hash(i) == i for small
# non-negative ints), so a hint routed host-side and a page admitted
# device-side land at the same owner.

def shard_of(keys: jax.Array, n_shards: int) -> jax.Array:
    """Owning shard per key (device-side twin of ``hash_partition``)."""
    return jnp.mod(jnp.asarray(keys, jnp.int32), n_shards)


def shard_mask(keys: jax.Array, shard_id: int, n_shards: int) -> jax.Array:
    """True where ``shard_id`` owns the key."""
    return shard_of(keys, n_shards) == shard_id


def probe_owned(state: TACState, keys: jax.Array, shard_id: int,
                n_shards: int, interpret: bool = True
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Shard-local probe: foreign keys (misrouted in the shard plane) are
    forced to miss so a stray probe can never refresh another shard's
    entries.  Returns (vals, hit, owned) — callers count ``~owned`` lanes
    as misroutes, not misses."""
    keys = jnp.asarray(keys, jnp.int32)
    owned = shard_mask(keys, shard_id, n_shards)
    vals, hit, _ = tac_probe(keys, state.keys, state.vals,
                             interpret=interpret)
    return vals, hit.astype(bool) & owned, owned


def admit_owned(state: TACState, keys: jax.Array, ts: jax.Array,
                shard_id: int, n_shards: int, vals: jax.Array = None,
                dirty: jax.Array = None) -> Tuple[AdmitResult, int]:
    """Shard-local admit: drops keys the shard does not own before the
    batched admit (a misrouted admit would orphan a page — no hint or probe
    would ever find it on this shard again).  Host-side filter (shapes are
    data-dependent); returns (AdmitResult over the owned subset, n_dropped).
    """
    keys = jnp.asarray(keys, jnp.int32)
    owned = np.asarray(shard_mask(keys, shard_id, n_shards))
    n_dropped = int((~owned).sum())
    if n_dropped == 0:
        return admit_batch(state, keys, ts, vals, dirty), 0
    idx = np.nonzero(owned)[0]
    sub = lambda a: None if a is None else jnp.asarray(a)[idx]
    if len(idx) == 0:
        empty = AdmitResult(state, jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), bool))
        return empty, n_dropped
    return admit_batch(state, sub(keys), sub(ts), sub(vals),
                       sub(dirty)), n_dropped


def evict_expired(state: TACState, watermark: float,
                  retention: Any = 0.0) -> Tuple[TACState, jax.Array]:
    """Watermark-driven bulk reclaim (DESIGN.md §10, §11): invalidate
    every occupied slot whose EXPIRY time lies strictly behind
    ``watermark``.

    Device-side primitive mirroring the engine's pane purge
    (``WindowedStatefulOp._purge_pane``) and interval-key expiry
    (``IntervalJoinOp._purge_key``) for a future windowed/join serving
    path — not yet wired into the scheduler.  The expiry time is
    ``ts + retention``:

      * ``retention == 0`` (default) — the slot timestamp IS the expiry
        deadline (window panes admitted with their fire deadline, §10);
      * ``retention > 0`` — slots admitted at their insertion/access
        timestamp expire at their INTERVAL END instead (interval-join
        entries whose matchability outlives the access that admitted
        them, §11).  ``retention`` may be a scalar (one bound for the
        whole cache) or a ``[n_buckets, ways]`` array (per-slot bounds,
        e.g. side-dependent ``hi`` vs ``−lo``).

    Allowed lateness is folded into ``watermark`` by the caller.  Dirty
    bits are cleared along with the slots: expired state is purged, not
    written back, so callers that still need the data must flush BEFORE
    the watermark passes.  Returns (state, number of slots reclaimed).
    """
    expiry = state.ts + jnp.asarray(retention, state.ts.dtype)
    expired = (state.keys >= 0) & (expiry < watermark)
    return TACState(
        keys=jnp.where(expired, -1, state.keys),
        ts=jnp.where(expired, -jnp.inf, state.ts),
        vals=state.vals,
        dirty=jnp.where(expired, False, state.dirty)), expired.sum()


# --------------------------------------------------------------- migration
class Exported(NamedTuple):
    state: TACState           # source state with the entries cleared
    keys: np.ndarray          # [M] exported keys
    ts: np.ndarray            # [M] their timestamps (preserved end-to-end)
    vals: np.ndarray          # [M, D] their value rows
    dirty: np.ndarray         # [M] their dirty bits
    slots: np.ndarray         # [M] flat source slots (page-payload gather)


def export_mask(state: TACState, mask: np.ndarray) -> Exported:
    """Migration drain: pop every resident entry selected by ``mask`` (a
    host boolean over keys, e.g. a key range or ``shard_mask``) out of the
    cache, preserving timestamps and dirty bits so the destination re-admits
    them with the SAME eviction priority (Megaphone-style fluid migration
    moves state, not recency).  Host-side: migrations are rare, bulk, and
    off the tuple path."""
    keys = np.asarray(state.keys)
    sel = (keys >= 0) & np.asarray(mask)
    if not sel.any():
        return Exported(state, np.zeros((0,), np.int32),
                        np.zeros((0,), np.float32),
                        np.zeros((0, state.vals.shape[-1]), np.float32),
                        np.zeros((0,), bool), np.zeros((0,), np.int32))
    b, w = np.nonzero(sel)
    slots = (b * state.keys.shape[1] + w).astype(np.int32)
    out = Exported(
        state._replace(
            keys=state.keys.at[b, w].set(-1),
            ts=state.ts.at[b, w].set(-jnp.inf),
            dirty=state.dirty.at[b, w].set(False)),
        keys[sel].astype(np.int32),
        np.asarray(state.ts)[sel].astype(np.float32),
        np.asarray(state.vals)[sel],
        np.asarray(state.dirty)[sel],
        slots)
    return out


def import_entries(state: TACState, keys: np.ndarray, ts: np.ndarray,
                   vals: np.ndarray = None,
                   dirty: np.ndarray = None) -> AdmitResult:
    """Migration re-admit at the destination shard: a batched admit that
    keeps the exported timestamps (NOT the migration time — a prefetched
    page whose hint ts lies in the future must stay protected after the
    move, DESIGN.md §9)."""
    keys = jnp.asarray(keys, jnp.int32)
    if keys.shape[0] == 0:
        return AdmitResult(state, jnp.zeros((0,), jnp.int32),
                           jnp.zeros((0,), jnp.int32),
                           jnp.zeros((0,), bool))
    return admit_batch(state, keys, jnp.asarray(ts, jnp.float32),
                       None if vals is None else jnp.asarray(vals),
                       None if dirty is None else jnp.asarray(dirty, bool))


def flush_dirty(state: TACState) -> Tuple[TACState, Exported]:
    """Barrier-time dirty export (DESIGN.md §7): the device twin of
    ``TimestampAwareCache.flush_dirty``.  Returns every DIRTY resident
    row (keys, timestamps, values, flat slots — the write-back batch the
    checkpoint persists) and the state with those dirty bits CLEARED;
    unlike the migration drain (``export_mask``) the entries stay
    resident — a checkpoint snapshots state, it does not evict it.
    Host-side like the other bulk paths: checkpoints are rare and run
    off the tuple path."""
    dirty = np.asarray(state.dirty) & (np.asarray(state.keys) >= 0)
    if not dirty.any():
        return state, Exported(state, np.zeros((0,), np.int32),
                               np.zeros((0,), np.float32),
                               np.zeros((0, state.vals.shape[-1]),
                                        np.float32),
                               np.zeros((0,), bool),
                               np.zeros((0,), np.int32))
    b, w = np.nonzero(dirty)
    slots = (b * state.keys.shape[1] + w).astype(np.int32)
    new_state = state._replace(dirty=state.dirty.at[b, w].set(False))
    exp = Exported(new_state,
                   np.asarray(state.keys)[dirty].astype(np.int32),
                   np.asarray(state.ts)[dirty].astype(np.float32),
                   np.asarray(state.vals)[dirty],
                   np.ones((int(dirty.sum()),), bool),
                   slots)
    return new_state, exp


def set_dirty(state: TACState, keys: jax.Array,
              value: bool = True) -> TACState:
    """Flip the dirty bit of resident keys (no-op for missing keys).

    Miss lanes alias way 0 of their bucket, so the scatter must be
    idempotent under duplicate indices: ``.at[].set`` with a stale value
    could clobber a hit lane's update (unspecified duplicate order) —
    ``.at[].max``/``.at[].min`` with a neutral element cannot."""
    _, hit, way = tac_probe(keys, state.keys, state.vals, interpret=True)
    hit = hit.astype(bool)
    b = bucket_of(keys, state.keys.shape[0])
    safe = jnp.maximum(way, 0)
    d_int = state.dirty.astype(jnp.int32)
    if value:
        d_int = d_int.at[b, safe].max(jnp.where(hit, 1, 0))
    else:
        d_int = d_int.at[b, safe].min(jnp.where(hit, 0, 1))
    return state._replace(dirty=d_int > 0)


# ------------------------------------------------------- fused hot path §14
# The device data plane of the fused execution mode (DESIGN.md §14): the
# stateful-operator inner loop — probe → gather → operator compute →
# scatter write-back — compiled into ONE jitted program per operator
# config.  The payload pool is ``pages [n_slots + 1, 1, V + 1]``: channel
# 0 is a presence flag (0 = the pane was never written; decodes to the
# Python side's ``None``), channels 1..V the value vector, and the LAST
# row a zeroed scratch slot that miss/read/padding lanes alias so their
# scatters are inert.  The host shadow directory (streaming/fused.py)
# owns eviction ORDER and slot assignment; the device directory
# (``TACState.keys``) is authoritative for MEMBERSHIP and the pool for
# payloads — both only change through the entry points below, so they
# agree by construction.

# The fused entry points below are LATENCY-critical: one call per engine
# batch, plus one per single-key cold-path op.  ``interpret=True`` means
# no real TPU backend is in play — and the pallas interpreter emulates
# the kernel grid step by step, orders of magnitude slower than the XLA
# program the same jit would otherwise produce.  So in interpret mode
# the probe/gather/scatter run as the kernels' pure-jnp reference ops
# fused into the surrounding jitted program (bit-identical semantics;
# tests/test_kernels.py holds kernel and reference to each other), and
# the pallas kernels serve the ``interpret=False`` accelerator path.

def _probe_gather(keys, state: "TACState", pages, interpret: bool):
    if not interpret:
        return tac_probe_gather(keys, state.keys, state.vals, pages,
                                interpret=False)
    n_buckets, ways = state.keys.shape
    trash = pages.shape[0] - 1
    if n_buckets == 1:
        # fully-associative fast path (every FusedPlane directory):
        # membership is a broadcast compare against the one bucket, and
        # first-match resolves via iota-min — argmax lowers ~3x slower
        # on the CPU backend, and the directory-vals gather the generic
        # probe does is dead weight here (payloads live in the pool)
        match = state.keys[0][None, :] == keys[:, None]
        iota = jnp.arange(ways, dtype=jnp.int32)
        way = jnp.min(jnp.where(match, iota, ways), axis=1)
        hit = way < ways
        slots = jnp.where(hit, jnp.minimum(way, ways - 1),
                          trash).astype(jnp.int32)
    else:
        buckets = bucket_of(keys, n_buckets)
        _, hiti, way = tac_probe_ref(keys.astype(jnp.int32), buckets,
                                     state.keys, state.vals)
        hit = hiti.astype(bool)
        slots = jnp.where(hit, buckets * ways + jnp.maximum(way, 0),
                          trash).astype(jnp.int32)
    return page_gather_ref(slots, pages), hit, slots


def _gather(slots, pages, interpret: bool):
    if not interpret:
        return page_gather_kernel(slots, pages, interpret=False)
    return page_gather_ref(slots, pages)


def _scatter(slots, blocks, pages, interpret: bool):
    if not interpret:
        return page_scatter_kernel(slots, blocks, pages, interpret=False)
    # last-write-wins matching the kernel's grid order: non-final writes
    # to a duplicated slot redirect to the scratch row (the pool's last
    # row, which fused callers keep zeroed / overwrite before reading)
    B = slots.shape[0]
    idx = jnp.arange(B)
    later = (slots[None, :] == slots[:, None]) & \
        (idx[None, :] > idx[:, None])
    eff = jnp.where(later.any(axis=1), pages.shape[0] - 1, slots)
    return pages.at[eff].set(blocks)


class FusedStep(NamedTuple):
    state: TACState
    pages: jax.Array
    hit: jax.Array        # [B] bool   (padding lanes forced False)
    slots: jax.Array      # [B] int32  flat slot; scratch for miss/padding
    new_vals: jax.Array   # [B, V]     value AFTER this lane's update,
    #                       prefix-composed over earlier same-key lanes
    present: jax.Array    # [B] bool   presence flag after this lane
    tallies: jax.Array    # [2] int32  (hits, misses) over valid lanes


@partial(jax.jit, static_argnames=("kind", "interpret"))
def fused_step(state: TACState, pages: jax.Array, keys: jax.Array,
               ts: jax.Array, weights: jax.Array, fire: jax.Array,
               valid: jax.Array, *, kind: str = "sum",
               interpret: bool = True) -> FusedStep:
    """One fused batch over the resident working set.

    ``kind`` picks the operator compute (static — one compiled program
    per operator config): ``sum`` (count is sum of ones), ``max``, or
    ``read`` (no state update, read-only enrichment).  ``weights`` is
    ``[B, V]``; ``fire`` lanes read the pane without updating it.

    Duplicate keys in one batch compose EXACTLY as the interpreted
    sequential loop: lane i's ``new_vals`` folds in every earlier
    same-key update lane (lower-triangular mask), and the scatter's
    last-write-wins grid order leaves the final composed value in the
    pool.  The batching contract (streaming/fused.py) never mixes a fire
    lane and an update lane of the same key in one batch.

    Miss lanes are NOT admitted here — the host parks their tuples and
    admissions arrive later through ``fused_admit`` (the asynchronous
    fetch path, DESIGN.md §2) — so a miss lane's only trace is its tally.
    """
    B = keys.shape[0]
    n_buckets, ways = state.keys.shape
    trash = pages.shape[0] - 1
    rows, hit, slots = _probe_gather(keys, state, pages, interpret)
    hit = hit & valid
    slots = jnp.where(hit, slots, trash)
    safe_b = jnp.where(hit, slots // ways, 0)
    safe_w = jnp.where(hit, slots % ways, 0)
    # timestamp refresh on hits (advisory fp32 copy; the fp64 eviction
    # order lives in the host shadow, §14)
    new_ts = state.ts.at[safe_b, safe_w].max(
        jnp.where(hit, ts, -jnp.inf))
    g = rows[:, 0, 1:]                         # [B, V] current value
    f = rows[:, 0, 0] > 0.5                    # [B] presence
    if kind == "read":
        upd = jnp.zeros_like(hit)
    else:
        upd = hit & ~fire
    same = keys[:, None] == keys[None, :]
    M = same & upd[None, :] & jnp.tril(jnp.ones((B, B), bool))
    hasupd = M.any(axis=1)
    if kind == "max":
        m = jnp.where(M[:, :, None], weights[None, :, :],
                      -jnp.inf).max(axis=1)
        new_v = jnp.maximum(jnp.where(f[:, None], g, -jnp.inf), m)
    else:                                      # sum (count = sum of ones)
        new_v = jnp.where(f[:, None], g, 0.0) + \
            M.astype(weights.dtype) @ weights
    present = f | hasupd
    new_v = jnp.where(present[:, None], new_v, 0.0)
    dirty = state.dirty
    if kind != "read":
        blocks = jnp.concatenate(
            [present[:, None].astype(pages.dtype),
             new_v.astype(pages.dtype)], axis=1)[:, None, :]
        wslots = jnp.where(upd, slots, trash)
        pages = _scatter(wslots, blocks, pages, interpret)
        # the scratch row must stay "absent" for future miss gathers
        pages = pages.at[trash].set(0.0)
        d_int = state.dirty.astype(jnp.int32).at[safe_b, safe_w].max(
            jnp.where(upd, 1, 0))
        dirty = d_int > 0
    tallies = jnp.stack([hit.sum(), (valid & ~hit).sum()]
                        ).astype(jnp.int32)
    return FusedStep(state._replace(ts=new_ts, dirty=dirty), pages,
                     hit, slots, new_v, present, tallies)


@partial(jax.jit, static_argnames=("interpret",))
def fused_admit(state: TACState, pages: jax.Array, slots: jax.Array,
                keys: jax.Array, ts: jax.Array, rows: jax.Array,
                present: jax.Array, dirty: jax.Array, *,
                interpret: bool = True):
    """Admit at HOST-CHOSEN slots (the shadow directory resolved victims
    and free slots; a slot may repeat only as an IDENTICAL padding
    duplicate of an earlier lane — chunked flushes pad to fixed jit
    shapes that way).  Gathers the pre-overwrite victim rows first — a
    dirty victim's value feeds the eviction buffer for asynchronous
    write-back — then scatters the new rows and updates the device
    directory.  Returns ``(state, pages, victim_rows [B, 1, V+1])``."""
    n_buckets, ways = state.keys.shape
    b, w = slots // ways, slots % ways
    victim_rows = _gather(slots, pages, interpret)
    blocks = jnp.concatenate(
        [present[:, None].astype(pages.dtype),
         rows.astype(pages.dtype)], axis=1)[:, None, :]
    new_pages = _scatter(slots, blocks, pages, interpret)
    # duplicate pads spill their non-final writes into the scratch row;
    # it must read as "absent" for future miss/padding gathers
    new_pages = new_pages.at[-1].set(0.0)
    st = TACState(
        keys=state.keys.at[b, w].set(keys.astype(jnp.int32)),
        ts=state.ts.at[b, w].set(ts.astype(jnp.float32)),
        vals=state.vals,
        dirty=state.dirty.at[b, w].set(dirty))
    return st, new_pages, victim_rows


@jax.jit
def drop_slots(state: TACState, slots: jax.Array,
               valid: jax.Array) -> TACState:
    """Clear directory entries at host-chosen slots (window-pane purges,
    drops).  Padding lanes (``valid`` False) alias slot 0, so the
    clears use masked min/max scatters that are idempotent no-ops for
    them.  Pool rows are left stale: a cleared slot can no longer be
    probed, and the next ``fused_admit`` overwrites the row."""
    ways = state.keys.shape[1]
    b, w = slots // ways, slots % ways
    imax = jnp.iinfo(jnp.int32).max
    keys = state.keys.at[b, w].min(
        jnp.where(valid, jnp.int32(-1), imax))
    ts = state.ts.at[b, w].min(
        jnp.where(valid, -jnp.inf, jnp.inf))
    d_int = state.dirty.astype(jnp.int32).at[b, w].min(
        jnp.where(valid, 0, 1))
    return state._replace(keys=keys, ts=ts, dirty=d_int > 0)


@partial(jax.jit, static_argnames=("interpret",))
def gather_rows(pages: jax.Array, slots: jax.Array, *,
                interpret: bool = True) -> jax.Array:
    """Pull payload rows at flat slots (single-key adapter reads)."""
    return _gather(slots, pages, interpret)
