"""Prefetching Manager + Prefetching Controller (paper §IV-A / §IV-C).

Engine-agnostic: all times are passed in, so the same logic drives the
discrete-event engine here and a wall-clock runtime on a real deployment.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.hints import HintsBuffer


def _pctl(samples: List[float], q: float) -> float:
    if not samples:
        return float("inf")
    return float(np.percentile(np.asarray(samples), q))


@dataclass
class LookaheadCandidate:
    op_id: str
    plan_pos: int          # position in the query plan (source=0, increasing)


class PrefetchingController:
    """Centralised (JobManager-side) component: keeps, per stateful operator,
    the ordered candidate lookaheads; activates prefetching on demand;
    discards candidates whose key distribution mismatches (paper keeps a 0%
    prefetch-miss threshold => discard current + everything upstream)."""

    def __init__(self, marker_interval: float = 0.100):
        self.candidates: Dict[str, List[LookaheadCandidate]] = {}
        self.active: Dict[str, Optional[str]] = {}
        self.marker_interval = marker_interval
        self.switch_log: List[Tuple[float, str, str, str]] = []

    def register(self, stateful_op: str,
                 candidates: List[LookaheadCandidate]) -> None:
        self.candidates[stateful_op] = sorted(candidates,
                                              key=lambda c: c.plan_pos)
        self.active[stateful_op] = None

    def activate(self, stateful_op: str, now: float = 0.0) -> Optional[str]:
        """First cache misses observed: start with the earliest candidate
        (maximum prefetch window; accuracy then adapts it)."""
        cands = self.candidates.get(stateful_op) or []
        if not cands:
            return None
        if self.active[stateful_op] is None:
            self.active[stateful_op] = cands[0].op_id
            self.switch_log.append((now, stateful_op, "activate",
                                    cands[0].op_id))
        return self.active[stateful_op]

    def report_mismatch(self, stateful_op: str, lookahead_id: str,
                        now: float) -> Optional[str]:
        """Discard the mismatching candidate and all upstream of it, switch
        to the next later one."""
        cands = self.candidates.get(stateful_op) or []
        idx = next((i for i, c in enumerate(cands)
                    if c.op_id == lookahead_id), None)
        if idx is None:
            return self.active.get(stateful_op)
        self.candidates[stateful_op] = cands[idx + 1:]
        new = self.candidates[stateful_op][0].op_id \
            if self.candidates[stateful_op] else None
        self.active[stateful_op] = new
        self.switch_log.append((now, stateful_op, "mismatch", new or "-"))
        return new

    def request_timing_switch(self, stateful_op: str, target_id: str,
                              now: float) -> Optional[str]:
        """Slack-driven move to a (possibly later) candidate; upstream
        candidates are kept (still accurate, just unnecessarily early)."""
        cands = self.candidates.get(stateful_op) or []
        if any(c.op_id == target_id for c in cands):
            if self.active[stateful_op] != target_id:
                self.active[stateful_op] = target_id
                self.switch_log.append((now, stateful_op, "timing",
                                        target_id))
        return self.active[stateful_op]


class PrefetchingManager:
    """Stateful-operator-side: handles hints, measures per-candidate slack
    G_i via markers, tracks state-access latency F and the prefetch-miss
    ratio, and asks the controller to re-select the lookahead."""

    def __init__(self, op_id: str, subtask: int,
                 controller: PrefetchingController,
                 gamma: float = 0.003, window: int = 256,
                 miss_threshold: float = 0.0, min_dwell: float = 2.0,
                 shared: Optional["PrefetchingManager"] = None):
        self.op_id = op_id
        self.subtask = subtask
        self.controller = controller
        self.gamma = gamma
        self.window = window
        self.miss_threshold = miss_threshold
        self.min_dwell = min_dwell
        self.hints = HintsBuffer()
        # adaptation statistics are SHARED across the subtasks of one
        # stateful operator (the decision is per-operator, paper §IV-A)
        if shared is not None:
            self.slack = shared.slack
            self.access_lat = shared.access_lat
            self._origin_base = shared._origin_base
            self._switch_state = shared._switch_state
        else:
            self.slack: Dict[str, List[float]] = {}
            self.access_lat: List[float] = []
            self._origin_base: Dict[str, Tuple[int, int]] = {}
            self._switch_state: Dict[str, float] = {"last_switch": -1e9}
        self._marker_hint_t: Dict[Tuple[int, str], float] = {}
        self.enabled = False
        self.hints_received = 0
        self.hints_late = 0
        self.hints_duplicate = 0
        self.prefetch_hits = 0
        # optional registry histogram mirroring record_access_latency
        # (DESIGN.md §12): the capped adaptation window stays the input
        # to `evaluate`, the sketch keeps the FULL distribution
        self.lat_hist = None

    # ------------------------------------------------------------ activation
    def on_cache_misses(self, now: float) -> Optional[str]:
        if not self.enabled:
            active = self.controller.activate(self.op_id, now)
            self.enabled = active is not None
            return active
        return self.controller.active.get(self.op_id)

    # ----------------------------------------------------------------- hints
    def on_hint(self, key: Any, access_ts: float, cache,
                watermark: Optional[float] = None,
                lateness: float = 0.0) -> bool:
        """Returns True if a fetch should be scheduled for this key.

        ``access_ts`` is the PREDICTED ACCESS TIMESTAMP of ``key`` in the
        clock domain the consumer's cache orders by — event time on the
        streaming engine (tuple event ts, or the window-fire deadline for
        windowed hints), predicted processing time on the serving
        scheduler.  See ``repro.streaming.events.Hint``.  With an event-
        time ``watermark``, hints whose access time already fell behind
        ``watermark - lateness`` target state the operator will drop or
        has purged, so no fetch is scheduled.
        """
        self.hints_received += 1
        if watermark is not None and access_ts < watermark - lateness:
            self.hints_late += 1
            return False                      # late record: will be dropped
        if cache.contains(key):
            self.hints_duplicate += 1
            cache.renew(key, access_ts)
            return False
        if self.hints.pending(key):
            self.hints.add(key, access_ts)
            return False
        self.hints.add(key, access_ts)
        return True

    # --------------------------------------------------------------- markers
    def on_marker_hint(self, marker_id: int, lookahead_id: str,
                       now: float) -> None:
        self._marker_hint_t[(marker_id, lookahead_id)] = now

    def on_marker_data(self, marker_id: int, now: float) -> None:
        done = []
        for (mid, lid), t_hint in self._marker_hint_t.items():
            if mid == marker_id:
                self.slack.setdefault(lid, []).append(now - t_hint)
                if len(self.slack[lid]) > self.window:
                    del self.slack[lid][0]
            if mid <= marker_id:          # also drop stale older rounds
                done.append((mid, lid))
        for k in done:
            del self._marker_hint_t[k]

    def record_access_latency(self, lat: float) -> None:
        self.access_lat.append(lat)
        if len(self.access_lat) > self.window:
            del self.access_lat[0]
        if self.lat_hist is not None:
            self.lat_hist.observe(lat)

    # ------------------------------------------------------------ adaptation
    def evaluate(self, caches, now: float) -> Optional[str]:
        """Periodic (called once per operator on the shared stats):
        (1) mismatch detection — per-ORIGIN prefetch-miss ratio over the
        caches of all subtasks; the offending lookahead (and everything
        upstream of it) is discarded;
        (2) timing — pick the LATEST candidate whose p99 slack covers
        p99 state-access latency + gamma, with dwell-time hysteresis."""
        if not isinstance(caches, (list, tuple)):
            caches = [caches]
        active = self.controller.active.get(self.op_id)
        if not self.enabled or active is None:
            return active
        # ---- per-origin mismatch detection
        ins_by: Dict[str, int] = {}
        unused_by: Dict[str, int] = {}
        for c in caches:
            for org, n in getattr(c, "pf_ins_by_origin", {}).items():
                ins_by[org] = ins_by.get(org, 0) + n
            for org, n in getattr(c, "pf_unused_by_origin", {}).items():
                unused_by[org] = unused_by.get(org, 0) + n
        cands = self.controller.candidates.get(self.op_id) or []
        cand_ids = {c.op_id for c in cands}
        for org in list(ins_by):
            if org not in cand_ids:
                continue                          # already discarded
            base_i, base_u = self._origin_base.get(org, (0, 0))
            ins = ins_by[org] - base_i
            unused = unused_by.get(org, 0) - base_u
            if ins >= 64:
                self._origin_base[org] = (ins_by[org],
                                          unused_by.get(org, 0))
                if unused / max(1, ins) > self.miss_threshold:
                    return self.controller.report_mismatch(self.op_id, org,
                                                           now)
        # ---- timing selection (hysteresis + switching margin)
        if now - self._switch_state["last_switch"] < self.min_dwell:
            return active
        need = _pctl(self.access_lat, 99) + self.gamma
        pos = {c.op_id: c.plan_pos for c in cands}

        def ok(op_id, margin):
            g = self.slack.get(op_id)
            return bool(g and len(g) >= 10 and _pctl(g, 99) >= need * margin)

        best = None
        for c in cands:                          # sorted source -> latest
            # moving LATER requires 25% slack headroom (anti-flapping);
            # staying / moving earlier only requires meeting the bound
            margin = 1.25 if pos.get(c.op_id, 0) > pos.get(active, 0) else 1.0
            if ok(c.op_id, margin):
                best = c.op_id                   # latest wins (keep updating)
        if best is not None and best != active:
            self._switch_state["last_switch"] = now
            return self.controller.request_timing_switch(self.op_id, best,
                                                         now)
        return active
