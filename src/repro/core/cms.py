"""Count-Min Sketch hot-key filter (paper §IV-B).

d rows x w columns of b-bit saturating counters; every ``aging_interval``
updates each counter is halved (integer right shift).  A key is HOT — and
its hint suppressed — iff ALL d touched counters are >= T.

This is the engine-side (Python/numpy) implementation used by lookahead
operators; ``repro.kernels.cms_sketch`` is the TPU twin for on-device hint
extraction, validated against this oracle.
"""
from __future__ import annotations

import numpy as np

_PRIMES = (1000003, 10000019, 100000007, 1000000007, 10000000019,
           100000000003, 1000000000039, 10000000000037)


class CountMinFilter:
    def __init__(self, depth: int = 4, width: int = 10000, bits: int = 8,
                 threshold: int = 20, aging_interval: int = 1000,
                 seed: int = 0):
        assert depth <= len(_PRIMES)
        self.d = depth
        self.w = width
        self.max_count = (1 << bits) - 1
        self.threshold = threshold
        self.aging_interval = aging_interval
        self.counters = np.zeros((depth, width), dtype=np.uint32)
        rng = np.random.RandomState(seed)
        self._a = rng.randint(1, 2 ** 31 - 1, size=depth).astype(np.int64)
        self._b = rng.randint(0, 2 ** 31 - 1, size=depth).astype(np.int64)
        self._since_aging = 0
        self.memory_bytes = depth * width * (bits // 8 or 1)

        # pure-python mirrors of the hash params: the per-event path touches
        # only d counters, where python ints beat numpy dispatch ~10x
        self._ap = [int(a) for a in self._a]
        self._bp = [int(b) for b in self._b]
        self._rows_buf = [0] * depth
        self._flat = self.counters.reshape(-1)

    def _cols(self, key):
        if not isinstance(key, int):
            key = hash(key)
        w = self.w
        out = self._rows_buf
        for i in range(self.d):
            out[i] = ((self._ap[i] * key + self._bp[i])
                      % _PRIMES[i]) % w
        return out

    def reset(self) -> None:
        """Zero all counters (process-restart semantics, DESIGN.md §7:
        CMS frequency state is soft and re-learns after a crash).  The
        cached flat view aliases ``counters``, so zero in place."""
        self.counters[:] = 0
        self._since_aging = 0

    def update_and_classify(self, key: int) -> bool:
        """Count one occurrence; return True iff the key is (now) hot."""
        flat = self._flat
        w = self.w
        hot = True
        thr = self.threshold
        mx = self.max_count
        for i, c in enumerate(self._cols(key)):
            j = i * w + c
            v = flat[j] + 1
            if v <= mx:
                flat[j] = v
            if v < thr:
                hot = False
        self._since_aging += 1
        if self._since_aging >= self.aging_interval:
            self.counters >>= 1
            self._since_aging = 0
        return hot

    def update(self, key: int) -> tuple:
        """Count one occurrence; return ``(estimate, hot)`` where
        ``estimate`` is the post-update count-min estimate (min over
        rows, saturating) and ``hot`` matches ``update_and_classify``.
        The selective HintFilter (core/hint_filter.py) needs the
        estimate for its cold/priority thresholds, not just the hot bit
        — same single pass over the d touched counters."""
        flat = self._flat
        w = self.w
        thr = self.threshold
        mx = self.max_count
        est = mx + 1
        for i, c in enumerate(self._cols(key)):
            j = i * w + c
            v = flat[j] + 1
            if v <= mx:
                flat[j] = v
            else:
                v = mx
            if v < est:
                est = v
        self._since_aging += 1
        if self._since_aging >= self.aging_interval:
            self.counters >>= 1
            self._since_aging = 0
        return int(est), est >= thr

    def estimate(self, key: int) -> int:
        flat = self._flat
        return int(min(flat[i * self.w + c]
                       for i, c in enumerate(self._cols(key))))

    def is_hot(self, key: int) -> bool:
        flat = self._flat
        return all(flat[i * self.w + c] >= self.threshold
                   for i, c in enumerate(self._cols(key)))
