from repro.core.cms import CountMinFilter
from repro.core.hint_filter import HintFilter
from repro.core.hints import HintsBuffer
from repro.core.policies import ClockCache, LRUCache
from repro.core.prefetch import (LookaheadCandidate, PrefetchingController,
                                 PrefetchingManager)
from repro.core.tac import TimestampAwareCache

__all__ = ["CountMinFilter", "HintFilter", "HintsBuffer", "ClockCache",
           "LRUCache", "LookaheadCandidate", "PrefetchingController",
           "PrefetchingManager", "TimestampAwareCache"]
