"""AdamW with bf16-moment option, global-norm clipping, cosine schedule.

Functional: ``state = init(params)``, ``params, state = update(...)``.
Moments can be kept in bf16 (``moment_dtype``) to fit 200B-class models on
16 GB/chip meshes (see EXPERIMENTS.md §Dry-run memory accounting); the ZeRO-1
sharding of this state over the data axis is applied by the launcher
(``repro.launch.specs.opt_pspec``), not here.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    mu: Any                  # pytree like params
    nu: Any                  # pytree like params


@dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"      # "float32" | "bfloat16"


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(1, cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) \
        * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), tree), gn


def init(cfg: AdamWConfig, params) -> AdamWState:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def update(cfg: AdamWConfig, params, state: AdamWState, grads,
           ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m, v, g):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay (skip 0/1-d params: norms, biases, scalars)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_g = jax.tree.leaves(grads)
    out = [upd(p, m, v, g) for p, m, v, g in
           zip(flat_p, flat_m, flat_v, flat_g)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics
