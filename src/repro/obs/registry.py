"""Unified metrics registry (DESIGN.md §12).

One typed, hierarchically-named sink for every counter, gauge, and latency
histogram in the repo: the streaming engine, the serving plane, the
checkpoint/recovery plane, and the device-side kernel tallies all publish
here, and every ``BENCH_*.json`` / ``tools/obs_report.py`` surface reads
back out of one ``snapshot()``.

Design points:

  * **Typed handles.**  ``Counter`` (monotonic), ``Gauge`` (last value),
    ``Histogram`` (a streaming log-linear quantile sketch — NOT a capped
    sample list, so percentiles never bias toward warmup samples no
    matter how long the run is).
  * **Hierarchical names.**  Dot-separated, e.g.
    ``engine.stateful.shard.0.prefetch_hits``.  The name grammar is
    documented as TEMPLATES in ``METRIC_CATALOG`` (``<op>`` matches one
    concrete segment); ``tools/check_docs.py`` verifies DESIGN.md §12
    cites only catalogued templates, and tests verify every name a run
    actually registers matches some template.
  * **Zero-cost when disabled.**  A disabled registry hands out shared
    no-op singletons, so instrumented hot paths pay one method call on a
    do-nothing object and allocate nothing.
  * **JSONL export.**  ``export_jsonl`` appends one snapshot line; the
    engine drives it on a configurable sim-clock cadence.

Stdlib-only on purpose: ``tools/check_docs.py`` imports the catalog from
here without jax/numpy installed.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple


class QuantileSketch:
    """Streaming two-sided log-linear histogram (HDR-style).

    Values are bucketed at ``bins_per_decade`` resolution (64/decade =>
    <2% relative quantile error); negative values get a mirrored bucket
    space (prefetch LEAD TIMES are signed — negative means late).  Count,
    sum, min, and max are tracked exactly; quantiles interpolate the bin
    midpoint (geometric) and clamp to the observed [min, max].
    """

    __slots__ = ("lo", "_k", "pos", "neg", "zero",
                 "count", "total", "vmin", "vmax")

    def __init__(self, lo: float = 1e-9, bins_per_decade: int = 64):
        self.lo = lo
        self._k = bins_per_decade / math.log(10.0)
        self.pos: Dict[int, int] = {}
        self.neg: Dict[int, int] = {}
        self.zero = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bin(self, mag: float) -> int:
        if mag <= self.lo:
            return 0
        return int(self._k * math.log(mag / self.lo)) + 1

    def _bin_value(self, idx: int) -> float:
        if idx == 0:
            return self.lo
        return self.lo * math.exp((idx - 0.5) / self._k)

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v > 0.0:
            b = self._bin(v)
            self.pos[b] = self.pos.get(b, 0) + 1
        elif v < 0.0:
            b = self._bin(-v)
            self.neg[b] = self.neg.get(b, 0) + 1
        else:
            self.zero += 1

    def merge(self, other: "QuantileSketch") -> None:
        for b, n in other.pos.items():
            self.pos[b] = self.pos.get(b, 0) + n
        for b, n in other.neg.items():
            self.neg[b] = self.neg.get(b, 0) + n
        self.zero += other.zero
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """q in [0, 1].  Walks negatives (most negative first), zeros,
        then positives."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)
        seen = 0
        for b in sorted(self.neg, reverse=True):   # most negative first
            seen += self.neg[b]
            if seen > rank:
                return self._clamp(-self._bin_value(b))
        seen += self.zero
        if seen > rank:
            return self._clamp(0.0)
        for b in sorted(self.pos):
            seen += self.pos[b]
            if seen > rank:
                return self._clamp(self._bin_value(b))
        return self.vmax

    def _clamp(self, v: float) -> float:
        return min(max(v, self.vmin), self.vmax)

    def percentiles(self, qs: Iterable[float] = (50, 90, 99)
                    ) -> Dict[str, float]:
        return {f"p{q:g}".replace(".", "_"): self.quantile(q / 100.0)
                for q in qs}

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0}
        out = {"count": self.count, "mean": self.mean,
               "min": self.vmin, "max": self.vmax}
        out.update(self.percentiles((50, 90, 99, 99.9)))
        return out


# --------------------------------------------------------------- handles
class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set(self, v) -> None:
        """Mirror an externally-maintained cumulative count (the legacy
        operator-local ints synced at snapshot time)."""
        self.value = v


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    __slots__ = ("name", "sketch")

    def __init__(self, name: str, lo: float = 1e-9,
                 bins_per_decade: int = 64):
        self.name = name
        self.sketch = QuantileSketch(lo, bins_per_decade)

    def observe(self, v: float) -> None:
        self.sketch.observe(v)

    @property
    def count(self) -> int:
        return self.sketch.count

    def quantile(self, q: float) -> float:
        return self.sketch.quantile(q)


class _NullCounter:
    __slots__ = ()
    name = ""
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    name = ""
    value = 0.0

    def set(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    name = ""
    count = 0

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()


# ---------------------------------------------------------------- registry
class MetricsRegistry:
    """Name -> typed handle store.  Handles are memoized, so hot paths
    hold the handle and never re-look-up by name.  A disabled registry
    returns the shared no-op singletons (zero allocation, zero state)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        # counter values at the previous export_jsonl call, so each
        # exported line can carry its own interval delta
        self._last_export: Dict[str, float] = {}

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return NULL_COUNTER
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return NULL_GAUGE
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, lo: float = 1e-9,
                  bins_per_decade: int = 64) -> Histogram:
        if not self.enabled:
            return NULL_HISTOGRAM
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, lo,
                                                   bins_per_decade)
        return h

    # ------------------------------------------------------------- export
    def names(self) -> List[str]:
        return sorted(list(self._counters) + list(self._gauges)
                      + list(self._histograms))

    def snapshot(self) -> Dict[str, Any]:
        """Flat name -> value map: counters/gauges to their value,
        histograms to a {count, mean, min, max, p50...} summary."""
        out: Dict[str, Any] = {}
        for n, c in self._counters.items():
            out[n] = c.value
        for n, g in self._gauges.items():
            out[n] = g.value
        for n, h in self._histograms.items():
            out[n] = h.sketch.summary()
        return out

    def export_jsonl(self, path: str, t: Optional[float] = None,
                     cumulative: bool = False) -> None:
        """Append one snapshot line.  By default the line carries a
        ``delta`` block — every counter's change since the PREVIOUS
        export on this registry, keyed to the logical timestamp ``t`` —
        alongside the cumulative ``metrics`` map, so downstream tools
        read interval rates directly instead of diffing consecutive
        snapshots by hand.  ``cumulative=True`` restores the legacy
        cumulative-only line shape (and does not advance the delta
        baseline)."""
        line: Dict[str, Any] = {"t": t, "metrics": self.snapshot()}
        if not cumulative:
            delta: Dict[str, float] = {}
            for n, c in self._counters.items():
                delta[n] = c.value - self._last_export.get(n, 0)
                self._last_export[n] = c.value
            line["delta"] = delta
        with open(path, "a") as f:
            f.write(json.dumps(line, sort_keys=True) + "\n")


# ----------------------------------------------------------------- catalog
# Metric-name TEMPLATES: ``<x>`` matches exactly one concrete segment
# (operator name, shard index, stage name, ...).  DESIGN.md §12's metric
# table cites these templates verbatim; tools/check_docs.py fails if it
# cites one that is not here, and tests/test_obs.py fails if a live run
# registers a name no template covers.  Keep the three in lockstep.
METRIC_CATALOG: Dict[str, str] = {
    # engine-wide
    "engine.sink.latency":
        "sink end-to-end latency (s), streaming sketch over ALL samples",
    "engine.sink.count": "tuples delivered to sinks",
    "engine.net.data_bytes": "bytes flushed on data channels",
    "engine.net.hint_bytes": "bytes flushed on hint side channels",
    "engine.cpu.util": "aggregate busy fraction across operator slots",
    # per-operator (any operator)
    "engine.<op>.processed": "messages processed by the operator",
    "engine.<op>.busy_frac": "busy-time fraction of the operator's slots",
    "engine.<op>.queue.depth": "input + ready queue depth at snapshot",
    "engine.<op>.watermark.lag":
        "max source event ts minus operator watermark (s)",
    # per-stateful-operator keyed-state plane
    "engine.<op>.cache.hits": "cache hits (all subtasks)",
    "engine.<op>.cache.misses": "cache misses (all subtasks)",
    "engine.<op>.backend.reads": "backend read ops",
    "engine.<op>.backend.writes": "backend write ops",
    "engine.<op>.access.latency":
        "charged state-access latency (s) seen by the PrefetchingManager",
    # hint telemetry (DESIGN.md §12; the headline plane)
    "engine.<op>.hints.received": "hints delivered to the operator",
    "engine.<op>.hints.late": "hints behind the watermark-lateness horizon",
    "engine.<op>.hints.duplicate": "hints for already-resident keys (renew)",
    "engine.<op>.hints.channel_delay":
        "hint-channel delay (s): emit at the lookahead -> receive",
    "engine.<op>.prefetch.staged": "hint-triggered stagings completed",
    "engine.<op>.prefetch.used": "staged entries later read by a tuple",
    "engine.<op>.prefetch.wasted": "staged entries evicted before any use",
    "engine.<op>.prefetch.late":
        "stagings that completed after a tuple already parked on the key",
    "engine.<op>.prefetch.hits": "tuple accesses served by staged state",
    "engine.<op>.prefetch.demand_fetches":
        "unhinted demand fetches (misses the hint plane failed to cover)",
    "engine.<op>.prefetch.lead":
        "hint lead time (s): first access minus stage-complete; <0 = late",
    "engine.<op>.prefetch.stage_latency": "staging I/O latency (s)",
    # hint suppression plane (§13): HintFilter verdicts graded by the
    # next access to the key at the stateful operator
    "engine.<op>.prefetch.suppressed": "hints dropped by the HintFilter",
    "engine.<op>.prefetch.suppress_resident":
        "suppressions graded correct: next access hit cache in-horizon",
    "engine.<op>.prefetch.suppress_miss":
        "suppressions graded incorrect: next access missed in-horizon",
    "engine.<op>.prefetch.suppress_unused":
        "suppressions never followed by an in-horizon access (hint would "
        "have been wasted)",
    # fused device hot path (§14): per-batch device tallies rolled up
    # host-side after each launch
    "engine.<op>.fused.batches": "fused device batches launched",
    "engine.<op>.fused.lanes": "lanes staged across all fused batches",
    "engine.<op>.fused.fill_ratio":
        "lanes / (batches x batch width) — underfilled batches waste "
        "launch cost (fences and drain stalls fragment them)",
    "engine.<op>.fused.device_hits": "device TAC directory probe hits",
    "engine.<op>.fused.device_misses":
        "device TAC directory probe misses (host adjudicates: admit, "
        "park, or write-back race)",
    "engine.<op>.fused.device_conflicts":
        "device misses adjudicated while the plane was FULL (admission "
        "must evict — the streaming analogue of serving probe conflicts)",
    # TAC eviction-reason breakdown, split by admission path
    "engine.<op>.evict.<reason>.<adm>":
        "evictions by reason (capacity|deadline|stale) and admission "
        "(prefetched|demand)",
    # sharded plane (§9)
    "engine.<op>.shard.<shard>.hints_routed": "hints routed to the shard",
    "engine.<op>.shard.<shard>.prefetch_hits": "prefetch hits on the shard",
    "engine.<op>.shard.<shard>.pending":
        "messages parked behind the shard's in-flight migration",
    "engine.<op>.shards.misroutes": "ownership-guard forwards",
    "engine.<op>.shards.migrations": "completed shard migrations",
    # checkpoint / recovery plane (§7)
    "checkpoint.snapshots_taken": "operator-subtask snapshots taken",
    "checkpoint.align_stall_total": "summed barrier alignment stall (s)",
    "checkpoint.align_stall_max": "max barrier alignment stall (s)",
    "checkpoint.align_buffered": "messages buffered during alignment",
    "checkpoint.completed": "epochs completed",
    "checkpoint.bytes": "snapshot bytes persisted",
    "recovery.count": "recoveries performed",
    "recovery.warmup_hints": "hint-WAL + manifest entries replayed at warmup",
    "recovery.restore_s": "modelled restore + warmup wall time (s)",
    # temporal plane (§16): logical-clock timeline + health detectors
    "timeline.intervals": "interval snapshots cut on the logical clock",
    "timeline.evicted":
        "intervals dropped off the bounded ring (reports over a window "
        "older than this are truncated, not silently shorter)",
    "timeline.interval_s": "configured timeline interval (sim seconds)",
    "health.alerts.raised": "health alerts raised (all detectors)",
    "health.alerts.cleared": "raised alerts whose detector returned to ok",
    "health.alerts.active": "detectors currently in the firing state",
    "health.alerts.<kind>":
        "alerts raised per kind: wm_lag|stall|precision|late_wall|"
        "migration|recovery|load_shift",
    # per-tuple critical-path tracing (sampled spans)
    "trace.sampled": "tuples sampled for span tracing",
    "trace.finished":
        "sampled spans finalized (sink delivery or absorbed into state)",
    "trace.probe.hit": "sampled tuples whose state probe hit",
    "trace.probe.miss": "sampled tuples whose state probe missed",
    "trace.stage.<stage>":
        "per-stage critical-path time (s): upstream|park_wait|sync_fetch|"
        "downstream",
    # serving plane (§6)
    "serving.ttft": "time to first token (s)",
    "serving.tpot": "time per output token (s)",
    "serving.requests": "requests enqueued",
    "serving.tokens": "tokens emitted",
    "serving.arena.probe.hits": "device TAC probe hits (tac_probe kernel)",
    "serving.arena.probe.misses": "device TAC probe misses",
    "serving.arena.probe.conflicts":
        "device TAC probe misses landing in a FULL bucket (admission would "
        "evict)",
}


def matches_catalog(name: str, catalog: Optional[Dict[str, str]] = None
                    ) -> bool:
    """True when ``name`` is covered by some catalog template
    (``<x>`` segments match any one concrete segment)."""
    catalog = METRIC_CATALOG if catalog is None else catalog
    parts = name.split(".")
    for tmpl in catalog:
        tparts = tmpl.split(".")
        if len(tparts) != len(parts):
            continue
        if all(tp.startswith("<") or tp == p
               for tp, p in zip(tparts, parts)):
            return True
    return False
