"""Logical-clock time series over the metrics registry (DESIGN.md §16).

The registry (``registry.py``) is cumulative: every counter is a running
total and every histogram an uncapped streaming sketch, which is exactly
right for end-of-run aggregates and exactly wrong for watching a
transient unfold.  This module adds the temporal axis:

  * ``Timeline`` — on a fixed interval of the engine's DISCRETE-EVENT
    clock (never wall time: runs replay bit-exactly, so the series do
    too), snapshot every registered instrument and keep the per-interval
    view in a bounded ring buffer: counter DELTAS (what happened in the
    interval), gauge SAMPLES (the state at the cut), and histogram
    INTERVAL SKETCHES (a full ``QuantileSketch`` of just the interval's
    observations, so p99-over-10s is a ``merge`` of 100 intervals, not a
    guess from cumulative percentiles).
  * ``interval_sketch`` — the subtraction that makes interval quantiles
    exact: two cumulative sketch states differ only in bin counts, so
    the delta sketch is the bin-wise difference and stays mergeable.

Per-operator and per-shard resolution comes for free: the engine's
``_sync_registry`` mirrors every ``engine.<op>.*`` / ``<op>.shard.<i>.*``
counter before each tick, so the timeline inherits the full catalogued
namespace without any plane-specific wiring.

Stdlib-only, like the registry: ``tools/obs_report.py --timeline`` and
the docs job import this without the jax toolchain.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.obs.registry import MetricsRegistry, QuantileSketch


def _sketch_state(sk: QuantileSketch) -> tuple:
    """Cheap frozen copy of a cumulative sketch's bin state."""
    return (dict(sk.pos), dict(sk.neg), sk.zero, sk.count, sk.total,
            sk.vmin, sk.vmax)


def interval_sketch(prev: Optional[tuple], sk: QuantileSketch
                    ) -> QuantileSketch:
    """Delta of two cumulative sketch states of the SAME instrument,
    as a standalone mergeable ``QuantileSketch``.

    Cumulative sketches only ever gain observations, so the interval's
    histogram is the bin-wise count difference.  Exact min/max of just
    the interval are not recoverable from bins; the delta clamps to the
    extreme bin midpoints it actually holds (within the sketch's
    relative-error bound), falling back to the cumulative extremes when
    an extreme bin gained counts.
    """
    out = QuantileSketch(sk.lo)
    out._k = sk._k
    ppos, pneg, pzero, pcount, ptotal, pvmin, pvmax = \
        prev if prev is not None else ({}, {}, 0, 0, 0.0,
                                       float("inf"), float("-inf"))
    for b, n in sk.pos.items():
        d = n - ppos.get(b, 0)
        if d > 0:
            out.pos[b] = d
    for b, n in sk.neg.items():
        d = n - pneg.get(b, 0)
        if d > 0:
            out.neg[b] = d
    out.zero = sk.zero - pzero
    out.count = sk.count - pcount
    out.total = sk.total - ptotal
    if out.count <= 0:
        return out
    lo_candidates: List[float] = []
    hi_candidates: List[float] = []
    if out.neg:
        lo_candidates.append(-out._bin_value(max(out.neg)))
        hi_candidates.append(-out._bin_value(min(out.neg)))
    if out.zero:
        lo_candidates.append(0.0)
        hi_candidates.append(0.0)
    if out.pos:
        lo_candidates.append(out._bin_value(min(out.pos)))
        hi_candidates.append(out._bin_value(max(out.pos)))
    out.vmin = min(lo_candidates)
    out.vmax = max(hi_candidates)
    # a new cumulative extreme must have landed in this interval — carry
    # the exact value instead of the bin midpoint
    if sk.vmin < pvmin:
        out.vmin = sk.vmin
    if sk.vmax > pvmax:
        out.vmax = sk.vmax
    return out


class Interval:
    """One timeline cut: everything that happened in ``(t0, t1]``."""

    __slots__ = ("t0", "t1", "deltas", "gauges", "sketches")

    def __init__(self, t0: float, t1: float, deltas: Dict[str, float],
                 gauges: Dict[str, float],
                 sketches: Dict[str, QuantileSketch]):
        self.t0 = t0
        self.t1 = t1
        self.deltas = deltas            # counter name -> interval delta
        self.gauges = gauges            # gauge name -> sample at t1
        self.sketches = sketches        # histogram name -> interval sketch

    def as_record(self) -> Dict[str, Any]:
        """JSON-serializable view (``export.timeline_jsonl``)."""
        q = {}
        for name, sk in self.sketches.items():
            if sk.count:
                q[name] = {"count": sk.count, "mean": sk.mean,
                           "p50": sk.quantile(0.50),
                           "p99": sk.quantile(0.99)}
        return {"t0": self.t0, "t1": self.t1, "deltas": self.deltas,
                "gauges": self.gauges, "quantiles": q}

    def __repr__(self):
        return (f"Interval({self.t0:.3f}..{self.t1:.3f}, "
                f"{len(self.deltas)} deltas)")


class Timeline:
    """Bounded ring of per-interval registry snapshots on the logical
    clock.  The driver (``Engine._timeline_tick``) calls ``tick`` every
    ``interval`` sim seconds after mirroring the operator counters; the
    ring holds the most recent ``capacity`` intervals and evicts the
    oldest beyond that (``evicted`` counts what fell off, so a report
    over a truncated window says so instead of silently covering less).
    """

    def __init__(self, registry: MetricsRegistry, interval: float = 0.1,
                 capacity: int = 600):
        if interval <= 0:
            raise ValueError(f"interval must be > 0, got {interval}")
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        self.registry = registry
        self.interval = float(interval)
        self.capacity = int(capacity)
        self.ring: deque = deque(maxlen=self.capacity)
        self.intervals_taken = 0
        self.evicted = 0
        self._last_t: Optional[float] = None
        self._prev_counters: Dict[str, float] = {}
        self._prev_hists: Dict[str, tuple] = {}
        # timeline's own instruments live in the same registry/catalog
        self._c_intervals = registry.counter("timeline.intervals")
        self._c_evicted = registry.counter("timeline.evicted")
        registry.gauge("timeline.interval_s").set(self.interval)

    # ------------------------------------------------------------- ticking
    def tick(self, t: float) -> Interval:
        """Cut an interval ending at logical time ``t``."""
        t0 = self._last_t if self._last_t is not None \
            else t - self.interval
        deltas: Dict[str, float] = {}
        for name, c in self.registry._counters.items():
            if name.startswith("timeline."):
                continue                # the meta-counters would self-count
            prev = self._prev_counters.get(name, 0)
            if c.value != prev or name in self._prev_counters:
                deltas[name] = c.value - prev
            self._prev_counters[name] = c.value
        gauges = {name: g.value
                  for name, g in self.registry._gauges.items()
                  if not name.startswith("timeline.")}
        sketches: Dict[str, QuantileSketch] = {}
        for name, h in self.registry._histograms.items():
            sk = interval_sketch(self._prev_hists.get(name), h.sketch)
            self._prev_hists[name] = _sketch_state(h.sketch)
            if sk.count:
                sketches[name] = sk
        iv = Interval(t0, t, deltas, gauges, sketches)
        if len(self.ring) == self.capacity:
            self.evicted += 1
        self.ring.append(iv)
        self.intervals_taken += 1
        self._last_t = t
        self._c_intervals.set(self.intervals_taken)
        self._c_evicted.set(self.evicted)
        return iv

    # ------------------------------------------------------------ querying
    def select(self, since: Optional[float] = None,
               until: Optional[float] = None) -> List[Interval]:
        """Retained intervals whose END time lies in [since, until]."""
        lo = float("-inf") if since is None else since
        hi = float("inf") if until is None else until
        return [iv for iv in self.ring if lo <= iv.t1 <= hi]

    def series(self, name: str, since: Optional[float] = None,
               until: Optional[float] = None
               ) -> List[Tuple[float, float]]:
        """(t1, value) points for a counter delta or gauge sample."""
        out = []
        for iv in self.select(since, until):
            if name in iv.deltas:
                out.append((iv.t1, iv.deltas[name]))
            elif name in iv.gauges:
                out.append((iv.t1, iv.gauges[name]))
        return out

    def merged_sketch(self, name: str, since: Optional[float] = None,
                      until: Optional[float] = None) -> QuantileSketch:
        """Quantiles over a window = merge of its interval sketches."""
        out = QuantileSketch()
        for iv in self.select(since, until):
            sk = iv.sketches.get(name)
            if sk is not None:
                if not out.count:
                    out.lo, out._k = sk.lo, sk._k
                out.merge(sk)
        return out

    def ratio_series(self, num: str, den: Iterable[str],
                     min_den: float = 1.0,
                     since: Optional[float] = None,
                     until: Optional[float] = None
                     ) -> List[Tuple[float, float]]:
        """Per-interval ``num / sum(den)`` (e.g. interval precision =
        Δused / (Δstaged + Δlate)); intervals whose denominator is below
        ``min_den`` are skipped rather than reported as noise."""
        den = list(den)
        out = []
        for iv in self.select(since, until):
            d = sum(iv.deltas.get(n, 0) for n in den)
            if d < min_den:
                continue
            out.append((iv.t1, iv.deltas.get(num, 0) / d))
        return out

    # ------------------------------------------------------------- summary
    def block(self) -> Dict[str, Any]:
        """Rollup for ``Engine.metrics`` / BENCH_obs.json."""
        return {"intervals": self.intervals_taken,
                "retained": len(self.ring),
                "evicted": self.evicted,
                "interval_s": self.interval,
                "capacity": self.capacity,
                "t_last": self._last_t}
