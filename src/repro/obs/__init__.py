"""Unified observability plane (DESIGN.md §12, §16): metrics registry
with streaming quantile sketches, per-tuple critical-path tracing,
prefetch-quality (hint timeliness/accuracy) telemetry, logical-clock
time series with health detectors, and Perfetto/Chrome-trace export."""
from repro.obs.export import (chrome_trace, read_timeline_jsonl,
                              timeline_jsonl)
from repro.obs.health import (Alert, Detector, HealthMonitor,
                              LoadShiftDetector, ORACLE_KINDS,
                              SpikeDetector)
from repro.obs.quality import PrefetchRecorder
from repro.obs.registry import (
    METRIC_CATALOG,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_COUNTER,
    NULL_GAUGE,
    NULL_HISTOGRAM,
    QuantileSketch,
    matches_catalog,
)
from repro.obs.timeseries import Interval, Timeline, interval_sketch
from repro.obs.trace import STAGES, Tracer, TupleTrace, attach

__all__ = [
    "Alert",
    "Detector",
    "HealthMonitor",
    "Interval",
    "LoadShiftDetector",
    "METRIC_CATALOG",
    "ORACLE_KINDS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_COUNTER",
    "NULL_GAUGE",
    "NULL_HISTOGRAM",
    "PrefetchRecorder",
    "QuantileSketch",
    "SpikeDetector",
    "Timeline",
    "chrome_trace",
    "interval_sketch",
    "matches_catalog",
    "read_timeline_jsonl",
    "timeline_jsonl",
    "STAGES",
    "Tracer",
    "TupleTrace",
    "attach",
]
