"""Unified observability plane (DESIGN.md §12): metrics registry with
streaming quantile sketches, per-tuple critical-path tracing, and
prefetch-quality (hint timeliness/accuracy) telemetry."""
from repro.obs.quality import PrefetchRecorder
from repro.obs.registry import (
    METRIC_CATALOG,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_COUNTER,
    NULL_GAUGE,
    NULL_HISTOGRAM,
    QuantileSketch,
    matches_catalog,
)
from repro.obs.trace import STAGES, Tracer, TupleTrace, attach

__all__ = [
    "METRIC_CATALOG",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_COUNTER",
    "NULL_GAUGE",
    "NULL_HISTOGRAM",
    "PrefetchRecorder",
    "QuantileSketch",
    "matches_catalog",
    "STAGES",
    "Tracer",
    "TupleTrace",
    "attach",
]
