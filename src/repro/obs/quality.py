"""Prefetch-quality telemetry: per-hint outcomes and lead times
(DESIGN.md §12 — the headline of the observability plane).

The paper's claim is that prefetching must be *timely* and *accurate*;
this module measures both directly instead of inferring them from p99.
Every hint that reaches a stateful operator ends in exactly one outcome:

  * ``duplicate`` — the key was already resident (the hint only renewed
    its timestamp); counted by the PrefetchingManager.
  * ``late`` (watermark) — the hint's access time fell behind the
    lateness horizon; no fetch was scheduled (``hints_late``).
  * ``late`` (staging) — a fetch was scheduled but a tuple parked on the
    key before staging completed: the prefetch was issued, just not in
    time.  Lead time is recorded NEGATIVE (first need minus
    stage-complete).
  * ``used`` — staged ahead of need and later read by a tuple.  Lead
    time is positive: first access minus stage-complete.
  * ``wasted`` — staged, never read, evicted (the TAC's
    ``prefetch_unused_evicted`` path, now with lead/registry accounting).
  * still-resident — staged, not yet read, still cached at snapshot time
    (derived: ``staged - used - wasted``).
  * ``suppressed`` — the lookahead's HintFilter (DESIGN.md §13) dropped
    the hint at the source; it never reached the channel.  Resolved
    retroactively by the NEXT access to the key at the stateful operator:
    a hit within the horizon = ``suppress_resident`` (correct — the key
    was cached, the hint would have been a duplicate), a miss within the
    horizon = ``suppress_miss`` (incorrect — the suppression cost a
    demand fetch), no access within ``suppress_horizon`` =
    ``suppress_unused`` (correct — the hint would have been wasted
    anyway).  Invariant: ``suppressed == suppress_resident +
    suppress_miss + suppress_unused + suppress_pending``.

From these, ``quality_block`` derives the two headline ratios every
benchmark now reports next to p99:

  * **precision** = used / (staged + late-staging) — what fraction of
    staging I/O moved bytes a tuple actually read;
  * **recall**    = prefetch_hits / (prefetch_hits + demand_fetches) —
    what fraction of would-be misses the hint plane covered in time.

One recorder serves all subtasks of a stateful operator (counters
aggregate, like the shared adaptation statistics of the
PrefetchingManager).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

from repro.obs.registry import MetricsRegistry


class PrefetchRecorder:
    """Bridges the TAC (staged/used/wasted) and the engine I/O layer
    (late stagings, staging latency, hint-channel delay) into the
    registry.  ``now_fn`` supplies the processing-time clock (the sim
    clock on the streaming engine) — lead times are processing-time
    quantities even when the cache orders by event time."""

    def __init__(self, registry: MetricsRegistry, prefix: str,
                 now_fn: Callable[[], float],
                 suppress_horizon: float = 1.0):
        self.now = now_fn
        self.staged = registry.counter(f"{prefix}.prefetch.staged")
        self.used = registry.counter(f"{prefix}.prefetch.used")
        self.wasted = registry.counter(f"{prefix}.prefetch.wasted")
        self.late = registry.counter(f"{prefix}.prefetch.late")
        self.lead = registry.histogram(f"{prefix}.prefetch.lead")
        self.stage_latency = registry.histogram(
            f"{prefix}.prefetch.stage_latency")
        self.channel_delay = registry.histogram(
            f"{prefix}.hints.channel_delay")
        # suppression plane (DESIGN.md §13): HintFilter verdicts graded
        # against what the stateful operator actually did next
        self.suppressed = registry.counter(f"{prefix}.prefetch.suppressed")
        self.suppress_resident = registry.counter(
            f"{prefix}.prefetch.suppress_resident")
        self.suppress_miss = registry.counter(
            f"{prefix}.prefetch.suppress_miss")
        self.suppress_unused = registry.counter(
            f"{prefix}.prefetch.suppress_unused")
        self.suppress_horizon = suppress_horizon
        # key -> [first suppression time, suppression count]; the access
        # path checks truthiness of this dict before paying a lookup
        self.pending_suppressed: Dict[Any, list] = {}
        self._since_expire = 0

    # ---- TAC-side hooks (core/tac.py calls these when a recorder is set)
    def on_staged(self) -> None:
        """A hint-triggered fetch completed and its entry was admitted
        with no tuple waiting: timely staging."""
        self.staged.inc()

    def on_used(self, stage_t: float) -> None:
        """First read of a staged-and-unused entry: positive lead =
        first-access time minus stage-complete time."""
        self.used.inc()
        self.lead.observe(self.now() - stage_t)

    def on_wasted(self) -> None:
        """A staged entry was evicted without ever being read."""
        self.wasted.inc()

    # ---- engine-side hooks (StatefulOp I/O completion path)
    def on_late(self, first_need_t: float) -> None:
        """Staging completed with a tuple already parked on the key:
        negative lead = first-need time minus stage-complete time."""
        self.late.inc()
        self.lead.observe(first_need_t - self.now())

    def on_stage_latency(self, lat: float) -> None:
        self.stage_latency.observe(lat)

    def on_channel_delay(self, delay: float) -> None:
        self.channel_delay.observe(delay)

    # ---- suppression hooks (lookahead HintFilter + StatefulOp access path)
    def on_suppressed(self, key: Any) -> None:
        """The lookahead suppressed a hint for ``key``.  Repeated
        suppressions of one key fold into a single pending entry (they
        all share the outcome of the next access)."""
        self.suppressed.inc()
        now = self.now()
        ent = self.pending_suppressed.get(key)
        if ent is None:
            self.pending_suppressed[key] = [now, 1]
        else:
            ent[1] += 1
        self._since_expire += 1
        if self._since_expire >= 1024:
            self._since_expire = 0
            self._expire(now)

    def on_access(self, key: Any, hit: bool) -> None:
        """The stateful operator accessed ``key``: grade any pending
        suppression.  A hit means the key really was resident (correct
        suppression); a miss means the suppressed hint would have
        prefetched it (incorrect).  An access arriving beyond the
        horizon is unrelated to the suppression — graded unused."""
        ent = self.pending_suppressed.pop(key, None)
        if ent is None:
            return
        first_t, n = ent
        if self.now() - first_t > self.suppress_horizon:
            self.suppress_unused.inc(n)
        elif hit:
            self.suppress_resident.inc(n)
        else:
            self.suppress_miss.inc(n)

    def _expire(self, now: float) -> None:
        """Grade pending suppressions older than the horizon as unused
        (the key was never accessed again — the hint would have been a
        wasted staging)."""
        horizon = self.suppress_horizon
        stale = [k for k, (t, _n) in self.pending_suppressed.items()
                 if now - t > horizon]
        for k in stale:
            self.suppress_unused.inc(self.pending_suppressed.pop(k)[1])

    def flush_pending(self) -> None:
        """End-of-run: grade everything still pending as unused so the
        invariant closes (benchmarks call this before the final
        snapshot; mid-run snapshots report ``suppress_pending``)."""
        for k in list(self.pending_suppressed):
            self.suppress_unused.inc(self.pending_suppressed.pop(k)[1])

    # ------------------------------------------------------------ rollup
    def quality_block(self, prefetch_hits: int, demand_fetches: int,
                      duplicates: int, late_wm: int) -> Dict[str, Any]:
        """The per-operator hint-quality block surfaced by
        ``Engine.metrics`` and every ``BENCH_*.json``."""
        staged = self.staged.value
        used = self.used.value
        wasted = self.wasted.value
        late = self.late.value
        issued = staged + late
        suppressed = self.suppressed.value
        resolved = (self.suppress_resident.value + self.suppress_miss.value
                    + self.suppress_unused.value)
        sk = self.lead.sketch
        out = {
            "staged": staged,
            "used": used,
            "wasted": wasted,
            "late": late,
            "late_watermark": late_wm,
            "duplicate": duplicates,
            "resident_unused": max(0, staged - used - wasted),
            "suppressed": suppressed,
            "suppress_resident": self.suppress_resident.value,
            "suppress_miss": self.suppress_miss.value,
            "suppress_unused": self.suppress_unused.value,
            "suppress_pending": suppressed - resolved,
            "precision": used / issued if issued else 0.0,
            "recall": prefetch_hits / (prefetch_hits + demand_fetches)
            if (prefetch_hits + demand_fetches) else 0.0,
        }
        if sk.count:
            out.update({"lead_p50": sk.quantile(0.50),
                        "lead_p99": sk.quantile(0.99),
                        "lead_min": sk.vmin, "lead_max": sk.vmax,
                        "lead_mean": sk.mean})
        return out
