"""Per-tuple critical-path tracing on the sim clock (DESIGN.md §12).

Every Nth source tuple gets a ``TupleTrace`` attached (``Tuple_.trace``);
operators stamp the few marks that matter — stateful arrival, park /
resume, charged synchronous fetch time, apply/emit — and the sink
finalizes the span into per-stage histograms of the shared
``MetricsRegistry`` plus a bounded ring of raw span records for
``tools/obs_report.py``.

Stage model (a tuple's end-to-end latency decomposes into):

  * ``upstream``   — source emit -> stateful-operator arrival (parse
    operators, network flush/hops, input-queue wait);
  * ``park_wait``  — async-miss park -> resume (the state-staging time
    left on the tuple's own critical path; zero on a cache hit);
  * ``sync_fetch`` — backend latency CHARGED synchronously on this
    tuple (sync-mode fetch, parked-then-evicted refetch).  NOTE: in the
    discrete-event engine a sync charge delays the operator's NEXT
    message, not this tuple's own emission, so this stage measures
    blocking cost on the pipeline rather than a slice of this tuple's
    sink latency — stages therefore need not sum exactly to the total;
  * ``downstream`` — apply/emit -> sink (output network + sink queue).

Tracing is OFF by default (``sample_every=0``): sources check one flag
per tuple and every operator mark is behind a ``trace is not None``
test, so the disabled cost is a no-op attribute read.  The overhead gate
(``benchmarks/obs.py`` + ``tools/bench_gate.py``) holds tracing-enabled
wall-clock throughput within 5% of disabled.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Deque, Dict, List, Optional

from repro.obs.registry import MetricsRegistry, QuantileSketch

STAGES = ("upstream", "park_wait", "sync_fetch", "downstream")


class TupleTrace:
    """Span marks for one sampled tuple.  Slots only — these are created
    on the source hot path when sampling is on."""

    __slots__ = ("t0", "op", "t_state", "t_park", "t_resume", "t_apply",
                 "fetch_s", "hit", "done")

    def __init__(self, t0: float):
        self.t0 = t0
        self.op: Optional[str] = None
        self.t_state: Optional[float] = None
        self.t_park: Optional[float] = None
        self.t_resume: Optional[float] = None
        self.t_apply: Optional[float] = None
        self.fetch_s = 0.0
        self.hit: Optional[bool] = None
        self.done = False

    # marks (called from the engine; each behind a `trace is not None`)
    def mark_state(self, op: str, t: float) -> None:
        if self.t_state is None:
            self.op = op
            self.t_state = t

    def mark_park(self, t: float) -> None:
        if self.t_park is None:
            self.t_park = t

    def mark_resume(self, t: float) -> None:
        self.t_resume = t

    def mark_apply(self, t: float) -> None:
        self.t_apply = t

    def stages(self, t_sink: float) -> Dict[str, float]:
        out = dict.fromkeys(STAGES, 0.0)
        t_state = self.t_state if self.t_state is not None else t_sink
        out["upstream"] = max(0.0, t_state - self.t0)
        if self.t_park is not None:
            out["park_wait"] = max(
                0.0, (self.t_resume if self.t_resume is not None
                      else t_sink) - self.t_park)
        out["sync_fetch"] = self.fetch_s
        t_leave = self.t_apply if self.t_apply is not None else t_state
        out["downstream"] = max(0.0, t_sink - t_leave)
        return out


class Tracer:
    """Sampling controller + span aggregation into the registry."""

    def __init__(self, registry: MetricsRegistry,
                 keep_spans: int = 4096):
        self.registry = registry
        self.sample_every = 0            # 0 = disabled
        self._n = 0
        self.spans: Deque[Dict[str, Any]] = deque(maxlen=keep_spans)
        self._stage_hist = {s: registry.histogram(f"trace.stage.{s}")
                            for s in STAGES}
        self._sampled = registry.counter("trace.sampled")
        self._finished = registry.counter("trace.finished")
        self._hit = registry.counter("trace.probe.hit")
        self._miss = registry.counter("trace.probe.miss")

    @property
    def active(self) -> bool:
        return self.sample_every > 0

    def enable(self, sample_every: int = 64) -> None:
        self.sample_every = max(0, int(sample_every))

    def maybe_start(self, t0: float) -> Optional[TupleTrace]:
        """One branch per source tuple; allocates only on sampled ones.
        Safe to call disabled (callers on the hot path pre-check
        ``sample_every`` to skip even the counter increment)."""
        if not self.sample_every:
            return None
        self._n += 1
        if self._n % self.sample_every:
            return None
        self._sampled.inc()
        return TupleTrace(t0)

    def finish(self, trace: TupleTrace, t_sink: float) -> None:
        """Sink-side finalization.  A trace shared by several emitted
        tuples (pane expansion, multi-output operators) finalizes once."""
        if trace.done:
            return
        trace.done = True
        self._finished.inc()
        if trace.hit is True:
            self._hit.inc()
        elif trace.hit is False:
            self._miss.inc()
        stages = trace.stages(t_sink)
        for s, v in stages.items():
            self._stage_hist[s].observe(v)
        rec = {"t0": trace.t0, "t_sink": t_sink, "op": trace.op,
               "total": t_sink - trace.t0, "hit": trace.hit}
        rec.update(stages)
        self.spans.append(rec)

    # ------------------------------------------------------------- report
    def summary(self) -> Dict[str, Any]:
        """Per-stage breakdown + the dominant critical-path stage (by
        total time across sampled spans)."""
        out: Dict[str, Any] = {"sampled": self._sampled.value,
                               "finished": self._finished.value,
                               "probe_hits": self._hit.value,
                               "probe_misses": self._miss.value}
        totals = {}
        for s in STAGES:
            sk = self._stage_hist[s].sketch
            totals[s] = sk.total
            out[s] = {"mean": sk.mean, "p50": sk.quantile(0.50),
                      "p99": sk.quantile(0.99), "total": sk.total,
                      "count": sk.count}
        grand = sum(totals.values())
        for s in STAGES:
            out[s]["share"] = totals[s] / grand if grand > 0 else 0.0
        out["dominant_stage"] = max(totals, key=totals.get) if grand > 0 \
            else None
        return out

    def reset(self) -> None:
        """Warmup boundary: drop spans sampled before measurement starts
        and restart the per-stage histograms (counters keep counting —
        they are cumulative like the engine's)."""
        self.spans.clear()
        for h in self._stage_hist.values():
            if hasattr(h, "sketch"):
                h.sketch = QuantileSketch()


def attach(tuples: List[Any], trace: Optional[TupleTrace]) -> None:
    """Propagate a sampled trace onto derived tuples (map outputs, pane
    expansions, operator emissions) — no-op when the input was not
    sampled."""
    if trace is not None:
        for o in tuples:
            if getattr(o, "trace", None) is None:
                o.trace = trace
